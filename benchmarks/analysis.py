"""SLO analysis over load-generator runs + BENCH_serving.json schema tools.

Three jobs, one module:

  * :func:`scenario_report` — turn a :class:`~repro.serving.loadgen.
    LoadResult` into the per-tenant SLO section serving benchmarks emit:
    p50/p95/p99 TTFT and TPOT per tenant, queue-wait summaries,
    SLO-attainment (fraction of requests meeting BOTH the tenant's TTFT
    and TPOT thresholds) and goodput (SLO-attaining completions per
    second), plus a windowed TTFT trajectory so a PR diff shows *when*
    in the run the tail degraded, not just that it did.
  * :func:`saturation_sweep` — find max sustainable QPS: double the
    arrival-rate scale until p99 TTFT blows the budget, then bisect the
    bracket.  The classic open-loop saturation probe (cf. llm-d-benchmark
    and the operating-point sweeps Bitnet.cpp reports), made cheap by the
    virtual clock: each probe replays a freshly-generated trace
    deterministically.
  * :func:`check_schema` — the ONE place that knows what every
    ``BENCH_serving.json`` schema version (v2..v5) must contain.  CI and
    tests call this instead of each re-inventing field lists.

Also a tiny CLI (no deps beyond the repo):

    PYTHONPATH=src python benchmarks/analysis.py check BENCH_serving.json
    PYTHONPATH=src python benchmarks/analysis.py diff OLD.json NEW.json

``diff`` prints percentile deltas between two bench files (the PR-over-PR
view CI surfaces); it is informational, never gating.
"""

from __future__ import annotations

import json
import sys

from repro.serving.loadgen import LoadResult, latency_summary, percentile
from repro.serving.workload import Scenario

__all__ = ["scenario_report", "saturation_sweep", "check_schema",
           "diff_benches"]


# -- per-tenant SLO analysis -------------------------------------------------

def _slo_ok(rec, ten) -> bool:
    """A request attains its tenant's SLO iff it completed, its TTFT is
    under budget, and (when it emitted >= 2 tokens, so TPOT is defined) its
    TPOT is under budget too."""
    if rec.t_done is None or rec.ttft_s is None:
        return False
    if rec.ttft_s > ten.slo_ttft_s:
        return False
    return rec.tpot_s is None or rec.tpot_s <= ten.slo_tpot_s


def _trajectory(records, n_windows: int, ndigits: int = 6) -> list[dict]:
    """p50/p95/p99 TTFT per arrival-time window — the tail's time course.
    Windows are equal slices of the arrival span; empty windows report
    zero percentiles (latency_summary of an empty sample)."""
    done = [r for r in records if r.ttft_s is not None]
    if not done:
        return []
    t0 = min(r.t_arrival for r in done)
    t1 = max(r.t_arrival for r in done)
    span = max(t1 - t0, 1e-9)
    out = []
    for w in range(n_windows):
        lo = t0 + span * w / n_windows
        hi = t0 + span * (w + 1) / n_windows
        # last window has no upper bound so the final arrival always lands
        # somewhere even when hi != t1 by a float ulp
        vals = [r.ttft_s for r in done
                if lo <= r.t_arrival and (r.t_arrival < hi
                                          or w == n_windows - 1)]
        out.append({"window": w, "t_start_s": round(lo - t0, ndigits),
                    "requests": len(vals),
                    "ttft_s": latency_summary(vals, ndigits)})
    return out


def scenario_report(scenario: Scenario, result: LoadResult, seed: int,
                    n_windows: int = 4) -> dict:
    """The schema-v5 ``workload`` section: per-tenant percentile + SLO
    figures for one scenario replay.  All floats are rounded, so equal runs
    serialize byte-identically (the CI diffability contract)."""
    nd = 6
    tenants = {t.name: t for t in scenario.tenants}
    per_tenant: dict[str, dict] = {}
    good_total = 0
    for tname, recs in sorted(result.by_tenant().items()):
        ten = tenants[tname]
        good = sum(_slo_ok(r, ten) for r in recs)
        good_total += good
        per_tenant[tname] = {
            "requests": len(recs),
            "completed": sum(r.t_done is not None for r in recs),
            "ttft_s": latency_summary(
                [r.ttft_s for r in recs if r.ttft_s is not None], nd),
            "tpot_s": latency_summary(
                [r.tpot_s for r in recs if r.tpot_s is not None], nd),
            "queue_wait_s": latency_summary(
                [r.queue_wait_s for r in recs
                 if r.queue_wait_s is not None], nd),
            "slo": {"ttft_s": ten.slo_ttft_s, "tpot_s": ten.slo_tpot_s},
            "slo_attainment": round(good / max(len(recs), 1), 4),
            "goodput_qps": round(good / result.makespan_s, 4),
        }
    n = len(result.records)
    return {
        "scenario": scenario.name,
        "seed": seed,
        "clock": result.clock,
        "requests": n,
        "completed": sum(r.t_done is not None for r in result.records),
        "offered_qps": round(result.offered_qps, 4),
        "achieved_qps": round(result.achieved_qps, 4),
        "makespan_s": round(result.makespan_s, nd),
        "emitted_tokens": result.emitted_tokens,
        "tenants": per_tenant,
        "slo_attainment": round(good_total / max(n, 1), 4),
        "goodput_qps": round(good_total / result.makespan_s, 4),
        "ttft_trajectory": _trajectory(result.records, n_windows, nd),
    }


# -- saturation sweep --------------------------------------------------------

def saturation_sweep(run_at, base_qps: float, slo_ttft_s: float, *,
                     max_doublings: int = 3, bisect_iters: int = 4,
                     log=None) -> dict:
    """Max sustainable QPS by doubling then bisection.

    ``run_at(scale)`` replays the scenario with every tenant's arrival rate
    multiplied by ``scale`` and returns the run's p99 TTFT in seconds
    (deterministic under the virtual clock, so the bracket is real, not
    noise).  Scale 1.0 is probed first; while p99 stays under
    ``slo_ttft_s`` the scale doubles (up to ``max_doublings``), then
    ``bisect_iters`` rounds of bisection tighten the good/bad bracket.
    Returns the probe list and ``max_sustainable_qps`` (largest probed QPS
    whose p99 met budget; 0.0 if even scale 1.0 failed —
    ``saturated=False`` flags a sweep that never found the wall, i.e. the
    estimate is a lower bound)."""
    probes: list[dict] = []

    def probe(scale: float) -> bool:
        p99 = float(run_at(scale))
        ok = p99 <= slo_ttft_s
        probes.append({"qps_scale": round(scale, 4),
                       "qps": round(base_qps * scale, 4),
                       "p99_ttft_s": round(p99, 6), "ok": ok})
        if log is not None:
            log(f"[saturation] scale {scale:.2f} ({base_qps * scale:.2f} "
                f"qps): p99 ttft {p99:.4f}s "
                f"({'ok' if ok else 'OVER'} vs {slo_ttft_s}s)")
        return ok

    lo, hi = 0.0, None  # lo: best passing scale; hi: smallest failing
    scale = 1.0
    for _ in range(max_doublings + 1):
        if probe(scale):
            lo = scale
            scale *= 2.0
        else:
            hi = scale
            break
    if hi is not None and lo > 0.0:
        for _ in range(bisect_iters):
            mid = (lo + hi) / 2.0
            if probe(mid):
                lo = mid
            else:
                hi = mid
    return {
        "slo_ttft_s": slo_ttft_s,
        "base_qps": round(base_qps, 4),
        "probes": probes,
        "max_sustainable_qps": round(base_qps * lo, 4),
        "max_sustainable_scale": round(lo, 4),
        # the wall was actually found (some probe failed); otherwise the
        # estimate is only a lower bound at the doubling cap
        "saturated": hi is not None,
    }


# -- schema checks -----------------------------------------------------------

_PCT_KEYS = ("mean", "p50", "max")
_PCT_TAIL_KEYS = ("mean", "p50", "p95", "p99", "max")


def _need(d: dict, keys, where: str) -> None:
    missing = [k for k in keys if k not in d]
    if missing:
        raise AssertionError(f"{where} missing fields: {missing}")


def _check_path_section(sec: dict, where: str, v: int) -> None:
    _need(sec, ("tokens", "seconds", "tok_s", "ttft_s"), where)
    _need(sec["ttft_s"], _PCT_TAIL_KEYS if v >= 4 else _PCT_KEYS,
          f"{where}.ttft_s")
    if v >= 4:
        _need(sec, ("tpot_s",), where)


def check_schema(results: dict) -> int:
    """Validate a BENCH_serving.json dict against its declared
    ``schema_version`` (2..5 supported).  Raises AssertionError naming the
    missing fields; returns the version.  This is the single source of
    truth for back-compat field checks — CI and tests import it instead of
    keeping their own lists."""
    _need(results, ("schema_version",), "results")
    v = results["schema_version"]
    if v not in (2, 3, 4, 5):
        raise AssertionError(f"unsupported schema_version {v!r}")
    _need(results, ("arch", "batch"), "results")
    mode = results.get("mode", "paths") if v >= 5 else "paths"
    if mode not in ("paths", "scenario"):
        raise AssertionError(f"unknown mode {mode!r} (schema v{v})")
    # the v2..v4 sections are preserved in EVERY mode (the back-compat
    # contract: a v5 scenario file still carries the classic comparison)
    _need(results, ("generational", "continuous", "speedup"), "results")
    _check_path_section(results["generational"], "generational", v)
    _check_path_section(results["continuous"], "continuous", v)
    if v >= 3:
        _need(results["continuous"], ("queue_wait_s",), "continuous")
        _need(results, ("prefix",), "results")
        _need(results["prefix"], ("enabled",), "prefix")
    if v >= 4:
        _need(results, ("speculative",), "results")
        _need(results["speculative"], ("enabled",), "speculative")
        if results["speculative"].get("enabled"):
            _need(results["speculative"],
                  ("spec_k", "acceptance_rate", "byte_identical",
                   "tokens_per_decode_step"), "speculative")
    if v >= 5:
        _need(results, ("seed", "mode"), "results")
    if mode == "scenario":
        _need(results, ("workload", "saturation", "request_mix"), "results")
        w = results["workload"]
        _need(w, ("scenario", "seed", "clock", "requests", "tenants",
                  "slo_attainment", "goodput_qps", "offered_qps",
                  "achieved_qps", "ttft_trajectory"), "workload")
        if not w["tenants"]:
            raise AssertionError("workload.tenants is empty")
        for name, t in w["tenants"].items():
            _need(t, ("requests", "ttft_s", "tpot_s", "queue_wait_s",
                      "slo", "slo_attainment", "goodput_qps"),
                  f"workload.tenants[{name}]")
            _need(t["ttft_s"], _PCT_TAIL_KEYS,
                  f"workload.tenants[{name}].ttft_s")
            _need(t["tpot_s"], _PCT_TAIL_KEYS,
                  f"workload.tenants[{name}].tpot_s")
            if not 0.0 <= t["slo_attainment"] <= 1.0:
                raise AssertionError(
                    f"workload.tenants[{name}].slo_attainment "
                    f"{t['slo_attainment']} outside [0, 1]")
        if not 0.0 <= w["slo_attainment"] <= 1.0:
            raise AssertionError(f"workload.slo_attainment "
                                 f"{w['slo_attainment']} outside [0, 1]")
        if results["saturation"] is not None:
            _need(results["saturation"],
                  ("probes", "max_sustainable_qps", "slo_ttft_s"),
                  "saturation")
    return v


# -- PR-over-PR diff ---------------------------------------------------------

def _walk_numeric(d, prefix=""):
    """Flatten nested dicts to {dotted.path: number} (lists indexed)."""
    out = {}
    if isinstance(d, dict):
        for k, v in d.items():
            out.update(_walk_numeric(v, f"{prefix}{k}."))
    elif isinstance(d, list):
        for i, v in enumerate(d):
            out.update(_walk_numeric(v, f"{prefix}{i}."))
    elif isinstance(d, (int, float)) and not isinstance(d, bool):
        out[prefix[:-1]] = float(d)
    return out


_DIFF_KEYS = ("tok_s", "ttft_s.p50", "ttft_s.p95", "ttft_s.p99",
              "tpot_s.p50", "tpot_s.p99", "slo_attainment", "goodput_qps",
              "max_sustainable_qps", "speedup", "acceptance_rate",
              "prefix_hit_rate")


def diff_benches(old: dict, new: dict, *, log=print) -> list[str]:
    """Print the percentile/throughput deltas between two bench files
    (suffix-matched against the interesting keys).  Informational only —
    returns the printed lines, raises nothing on regressions."""
    a, b = _walk_numeric(old), _walk_numeric(new)
    lines = []
    for path in sorted(set(a) | set(b)):
        if not any(path == k or path.endswith("." + k)
                   for k in _DIFF_KEYS):
            continue
        va, vb = a.get(path), b.get(path)
        if va is None or vb is None:
            lines.append(f"  {path}: "
                         f"{'added' if va is None else 'removed'} "
                         f"({va if vb is None else vb:g})")
        elif va != vb:
            rel = f" ({(vb - va) / abs(va):+.1%})" if va else ""
            lines.append(f"  {path}: {va:g} -> {vb:g}{rel}")
    if not lines:
        lines = ["  no tracked metric changed"]
    for ln in lines:
        log(ln)
    return lines


def _main(argv: list[str]) -> int:
    if len(argv) >= 2 and argv[0] == "check":
        with open(argv[1]) as f:
            v = check_schema(json.load(f))
        print(f"[analysis] {argv[1]}: schema v{v} ok")
        return 0
    if len(argv) >= 3 and argv[0] == "diff":
        with open(argv[1]) as f:
            old = json.load(f)
        with open(argv[2]) as f:
            new = json.load(f)
        print(f"[analysis] bench delta {argv[1]} -> {argv[2]}:")
        diff_benches(old, new)
        return 0
    print("usage: analysis.py check FILE | diff OLD NEW", file=sys.stderr)
    return 2


if __name__ == "__main__":
    sys.exit(_main(sys.argv[1:]))
