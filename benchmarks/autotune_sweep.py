"""Populate the ternary-matmul autotune cache across the config registry.

For every architecture in ``repro.configs.registry`` this sweep enumerates
the per-layer ternary matmul shapes a serving step issues
(:func:`repro.models.decode.layer_matmul_shapes`) and benchmarks every
registered kernel on each, persisting the measurements to the dispatch
cache (``$REPRO_AUTOTUNE_CACHE``, default ``~/.cache/repro/autotune.json``).
After a sweep, ``ternary_matmul(policy="auto")`` dispatches every serving
projection on measured wall-times instead of the analytical prior.

Usage::

    PYTHONPATH=src python benchmarks/autotune_sweep.py                  # smoke dims
    PYTHONPATH=src python benchmarks/autotune_sweep.py --full           # real dims
    PYTHONPATH=src python benchmarks/autotune_sweep.py --archs qwen3-0.6b \
        --batch-sizes 1 8 --reps 5

Real-dimension sweeps on CPU run the Pallas kernels in interpret mode and
can take a long time; the default therefore sweeps the structure-preserving
smoke-scale configs (``--full`` opts into real dims, intended for TPU).
"""

from __future__ import annotations

import argparse

from repro.configs.registry import ARCHS, get_smoke_config
from repro.kernels import dispatch
from repro.models.decode import layer_grouped_matmul_shapes, layer_matmul_shapes


def sweep(archs: list[str], batch_sizes: list[int], *, full: bool = False,
          dtypes: tuple[str, ...] | None = None, reps: int = 3,
          verbose: bool = True) -> dict:
    """``dtypes=None`` benchmarks each arch at its own serving activation
    dtype (``cfg.dtype``, normally bfloat16) — the dtype the cache key must
    match for serving dispatch to hit the entries.  Group size is always the
    arch's ``cfg.mu`` for the same reason.  MoE archs contribute their
    grouped expert-stack problems ``(E, C, K, N)`` alongside the dense
    triples (job key: ``e=None`` marks a dense problem)."""
    cache = dispatch.get_autotune_cache()
    jobs: set[tuple[int | None, int, int, int, str, int]] = set()
    for arch in archs:
        cfg = ARCHS[arch] if full else get_smoke_config(arch)
        for b in batch_sizes:
            for dt in (dtypes or (cfg.dtype,)):
                for (m, k, n) in layer_matmul_shapes(cfg, b):
                    jobs.add((None, m, k, n, dt, cfg.mu))
                for (e, c, k, n) in layer_grouped_matmul_shapes(cfg, b):
                    jobs.add((e, c, k, n, dt, cfg.mu))

    results = {}
    key = lambda j: tuple(x if x is not None else -1 for x in j)
    for i, (e, m, k, n, dt, mu) in enumerate(sorted(jobs, key=key)):
        timings = dispatch.autotune(m, k, n, dt, reps=reps, cache=cache,
                                    save=False, mu=mu, e=e)
        results[(e, m, k, n, dt, mu)] = timings
        if verbose and timings:
            best = min(timings, key=timings.get)
            tag = f"E{e} " if e is not None else ""
            print(f"[{i + 1}/{len(jobs)}] {tag}M{m} K{k} N{n} mu{mu} {dt}: "
                  f"best={best} ({timings[best]:.0f}us of "
                  f"{len(timings)} kernels)")
    cache.save()
    if verbose:
        print(f"cache: {len(cache)} entries -> {cache.path}")
    return results


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--archs", nargs="*", default=sorted(ARCHS),
                    choices=sorted(ARCHS))
    ap.add_argument("--batch-sizes", nargs="*", type=int, default=[1, 8])
    ap.add_argument("--dtypes", nargs="*", default=None,
                    choices=["float32", "bfloat16", "float16", "int8"],
                    help="override per-arch serving dtype (default: each "
                         "arch's cfg.dtype)")
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument("--full", action="store_true",
                    help="sweep real model dims (slow on CPU) instead of "
                         "smoke-scale configs")
    args = ap.parse_args(argv)
    sweep(args.archs, args.batch_sizes, full=args.full,
          dtypes=tuple(args.dtypes) if args.dtypes else None, reps=args.reps)


if __name__ == "__main__":
    main()
