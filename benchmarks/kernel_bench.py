"""Kernel microbenchmarks: wall time of every registered ternary matmul path,
dense AND grouped (batched-expert), with a committed JSON trajectory.

Kernels are enumerated and executed through the unified dispatch layer
(``repro.kernels.dispatch``) so this benchmark measures exactly what
``ternary_matmul(policy="fixed:<name>")`` / ``grouped_ternary_matmul`` run,
and the timings are written into the autotune cache — running the benchmark
*is* autotuning for its shapes.  CPU interpret-mode numbers for the Pallas
kernels are *functional* timings (the TPU target numbers come from the
roofline analysis); the ``ref``/``grouped_ref`` XLA paths are what the
serving stack executes on CPU and their timings are real.

The grouped section benches the phi3.5-moe expert-stack operating points
(decode: per-expert capacity from a B=4 batch; prefill: capacity of one
admission chunk) against the **eager full-dequant einsum baseline** — the
pre-dispatch MoE path that unpacked ``[E, d_out, d_in]`` dense weights every
forward.  ``speedup_vs_einsum`` is the trajectory headline: it must stay
> 1 at the decode point (CI smoke asserts this).

Writes ``BENCH_kernels.json``::

  {"schema_version": 1, "backend": ..., "smoke": true, "arch": ...,
   "dense": {"shape": {"M","K","N"}, "kernels": {name: us}, "best": name},
   "dense_int8": same shape, W1.58A8 (pre-quantized int8 activations),
   "grouped": [{"op_point": "decode"|"prefill"|"decode_a8",
                "shape": {"E","C","K","N"}, "kernels": {name: us},
                "best": name, "best_us": us, "einsum_baseline_us": us,
                "speedup_vs_einsum": ratio}, ...],
   "a8_bytes": static bytes-moved at the decode point (bf16 dense vs the
               grouped_w2a8 / grouped_tl2 packed streams + bits/weight) —
               the non-flaky bandwidth gate CI asserts on}

Run:  PYTHONPATH=src python benchmarks/kernel_bench.py --smoke
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import encoding
from repro.kernels import dispatch

#: serving batch / admission chunk defining the two MoE operating points
MOE_ARCH = "phi3.5-moe-42b-a6.6b"
DECODE_BATCH = 4
PREFILL_CHUNK = 16


def _time_fn(fn, *args, reps: int = 3) -> float:
    jax.block_until_ready(fn(*args))  # compile + warm
    t0 = time.perf_counter()
    for _ in range(reps):
        y = fn(*args)
    jax.block_until_ready(y)
    return (time.perf_counter() - t0) / reps * 1e6


def _einsum_baseline_us(e: int, c: int, k: int, n: int, dtype: str,
                        reps: int = 3, seed: int = 0) -> float:
    """The pre-dispatch MoE path: eagerly unpack the WHOLE expert stack to a
    dense ``[E, N, K]`` tensor inside the jitted step, then one einsum."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(e, c, k)), dtype)
    packed = encoding.pack_base3(
        jnp.asarray(rng.integers(-1, 2, size=(e, n, k)), jnp.int8))
    scale = jnp.ones((e,), jnp.float32)

    @jax.jit
    def eager(t, pk):
        w_t = encoding.unpack_base3(pk, k)          # [E, N, K] every call
        y = jnp.einsum("ecd,efd->ecf", t, w_t.astype(t.dtype))
        return y * scale[:, None, None].astype(y.dtype)

    return _time_fn(eager, x, packed, reps=reps)


def bench_dense(cache, *, m: int = 8, n_out: int = 512, k_in: int = 1024,
                reps: int = 3, act: str = "float32") -> dict:
    timings = dispatch.autotune(m, k_in, n_out, act, reps=reps,
                                cache=cache, save=False)
    return {"shape": {"M": m, "K": k_in, "N": n_out},
            "kernels": {name: round(us, 2) for name, us in timings.items()},
            "best": min(timings, key=timings.get)}


def a8_bytes_moved(*, e: int, c: int, k: int, n: int, mu: int = 3) -> dict:
    """Static bytes-moved comparison at a grouped decode operating point:
    the W1.58A8 packed paths versus streaming a dense bf16 expert stack.
    Decode is bandwidth-bound (every expert's weights stream every step), so
    bytes moved per step is the property CI gates — unlike wall-clock on a
    shared runner, it cannot flake."""
    per = {
        "bf16_dense": 2 * k * n,
        "grouped_w2a8": int(dispatch.get_kernel("grouped_w2a8")
                            .weight_bytes(k, n, mu)),
        # the TL2 packed artifact (5 base-9 digit pairs per uint16 =
        # 1.6 b/w) — taken from the Pallas spec: the grouped_tl2 XLA ref
        # deliberately charges its onehot decode in the cost model, which
        # is an interpret-mode dispatch-ordering device, not HBM traffic
        "tl2_packed": int(dispatch.get_kernel("tl2").weight_bytes(k, n, mu)),
    }
    return {
        "shape": {"E": e, "C": c, "K": k, "N": n},
        "bytes_per_expert_step": per,
        "bytes_per_step": {nm: b * e for nm, b in per.items()},
        "bits_per_weight": {nm: round(8 * b / (k * n), 3)
                            for nm, b in per.items()},
    }


def bench_grouped(cache, *, smoke: bool, reps: int = 3) -> list[dict]:
    from repro.configs.registry import get_config, get_smoke_config
    from repro.models.decode import layer_grouped_matmul_shapes

    cfg = get_smoke_config(MOE_ARCH) if smoke else get_config(MOE_ARCH)
    decode_shapes = layer_grouped_matmul_shapes(cfg, DECODE_BATCH)
    points = [("decode", cfg.dtype, decode_shapes),
              ("prefill", cfg.dtype,
               layer_grouped_matmul_shapes(cfg, 1, seq_len=PREFILL_CHUNK)),
              # the W1.58A8 decode path: per-expert int8 activations through
              # the same expert stacks (routes grouped_w2a8/grouped_tl2)
              ("decode_a8", "int8", decode_shapes)]
    out = []
    for op_point, act, shapes in points:
        for (e, c, k, n) in shapes:
            timings = dispatch.autotune(c, k, n, act, reps=reps,
                                        cache=cache, save=False,
                                        mu=cfg.mu, e=e)
            best = min(timings, key=timings.get)
            base = _einsum_baseline_us(e, c, k, n, cfg.dtype, reps=reps)
            out.append({
                "op_point": op_point,
                "shape": {"E": e, "C": c, "K": k, "N": n},
                "kernels": {nm: round(us, 2) for nm, us in timings.items()},
                "best": best, "best_us": round(timings[best], 2),
                "einsum_baseline_us": round(base, 2),
                "speedup_vs_einsum": round(base / timings[best], 3),
            })
    return out


def collect(*, smoke: bool = True, reps: int = 3) -> dict:
    from repro.configs.registry import get_config, get_smoke_config
    from repro.models.decode import layer_grouped_matmul_shapes

    cache = dispatch.get_autotune_cache()
    cfg = get_smoke_config(MOE_ARCH) if smoke else get_config(MOE_ARCH)
    e, c, k, n = layer_grouped_matmul_shapes(cfg, DECODE_BATCH)[0]
    results = {
        "schema_version": 1,
        "backend": jax.default_backend(),
        "smoke": bool(smoke),
        "arch": MOE_ARCH,
        "dense": bench_dense(cache, reps=reps),
        # same dense problem with pre-quantized int8 activations (W1.58A8)
        "dense_int8": bench_dense(cache, reps=reps, act="int8"),
        "grouped": bench_grouped(cache, smoke=smoke, reps=reps),
        "a8_bytes": a8_bytes_moved(e=e, c=c, k=k, n=n, mu=cfg.mu),
    }
    cache.save()  # bench timings double as autotune measurements
    return results


def run():
    """CSV-row adapter for ``benchmarks/run.py``."""
    backend = jax.default_backend()
    results = collect(smoke=True)
    rows = []
    d = results["dense"]
    B, K, O = d["shape"]["M"], d["shape"]["K"], d["shape"]["N"]
    for name, us in sorted(d["kernels"].items(), key=lambda kv: kv[1]):
        spec = dispatch.get_kernel(name)
        tag = "pallas interpret" if (spec.pallas and backend != "tpu") else "xla"
        rows.append((f"kernel_{name}", us, f"B{B}xO{O}xN{K} via dispatch ({tag})"))

    auto = dispatch.select_kernel(B, K, O, "float32", policy="auto")
    rows.append(("dispatch_auto_choice", 0.0,
                 f"cache best={d['best']}; policy=auto -> {auto.name}"))

    for g in results["grouped"]:
        s = g["shape"]
        rows.append((f"grouped_{g['op_point']}_E{s['E']}C{s['C']}K{s['K']}N{s['N']}",
                     g["best_us"],
                     f"best={g['best']}; {g['speedup_vs_einsum']}x vs "
                     f"full-dequant einsum ({g['einsum_baseline_us']}us)"))

    # bandwidth story: bytes per weight streamed per matmul
    bf16_bytes = O * K * 2
    packed_bytes = O * -(-K // encoding.TRITS_PER_BYTE)
    rows.append(("weight_bytes_ratio_bf16_over_packed",
                 0.0, f"{bf16_bytes / packed_bytes:.1f}x fewer HBM bytes "
                      f"({packed_bytes} vs {bf16_bytes})"))
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="smoke-scale MoE dims (CI mode)")
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument("--out", default="BENCH_kernels.json")
    args = ap.parse_args()
    results = collect(smoke=args.smoke, reps=args.reps)
    for g in results["grouped"]:
        s = g["shape"]
        print(f"[kernel_bench] grouped {g['op_point']:>7} "
              f"E{s['E']} C{s['C']} K{s['K']} N{s['N']}: best={g['best']} "
              f"{g['best_us']:.0f}us vs einsum {g['einsum_baseline_us']:.0f}us "
              f"-> {g['speedup_vs_einsum']:.2f}x")
    with open(args.out, "w") as f:
        json.dump(results, f, indent=2)
        f.write("\n")
    print(f"[kernel_bench] wrote {os.path.abspath(args.out)}")


if __name__ == "__main__":
    main()
