"""Kernel microbenchmarks: wall time of every registered ternary matmul path.

Kernels are enumerated and executed through the unified dispatch layer
(``repro.kernels.dispatch``) so this benchmark measures exactly what
``ternary_matmul(policy="fixed:<name>")`` runs, and the timings are written
into the autotune cache — running the benchmark *is* autotuning for its
shape.  CPU interpret-mode numbers for the Pallas kernels are *functional*
timings (the TPU target numbers come from the roofline analysis); the ``ref``
XLA path is the one the serving stack executes on CPU and its timing is real.
"""

from __future__ import annotations

import jax

from repro.core import encoding
from repro.kernels import dispatch


def run():
    B, O, N = 8, 512, 1024
    backend = jax.default_backend()

    rows = []
    timings = dispatch.autotune(B, N, O, "float32", reps=3,
                                cache=dispatch.get_autotune_cache())
    for name, us in sorted(timings.items(), key=lambda kv: kv[1]):
        spec = dispatch.get_kernel(name)
        tag = "pallas interpret" if (spec.pallas and backend != "tpu") else "xla"
        rows.append((f"kernel_{name}", us, f"B{B}xO{O}xN{N} via dispatch ({tag})"))

    best = dispatch.get_autotune_cache().best(B, N, O, "float32", backend)
    auto = dispatch.select_kernel(B, N, O, "float32", policy="auto")
    rows.append(("dispatch_auto_choice", 0.0,
                 f"cache best={best}; policy=auto -> {auto.name}"))

    # bandwidth story: bytes per weight streamed per matmul
    bf16_bytes = O * N * 2
    packed_bytes = O * -(-N // encoding.TRITS_PER_BYTE)
    rows.append(("weight_bytes_ratio_bf16_over_packed",
                 0.0, f"{bf16_bytes / packed_bytes:.1f}x fewer HBM bytes "
                      f"({packed_bytes} vs {bf16_bytes})"))
    return rows
