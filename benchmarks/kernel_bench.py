"""Kernel microbenchmarks: wall time of the three ternary matmul paths.

CPU interpret-mode numbers are *functional* timings (the TPU target numbers
come from the roofline analysis); the XLA packed path is the one the serving
stack actually executes and its timing here is real.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import encoding
from repro.core.quantization import ternarize
from repro.kernels.dequant_matmul import packed_matmul
from repro.kernels.lut_matmul import lut_matmul
from repro.kernels.signflip_matmul import signflip_matmul


def _time(fn, *args, reps=3):
    y = fn(*args)
    jax.block_until_ready(y)
    t0 = time.perf_counter()
    for _ in range(reps):
        y = fn(*args)
    jax.block_until_ready(y)
    return (time.perf_counter() - t0) / reps * 1e6  # us


def run():
    rng = np.random.default_rng(0)
    B, O, N = 8, 512, 1024
    x = jnp.asarray(rng.normal(size=(B, N)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(O, N)), jnp.float32)
    w_t, scale = ternarize(w)
    packed = encoding.pack_base3(w_t)
    keys = encoding.encode_weight_matrix(w_t, 3)
    xg = jnp.pad(x, ((0, 0), (0, keys.shape[1] * 3 - N)))

    rows = []

    def xla_packed(x, p):
        wt = encoding.unpack_base3(p, N)
        return x @ wt.astype(x.dtype).T

    rows.append(("kernel_xla_packed_dequant",
                 _time(jax.jit(xla_packed), x, packed, reps=10),
                 f"B{B}xO{O}xN{N}, 1.6b/w weight stream (serving path)"))
    rows.append(("kernel_pallas_signflip_interp",
                 _time(lambda: signflip_matmul(x, w_t, block_b=8, block_o=128,
                                               block_n=256)),
                 "interpret=True functional timing"))
    rows.append(("kernel_pallas_packed_interp",
                 _time(lambda: packed_matmul(x, packed, N, block_b=8,
                                             block_o=128, block_n=320)),
                 "interpret=True functional timing"))
    rows.append(("kernel_pallas_lut_mu3_interp",
                 _time(lambda: lut_matmul(xg, keys, 3, block_b=8, block_o=128,
                                          block_g=64)),
                 "interpret=True functional timing"))

    # bandwidth story: bytes per weight streamed per matmul
    bf16_bytes = O * N * 2
    packed_bytes = packed.size
    rows.append(("weight_bytes_ratio_bf16_over_packed",
                 0.0, f"{bf16_bytes / packed_bytes:.1f}x fewer HBM bytes "
                      f"({packed_bytes} vs {bf16_bytes})"))
    return rows
