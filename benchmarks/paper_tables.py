"""One benchmark per paper table/figure.  Each returns (rows, derived) where
rows are printable dicts and derived is a short claim-check string."""

from __future__ import annotations

import numpy as np

from repro.core import cost_model as cm
from repro.core import dse, encoding
from repro.core import netlist as nl
from repro.core.generator import LUTCoreConfig, generate


def fig5_submodule_breakdown():
    """Fig. 5: area/power breakdown of 32×32 FP16 across group sizes."""
    rows = []
    for mu in (1, 2, 3, 4, 5):
        b = cm.breakdown(mu, 32, 32, "fp16")
        pwr = cm.power_proxy_breakdown(mu, 32, 32, "fp16")
        rows.append({"mu": mu, **{k: round(v, 1) for k, v in b.items()},
                     "power_proxy": round(pwr["total"], 1)})
    areas = {r["mu"]: r["total"] for r in rows}
    pwrs = {r["mu"]: r["power_proxy"] for r in rows}
    derived = (f"argmin_area_mu={min(areas, key=areas.get)} (paper 3); "
               f"argmin_power_mu={min(pwrs, key=pwrs.get)} (paper 3)")
    return rows, derived


def fig6_model_validation():
    """Fig. 6: analytical model vs 'synthesis' across the Table III grid.

    Without EDA tools the synthesis stand-in is the exact constructive
    netlist ('exact' mode — real unit counts from the generated DAG); the
    paper's curve-fit model must track it closely across all design points.
    """
    rows, ratios = [], {"fp16": [], "int8": []}
    for dt in ("int8", "fp16"):
        for t in (8, 32, 64, 96):
            for mu in (1, 2, 3, 4, 5):
                if t % mu:
                    continue
                a_fit = cm.lut_core_area_mm2(mu, t, t, dt, mode="paper")
                a_exact = cm.lut_core_area_mm2(mu, t, t, dt, mode="exact")
                rows.append({"dtype": dt, "tile": t, "mu": mu,
                             "model_mm2": round(a_fit, 5),
                             "exact_netlist_mm2": round(a_exact, 5)})
                ratios[dt].append(a_fit / a_exact)
    r_all = np.asarray(ratios["fp16"] + ratios["int8"])
    derived = (f"model/exact ratio mean={r_all.mean():.3f} "
               f"max_dev={np.abs(r_all - 1).max():.3f} "
               f"(model tracks the generated netlist)")
    return rows, derived


def table4_baseline_comparison():
    """Table IV: 32×32 FP16 — dequant / sign-flip / LUT areas."""
    c = cm.get_coeffs("fp16")
    lut = cm.area_gates_lut(3, 32, 32, c)
    deq = cm.area_gates_dequant_baseline(32, 32, c)
    sf = cm.area_gates_signflip_baseline(32, 32, c)
    rows = [
        {"design": "full-width multiplication baseline",
         "area_mm2": round(cm.area_mm2(deq, c), 4),
         "relative": round(deq / lut, 3), "paper": 2.23},
        {"design": "sign-flip multiplication baseline",
         "area_mm2": round(cm.area_mm2(sf, c), 4),
         "relative": round(sf / lut, 3), "paper": 1.64},
        {"design": "this work (optimal mu=3)",
         "area_mm2": round(cm.area_mm2(lut, c), 4),
         "relative": 1.0, "paper": 1.0},
    ]
    derived = (f"dequant={deq/lut:.3f}x (paper 2.23x), "
               f"signflip={sf/lut:.3f}x (paper 1.64x), "
               f"abs={cm.area_mm2(lut, c):.4f}mm2 (paper 0.120)")
    return rows, derived


def fig7_tile_scaling():
    """Fig. 7: area efficiency vs square tile size (FP16, optimal mu).

    Uses the paper's tile grid (8, 32, 64, 96).  Off-grid sizes whose side is
    not divisible by mu=3 (64, 128) show a local dip from the forced
    suboptimal group size — a generator constraint worth knowing about, noted
    in EXPERIMENTS.md.
    """
    rows = []
    for t in (8, 32, 64, 96):
        mus = [m for m in (1, 2, 3, 4, 5) if t % m == 0]
        mu = min(mus, key=lambda m: cm.area_gates_lut(m, t, t, cm.get_coeffs("fp16")))
        rows.append({"tile": t, "mu_opt": mu,
                     "area_mm2": round(cm.lut_core_area_mm2(mu, t, t, "fp16"), 4),
                     "tops_per_mm2": round(cm.tops_per_mm2(mu, t, t, "fp16"), 2)})
    effs = [r["tops_per_mm2"] for r in rows]
    derived = ("monotone=" + str(all(b >= a for a, b in zip(effs, effs[1:]))) +
               f" ({effs[0]} -> {effs[-1]} TOPS/mm2, paper grid 8/32/64/96)")
    return rows, derived


def fig8_tile_geometry():
    """Fig. 8: non-square tiles at fixed throughput, both dtypes.

    The dtype-dependent asymmetry is checked on mirrored aspect pairs
    (n×m vs m×n): FP16 must prefer wide (K > L·mu), INT8 tall (L·mu > K).
    """
    rows, verdicts = [], []
    for dt in ("fp16", "int8"):
        recs = dse.geometry_sweep(1024, dt)
        best = max(recs, key=lambda r: r["delta_vs_square"])
        rows += [{"dtype": dt, **{k: (round(v, 4) if isinstance(v, float) else v)
                                  for k, v in r.items()}}
                 for r in recs if r["n"] in (8, 16, 32, 64, 128) or r is best]
        by_nm = {(r["n"], r["m"]): r["area_mm2"] for r in recs}
        tall = by_nm.get((64, 16))   # L·mu > K direction
        wide = by_nm.get((16, 64))   # K > L·mu direction
        pref = "L*mu>K" if (tall is not None and wide is not None and tall < wide) \
            else "K>L*mu"
        verdicts.append(f"{dt}: best {best['n']}x{best['m']} mu={best['mu']} "
                        f"Δ={best['delta_vs_square']*100:.1f}%; mirrored-pair "
                        f"preference {pref}")
    derived = "; ".join(verdicts) + "  (paper: FP16 K>L*mu, INT8 L*mu>K)"
    return rows, derived


def table5_sota_comparison():
    """Table V: reconfigure published designs at matched throughput."""
    rows = []
    for r in dse.sota_comparison():
        rows.append({k: (round(v, 4) if isinstance(v, float) else v)
                     for k, v in r.items()})
    by = {r["work"]: r for r in rows}
    derived = (f"tenet model prediction={by['tenet']['model_prediction']:.3f}x "
               f"(paper 1.004x); tellme_v2={by['tellme_v2']['model_prediction']:.3f}x "
               f"(paper 1.22x in FPGA LUTs); "
               f"tenet published-area decrease={by['tenet'].get('area_decrease_vs_published', 0):.1f}x "
               f"(paper 7.9x)")
    return rows, derived


def table1_encoding_density():
    """§III-D: encoding density vs information-theoretic limit."""
    rows = []
    for mu in (1, 2, 3, 4, 5):
        rows.append({"mu": mu, "key_bits": encoding.key_bits(mu),
                     "paper_bits": encoding.key_bits_paper(mu),
                     "bits_per_weight": round(encoding.bits_per_weight(mu), 4)})
    derived = (f"mu=5: {encoding.bits_per_weight(5):.3f} b/w "
               f"(paper 1.6; limit {np.log2(3):.3f}); vs 2-bit saving "
               f"{(2 - encoding.bits_per_weight(5)) / 2 * 100:.0f}% (paper 20%)")
    return rows, derived


def eq2_adder_reduction():
    """§III-B: adder-count optimizations (Eq. 2-4 + constructive DAG)."""
    rows = []
    for mu in (2, 3, 4, 5):
        rows.append({"mu": mu, "naive": nl.naive_adders(mu),
                     "symmetry": nl.symmetry_adders(mu),
                     "eq2_bound": nl.bound_adders(mu),
                     "constructive": nl.constructive_adders(mu),
                     "reduction_pct": round(nl.adder_reduction_vs_naive(mu) * 100, 2)})
    derived = (f"mu=4 reduction={nl.adder_reduction_vs_naive(4)*100:.2f}% "
               f"(paper 81.89%); constructive DAG beats Eq.2 bound for mu>=4")
    return rows, derived


def generator_frontier():
    """Beyond-paper: efficiency frontier emitted by the generator."""
    rows = []
    for dt in ("fp16", "int8"):
        for rec in dse.frontier(dt):
            rows.append({"dtype": dt, **rec,
                         "area_mm2": round(rec["area_mm2"], 4),
                         "tops_per_mm2": round(rec["tops_per_mm2"], 2)})
    d = generate(LUTCoreConfig(mu=3, L=32, K=32, act_dtype="fp16"))
    derived = f"example core: {d.tops_per_mm2:.1f} TOPS/mm2 @ {d.area_mm2:.4f} mm2"
    return rows, derived


ALL = {
    "table1_encoding_density": table1_encoding_density,
    "eq2_adder_reduction": eq2_adder_reduction,
    "fig5_submodule_breakdown": fig5_submodule_breakdown,
    "fig6_model_validation": fig6_model_validation,
    "table4_baseline_comparison": table4_baseline_comparison,
    "fig7_tile_scaling": fig7_tile_scaling,
    "fig8_tile_geometry": fig8_tile_geometry,
    "table5_sota_comparison": table5_sota_comparison,
    "generator_frontier": generator_frontier,
}
