"""Benchmark suite: one function per paper table/figure + kernel micro-
benchmarks + the dry-run roofline summary.

Prints ``name,us_per_call,derived`` CSV lines (one per benchmark) followed by
the detailed rows of each table.
"""

from __future__ import annotations

import glob
import json
import os
import time


def _roofline_summary():
    """Summarize experiments/dryrun (if the sweep has been run)."""
    pat = os.path.join(os.path.dirname(__file__), "..", "experiments",
                       "dryrun", "*__16x16.json")
    recs = []
    for f in sorted(glob.glob(pat)):
        with open(f) as fh:
            r = json.load(fh)
        if r.get("ok"):
            recs.append(r)
    if not recs:
        return [], "dry-run not yet executed (python -m repro.launch.dryrun)"
    rows = [{"arch": r["arch"], "shape": r["shape"],
             "bottleneck": r["roofline"]["bottleneck"],
             "step_s": round(r["roofline"]["step_time_s"], 4),
             "model_flops_ratio": round(r.get("model_flops_ratio", 0), 3)}
            for r in recs]
    bn = [r["bottleneck"] for r in rows]
    derived = (f"{len(recs)} cells: {bn.count('memory')} memory-bound, "
               f"{bn.count('collective')} collective-bound, "
               f"{bn.count('compute')} compute-bound")
    return rows, derived


def main() -> None:
    from benchmarks import kernel_bench, paper_tables

    all_rows = {}
    print("name,us_per_call,derived")
    for name, fn in paper_tables.ALL.items():
        t0 = time.perf_counter()
        rows, derived = fn()
        us = (time.perf_counter() - t0) * 1e6
        print(f"{name},{us:.0f},{derived}")
        all_rows[name] = rows
    for name, us, derived in kernel_bench.run():
        print(f"{name},{us:.0f},{derived}")
    rows, derived = _roofline_summary()
    print(f"dryrun_roofline_summary,0,{derived}")
    all_rows["dryrun_roofline_summary"] = rows

    print("\n=== detailed rows ===")
    for name, rows in all_rows.items():
        print(f"\n-- {name} --")
        for r in rows:
            print("  " + ", ".join(f"{k}={v}" for k, v in r.items()))


if __name__ == "__main__":
    main()
