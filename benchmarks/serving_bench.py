"""Serving throughput: continuous-batching scheduler vs generational batching
on a skewed workload — the case where generational batching collapses
(every batch turns over at the pace of its slowest request, so a few long
requests leave most slots idle most of the time).

Bitnet.cpp and TENET report end-to-end ternary decode tok/s as the headline
metric; this benchmark seeds the same trajectory for this repo.  Both paths
run the identical packed-ternary model through the identical jitted
decode_step — only the batching discipline differs — so the ratio isolates
scheduling, not kernels.

The workload is skewed along two axes: token budgets (many short + few long
generations: generational idle-slot collapse) and prompt lengths (every
``--long-prompt-every``-th request carries a ``--long-prompt-len`` prompt:
admission latency).  Besides tok/s, the bench records per-request
**time-to-first-token** — continuous admission is chunked (fixed-size
prefill chunks, one compiled trace) and budgeted (``--admission-budget``
chunks per scheduler step), so co-batched requests keep decoding while a
long prompt is admitted and their TTFT stays bounded.

With ``--prefix-cache`` the bench additionally runs the **shared-prefix
workload** — N requests sharing a long system prompt, mixed with unique
cold prompts, the traffic shape prefix caching exists for (cf. the
``precise-prefix-cache-aware`` scenario in llm-d-benchmark) — twice: a cold
engine with no store (recompute-from-scratch baseline) and a warm engine
whose ``PrefixBlockStore`` was pre-populated by a full warmup pass.  It
reports the block ``prefix_hit_rate`` of the measured warm pass, TTFT split
by shared vs cold requests, the warm/cold shared-TTFT improvement, and the
scheduler's per-request queue-wait summary (the fairness cost of
cache-affinity admission reordering, measurable next to the TTFT it buys).

Writes ``BENCH_serving.json`` (schema below) for CI to surface in PRs:

  {"schema_version": 3, "arch": ..., "batch": ..., "workload": {...},
   "prefill_chunk": C, "admission_budget": k, "mesh": "1x8" | null,
   "generational": {"tokens": N, "seconds": s, "tok_s": r, "decode_steps": d,
                    "ttft_s": {"mean": m, "p50": p, "max": M}},
   "continuous":   {... same keys, plus "admission_steps"/"sched_steps"
                    and "queue_wait_s" mean/p50/max ...},
   "speedup": continuous.tok_s / generational.tok_s,
   "ttft_ratio": continuous.ttft_s.max / generational.ttft_s.max,
   "prefix": {"enabled": bool, ...with --prefix-cache:
              "workload": {...}, "cold": {...}, "warm": {...},
              "prefix_hit_rate": h, "ttft_improvement":
              cold.shared_ttft_s.mean / warm.shared_ttft_s.mean}}

Schema v3 is v2 plus the ``prefix`` section and the continuous path's
``queue_wait_s`` — every v2 field is unchanged, so v2-era consumers (and
the CI field-presence check, which accepts both) keep working on old files.

``decode_steps`` counts steps that ran a decode; the continuous path's
admission-only steps (prompts still prefilling, nothing live to decode) are
reported separately as ``admission_steps``.  ``--mesh DxM`` runs both paths
on a sharded engine (TP on model, MoE EP on data) over forced host devices.

Run:  PYTHONPATH=src python benchmarks/serving_bench.py --smoke
      (CPU-friendly reduced config; full mode uses the registry smoke config
      unreduced).  Compile time is excluded via a warmup pass; the chunked
      admission path compiles one trace per chunk size regardless of the
      prompt-length mix.
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax

from repro.configs.registry import get_smoke_config
from repro.models.decode import quantize_for_serving
from repro.models.model import init_params
from repro.serving.engine import DecodeEngine, Request
from repro.serving.scheduler import ContinuousScheduler


def make_requests(n: int, short_new: int, long_new: int, long_every: int,
                  prompt_len: int, long_prompt_len: int,
                  long_prompt_every: int, vocab: int) -> list[Request]:
    """Doubly skewed workload: every ``long_every``-th request generates
    ``long_new`` tokens (vs ``short_new``), and every
    ``long_prompt_every``-th request carries a ``long_prompt_len`` prompt
    (vs ``prompt_len``) — the admission-latency case."""
    reqs = []
    for i in range(n):
        new = long_new if i % long_every == long_every - 1 else short_new
        plen = long_prompt_len if i % long_prompt_every == long_prompt_every - 1 \
            else prompt_len
        prompt = [2 + ((7 * i + j) % (vocab - 3)) for j in range(plen)]
        reqs.append(Request(prompt=prompt, max_new_tokens=new))
    return reqs


def make_shared_prefix_requests(n: int, prefix_len: int, suffix_len: int,
                                cold_every: int, cold_prompt_len: int,
                                new_tokens: int, vocab: int,
                                salt: int = 0) -> list[Request]:
    """Prefix-cache traffic shape: most requests share one long system
    prompt (plus a short unique suffix), every ``cold_every``-th request is
    a unique cold prompt.  ``salt`` varies the *unique* parts between runs
    so cold prompts never accidentally warm-hit across passes; the shared
    prefix is deliberately salt-independent."""
    shared = [2 + ((11 * j) % (vocab - 3)) for j in range(prefix_len)]
    reqs = []
    for i in range(n):
        cold = cold_every > 0 and i % cold_every == cold_every - 1
        if cold:
            prompt = [2 + ((5 * (i + 131 * salt) + 3 * j) % (vocab - 3))
                      for j in range(cold_prompt_len)]
        else:
            prompt = shared + [2 + ((7 * (i + 131 * salt) + j) % (vocab - 3))
                               for j in range(suffix_len)]
        r = Request(prompt=prompt, max_new_tokens=new_tokens)
        r.shared = not cold  # bench-side tag for the TTFT split
        reqs.append(r)
    return reqs


def _ttft_summary(vals: list[float]) -> dict:
    vals = sorted(vals)
    return {"mean": round(sum(vals) / len(vals), 4),
            "p50": round(vals[len(vals) // 2], 4),
            "max": round(vals[-1], 4)}


def run_shared_prefix(engine: DecodeEngine, reqs: list[Request],
                      admission_budget: int | None) -> dict:
    """One pass of the shared-prefix workload with per-request TTFT split
    by shared vs cold, plus the scheduler queue-wait summary."""
    first_tok: dict[int, float] = {}

    def stamp(req, tok):
        first_tok.setdefault(id(req), time.perf_counter())

    for r in reqs:
        r.on_token = stamp
    sched = ContinuousScheduler(engine, admission_budget=admission_budget)
    for r in reqs:
        sched.submit(r)
    t0 = time.perf_counter()
    sched.run(max_steps=100_000)
    dt = time.perf_counter() - t0
    ttft = {id(r): first_tok[id(r)] - t0 for r in reqs}
    assert len(ttft) == len(reqs), "a request never emitted a first token"
    return {"tokens": sum(len(r.out) for r in reqs),
            "seconds": round(dt, 4),
            "ttft_s": _ttft_summary(list(ttft.values())),
            "shared_ttft_s": _ttft_summary(
                [ttft[id(r)] for r in reqs if r.shared]),
            "cold_ttft_s": _ttft_summary(
                [ttft[id(r)] for r in reqs if not r.shared]),
            "prefill_chunks": sched.stats.prefill_chunks,
            "affinity_reorders": sched.stats.affinity_reorders,
            "queue_wait_s": {k: round(v, 4) for k, v in
                             sched.stats.queue_wait_summary().items()}}


def bench_prefix(args, cfg, served, mesh, budget) -> dict:
    """Shared-prefix workload, cold vs warm: a no-store engine (recompute
    baseline) vs a prefix-cache engine whose store was populated by a full
    warmup pass.  The measured warm pass's block hit rate and shared-request
    TTFT improvement are the headline numbers."""
    from repro.serving.prefix_cache import PrefixStoreStats

    max_len = max(args.shared_prefix_len + args.shared_suffix_len,
                  args.cold_prompt_len) + args.shared_new + 1

    def mk(salt):
        return make_shared_prefix_requests(
            args.shared_requests, args.shared_prefix_len,
            args.shared_suffix_len, args.cold_every, args.cold_prompt_len,
            args.shared_new, cfg.vocab_size, salt=salt)

    def engine(prefix_cache):
        return DecodeEngine(served, cfg, batch_size=args.batch,
                            max_len=max_len, matmul_policy=args.policy,
                            prefill_chunk=args.prefill_chunk, mesh=mesh,
                            prefix_cache=prefix_cache,
                            prefix_cache_mb=args.prefix_cache_mb)

    e_cold = engine(False)
    run_shared_prefix(e_cold, mk(0), budget)  # warmup: compile
    cold = run_shared_prefix(e_cold, mk(1), budget)

    e_warm = engine(True)
    run_shared_prefix(e_warm, mk(2), budget)  # warmup: compile + publish
    e_warm.prefix_store.stats = PrefixStoreStats()  # measure one pass only
    warm = run_shared_prefix(e_warm, mk(3), budget)
    st = e_warm.prefix_store.stats

    out = {"enabled": True,
           "workload": {"requests": args.shared_requests,
                        "shared_prefix_len": args.shared_prefix_len,
                        "shared_suffix_len": args.shared_suffix_len,
                        "cold_every": args.cold_every,
                        "cold_prompt_len": args.cold_prompt_len,
                        "shared_new": args.shared_new},
           "cold": cold, "warm": warm,
           "prefix_hit_rate": round(st.hit_rate, 4),
           "hit_blocks": st.hit_blocks, "miss_blocks": st.miss_blocks,
           "reused_tokens": st.reused_tokens,
           "ttft_improvement": round(
               cold["shared_ttft_s"]["mean"]
               / max(warm["shared_ttft_s"]["mean"], 1e-9), 3)}
    print(f"[serving_bench] shared-prefix cold: shared ttft mean "
          f"{cold['shared_ttft_s']['mean']:.3f}s, cold-req mean "
          f"{cold['cold_ttft_s']['mean']:.3f}s")
    print(f"[serving_bench] shared-prefix warm: shared ttft mean "
          f"{warm['shared_ttft_s']['mean']:.3f}s, hit rate "
          f"{st.hit_rate:.0%} ({st.hit_blocks}/{st.lookups} blocks, "
          f"{st.reused_tokens} tokens spliced), ttft improvement "
          f"{out['ttft_improvement']:.2f}x")
    return out


def run_generational(engine: DecodeEngine, reqs: list[Request]) -> dict:
    """Seed baseline: batches of B run to the slowest request, sequentially."""
    steps = 0
    for i in range(0, len(reqs), engine.B):
        chunk = reqs[i:i + engine.B]
        engine.run(chunk)
        steps += max(len(r.out) for r in chunk)
    return {"decode_steps": steps}


def run_continuous(engine: DecodeEngine, reqs: list[Request],
                   admission_budget: int | None = None) -> dict:
    sched = ContinuousScheduler(engine, admission_budget=admission_budget)
    for r in reqs:
        sched.submit(r)
    sched.run(max_steps=100_000)
    # decode_steps counts steps that ran a decode; admission-only steps
    # (all slots still prefilling) are tallied separately so tok/step stays
    # an honest decode metric
    return {"decode_steps": sched.stats.decode_steps,
            "admission_steps": sched.stats.admission_steps,
            "sched_steps": sched.stats.steps,
            "queue_wait_s": {k: round(v, 4) for k, v in
                             sched.stats.queue_wait_summary().items()}}


def bench(path_fn, engine, mk_reqs) -> dict:
    path_fn(engine, mk_reqs())  # warmup: compile prefill chunks + decode step
    reqs = mk_reqs()
    first_tok: dict[int, float] = {}

    def stamp(req, tok):
        first_tok.setdefault(id(req), time.perf_counter())

    for r in reqs:
        r.on_token = stamp
    t0 = time.perf_counter()
    step_stats = path_fn(engine, reqs)
    dt = time.perf_counter() - t0
    tokens = sum(len(r.out) for r in reqs)
    assert all(r.done or len(r.out) == r.max_new_tokens for r in reqs)
    ttft = sorted(first_tok[id(r)] - t0 for r in reqs if id(r) in first_tok)
    assert len(ttft) == len(reqs), "a request never emitted a first token"
    return {"tokens": tokens, "seconds": round(dt, 4),
            "tok_s": round(tokens / dt, 2), **step_stats,
            "ttft_s": {"mean": round(sum(ttft) / len(ttft), 4),
                       "p50": round(ttft[len(ttft) // 2], 4),
                       "max": round(ttft[-1], 4)}}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="bitnet-b1.58-2b")
    ap.add_argument("--smoke", action="store_true",
                    help="CPU-friendly reduction (CI mode)")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--short-new", type=int, default=2)
    ap.add_argument("--long-new", type=int, default=32)
    ap.add_argument("--long-every", type=int, default=4,
                    help="every k-th request is long (generation-skew knob)")
    ap.add_argument("--prompt-len", type=int, default=3)
    ap.add_argument("--long-prompt-len", type=int, default=48,
                    help="prompt length of the long-prompt requests "
                    "(admission-skew knob)")
    ap.add_argument("--long-prompt-every", type=int, default=5,
                    help="every k-th request has a long prompt")
    ap.add_argument("--prefill-chunk", type=int, default=16,
                    help="admission prefill chunk size (bucket granularity)")
    ap.add_argument("--admission-budget", type=int, default=1,
                    help="prefill chunks per scheduler step for the "
                    "continuous path (0 = unbounded)")
    ap.add_argument("--policy", default="auto",
                    help="ternary-matmul dispatch policy for both paths")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="also run the shared-prefix workload cold vs warm "
                    "and report prefix_hit_rate + shared-request TTFT "
                    "improvement in a schema-v3 'prefix' section")
    ap.add_argument("--prefix-cache-mb", type=float, default=64.0,
                    help="prefix-cache byte budget in MiB (LRU eviction)")
    ap.add_argument("--shared-requests", type=int, default=12,
                    help="shared-prefix workload size")
    ap.add_argument("--shared-prefix-len", type=int, default=96,
                    help="length of the shared system prompt (reusable "
                    "blocks = full --prefill-chunk multiples below this)")
    ap.add_argument("--shared-suffix-len", type=int, default=2,
                    help="unique per-request suffix after the shared prefix")
    ap.add_argument("--cold-every", type=int, default=4,
                    help="every k-th shared-prefix-workload request is a "
                    "unique cold prompt (0 = all shared)")
    ap.add_argument("--cold-prompt-len", type=int, default=48,
                    help="prompt length of the cold requests")
    ap.add_argument("--shared-new", type=int, default=4,
                    help="tokens generated per shared-prefix-workload "
                    "request (short: TTFT is the metric, not decode)")
    ap.add_argument("--mesh", default=None,
                    help="run both paths sharded over a DxM (data x model) "
                    "mesh, e.g. 1x8; axis product must equal the device "
                    "count (CPU: XLA_FLAGS=--xla_force_host_platform_"
                    "device_count=N)")
    ap.add_argument("--out", default="BENCH_serving.json")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    if args.smoke:
        cfg = cfg.with_(n_layers=2, d_model=128, n_heads=2, n_kv_heads=2,
                        head_dim=64, d_ff=256, vocab_size=512, loss_chunk=64)
    max_prompt = max(args.prompt_len, args.long_prompt_len)
    max_len = max_prompt + args.long_new + 1
    budget = args.admission_budget if args.admission_budget > 0 else None
    mesh = None
    if args.mesh:
        from repro.launch.mesh import make_serving_mesh

        mesh = make_serving_mesh(args.mesh)
    params = init_params(cfg, jax.random.PRNGKey(0))
    served = quantize_for_serving(params, cfg)

    def mk_reqs():
        return make_requests(args.requests, args.short_new, args.long_new,
                             args.long_every, args.prompt_len,
                             args.long_prompt_len, args.long_prompt_every,
                             cfg.vocab_size)

    results = {"schema_version": 3, "arch": cfg.name, "batch": args.batch,
               "policy": args.policy, "smoke": bool(args.smoke),
               "mesh": args.mesh,
               "prefill_chunk": args.prefill_chunk,
               "admission_budget": args.admission_budget,
               "workload": {"requests": args.requests,
                            "short_new": args.short_new,
                            "long_new": args.long_new,
                            "long_every": args.long_every,
                            "prompt_len": args.prompt_len,
                            "long_prompt_len": args.long_prompt_len,
                            "long_prompt_every": args.long_prompt_every}}
    paths = [("generational", run_generational),
             ("continuous",
              lambda e, r: run_continuous(e, r, admission_budget=budget))]
    for name, fn in paths:
        # fresh engine per path: identical PRNG/jit state, no cross-warming
        engine = DecodeEngine(served, cfg, batch_size=args.batch,
                              max_len=max_len, matmul_policy=args.policy,
                              prefill_chunk=args.prefill_chunk, mesh=mesh)
        # record the EFFECTIVE chunk (the engine clamps to the ring length
        # on windowed configs), not the requested flag
        results["prefill_chunk"] = engine.prefill_chunk
        results[name] = bench(fn, engine, mk_reqs)
        r = results[name]
        print(f"[serving_bench] {name:>12}: {r['tokens']} tok in "
              f"{r['seconds']:.2f}s = {r['tok_s']:.1f} tok/s "
              f"({r['decode_steps']} decode steps, ttft mean/max "
              f"{r['ttft_s']['mean']:.3f}/{r['ttft_s']['max']:.3f}s)")

    results["speedup"] = round(
        results["continuous"]["tok_s"] / results["generational"]["tok_s"], 3)
    results["ttft_ratio"] = round(
        results["continuous"]["ttft_s"]["max"]
        / max(results["generational"]["ttft_s"]["max"], 1e-9), 3)
    print(f"[serving_bench] continuous / generational speedup: "
          f"{results['speedup']:.2f}x; worst-case ttft ratio: "
          f"{results['ttft_ratio']:.2f}")
    results["prefix"] = (bench_prefix(args, cfg, served, mesh, budget)
                         if args.prefix_cache else {"enabled": False})
    with open(args.out, "w") as f:
        json.dump(results, f, indent=2)
        f.write("\n")
    print(f"[serving_bench] wrote {os.path.abspath(args.out)}")


if __name__ == "__main__":
    main()
