"""Serving throughput: continuous-batching scheduler vs generational batching
on a skewed-length workload — the case where generational batching collapses
(every batch turns over at the pace of its slowest request, so a few long
requests leave most slots idle most of the time).

Bitnet.cpp and TENET report end-to-end ternary decode tok/s as the headline
metric; this benchmark seeds the same trajectory for this repo.  Both paths
run the identical packed-ternary model through the identical jitted
decode_step — only the batching discipline differs — so the ratio isolates
scheduling, not kernels.

Writes ``BENCH_serving.json`` (schema below) for CI to surface in PRs:

  {"schema_version": 1, "arch": ..., "batch": ..., "workload": {...},
   "generational": {"tokens": N, "seconds": s, "tok_s": r, "decode_steps": d},
   "continuous":   {... same keys ...},
   "speedup": continuous.tok_s / generational.tok_s}

Run:  PYTHONPATH=src python benchmarks/serving_bench.py --smoke
      (CPU-friendly reduced config; full mode uses the registry smoke config
      unreduced).  Prompts share one length so each path compiles exactly one
      prefill + one decode step; compile time is excluded via a warmup pass.
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax

from repro.configs.registry import get_smoke_config
from repro.models.decode import quantize_for_serving
from repro.models.model import init_params
from repro.serving.engine import DecodeEngine, Request
from repro.serving.scheduler import ContinuousScheduler


def make_requests(n: int, short_new: int, long_new: int, long_every: int,
                  prompt_len: int, vocab: int) -> list[Request]:
    """Many short + few long (every ``long_every``-th request), fixed prompt
    length (one compile), varied prompt contents."""
    reqs = []
    for i in range(n):
        new = long_new if i % long_every == long_every - 1 else short_new
        prompt = [2 + ((7 * i + j) % (vocab - 3)) for j in range(prompt_len)]
        reqs.append(Request(prompt=prompt, max_new_tokens=new))
    return reqs


def run_generational(engine: DecodeEngine, reqs: list[Request]) -> int:
    """Seed baseline: batches of B run to the slowest request, sequentially."""
    steps = 0
    for i in range(0, len(reqs), engine.B):
        chunk = reqs[i:i + engine.B]
        engine.run(chunk)
        steps += max(len(r.out) for r in chunk)
    return steps


def run_continuous(engine: DecodeEngine, reqs: list[Request]) -> int:
    sched = ContinuousScheduler(engine)
    for r in reqs:
        sched.submit(r)
    sched.run(max_steps=100_000)
    return sched.stats.steps


def bench(path_fn, engine, mk_reqs) -> dict:
    path_fn(engine, mk_reqs())  # warmup: compile prefill + decode step
    reqs = mk_reqs()
    t0 = time.perf_counter()
    steps = path_fn(engine, reqs)
    dt = time.perf_counter() - t0
    tokens = sum(len(r.out) for r in reqs)
    assert all(r.done or len(r.out) == r.max_new_tokens for r in reqs)
    return {"tokens": tokens, "seconds": round(dt, 4),
            "tok_s": round(tokens / dt, 2), "decode_steps": steps}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="bitnet-b1.58-2b")
    ap.add_argument("--smoke", action="store_true",
                    help="CPU-friendly reduction (CI mode)")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--short-new", type=int, default=2)
    ap.add_argument("--long-new", type=int, default=32)
    ap.add_argument("--long-every", type=int, default=4,
                    help="every k-th request is long (skew knob)")
    ap.add_argument("--prompt-len", type=int, default=3)
    ap.add_argument("--policy", default="auto",
                    help="ternary-matmul dispatch policy for both paths")
    ap.add_argument("--out", default="BENCH_serving.json")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    if args.smoke:
        cfg = cfg.with_(n_layers=2, d_model=128, n_heads=2, n_kv_heads=2,
                        head_dim=64, d_ff=256, vocab_size=512, loss_chunk=64)
    max_len = args.prompt_len + args.long_new + 1
    params = init_params(cfg, jax.random.PRNGKey(0))
    served = quantize_for_serving(params, cfg)

    def mk_reqs():
        return make_requests(args.requests, args.short_new, args.long_new,
                             args.long_every, args.prompt_len, cfg.vocab_size)

    results = {"schema_version": 1, "arch": cfg.name, "batch": args.batch,
               "policy": args.policy, "smoke": bool(args.smoke),
               "workload": {"requests": args.requests,
                            "short_new": args.short_new,
                            "long_new": args.long_new,
                            "long_every": args.long_every,
                            "prompt_len": args.prompt_len}}
    for name, fn in [("generational", run_generational),
                     ("continuous", run_continuous)]:
        # fresh engine per path: identical PRNG/jit state, no cross-warming
        engine = DecodeEngine(served, cfg, batch_size=args.batch,
                              max_len=max_len, matmul_policy=args.policy)
        results[name] = bench(fn, engine, mk_reqs)
        print(f"[serving_bench] {name:>12}: {results[name]['tokens']} tok in "
              f"{results[name]['seconds']:.2f}s = {results[name]['tok_s']:.1f} "
              f"tok/s ({results[name]['decode_steps']} decode steps)")

    results["speedup"] = round(
        results["continuous"]["tok_s"] / results["generational"]["tok_s"], 3)
    print(f"[serving_bench] continuous / generational speedup: "
          f"{results['speedup']:.2f}x")
    with open(args.out, "w") as f:
        json.dump(results, f, indent=2)
        f.write("\n")
    print(f"[serving_bench] wrote {os.path.abspath(args.out)}")


if __name__ == "__main__":
    main()
