"""Serving throughput: continuous-batching scheduler vs generational batching
on a skewed workload — the case where generational batching collapses
(every batch turns over at the pace of its slowest request, so a few long
requests leave most slots idle most of the time).

Bitnet.cpp and TENET report end-to-end ternary decode tok/s as the headline
metric; this benchmark seeds the same trajectory for this repo.  Both paths
run the identical packed-ternary model through the identical jitted
decode_step — only the batching discipline differs — so the ratio isolates
scheduling, not kernels.

The workload is skewed along two axes: token budgets (many short + few long
generations: generational idle-slot collapse) and prompt lengths (every
``--long-prompt-every``-th request carries a ``--long-prompt-len`` prompt:
admission latency).  Besides tok/s, the bench records per-request
**time-to-first-token** — continuous admission is chunked (fixed-size
prefill chunks, one compiled trace) and budgeted (``--admission-budget``
chunks per scheduler step), so co-batched requests keep decoding while a
long prompt is admitted and their TTFT stays bounded.

With ``--prefix-cache`` the bench additionally runs the **shared-prefix
workload** — N requests sharing a long system prompt, mixed with unique
cold prompts, the traffic shape prefix caching exists for (cf. the
``precise-prefix-cache-aware`` scenario in llm-d-benchmark) — twice: a cold
engine with no store (recompute-from-scratch baseline) and a warm engine
whose ``PrefixBlockStore`` was pre-populated by a full warmup pass.  It
reports the block ``prefix_hit_rate`` of the measured warm pass, TTFT split
by shared vs cold requests, the warm/cold shared-TTFT improvement, and the
scheduler's per-request queue-wait summary (the fairness cost of
cache-affinity admission reordering, measurable next to the TTFT it buys).

With ``--draft <arch>`` the bench additionally runs the **speculative**
section: a small ternary draft model proposes ``--spec-k - 1`` greedy
continuations per round and the target verifies all candidates in one
batched forward (``DecodeEngine(draft=..., spec_k=...)``).  The section is
self-contained — the main workload is admission-heavy by design, so
speculation (a decode optimization) runs its own decode-heavy workload
through a **zero-tail twin** pair: a ≥8-layer target whose tail layers'
output scales are zeroed post-quantization (exact no-ops — the deep model
computes its 1-layer slice's function at full L-layer cost) and a draft
that IS that first layer, so drafting is ~L× cheaper and acceptance is
1.0 by construction.  Both a plain continuous baseline and the
speculative engine run the same workload; the streams are compared
byte-for-byte (greedy speculation must change *how many steps* the tokens
take, never the tokens — dense verify is scatter-first bitwise-exact, and
both engines use the canonical bf16-argmax greedy selection the
speculative round is defined over) and the tok/s ratio is the per-round
amortization win at the acceptance ceiling.  Real drafts accept less; the
section is labeled ``twin_draft``.

Writes ``BENCH_serving.json`` (schema below) for CI to surface in PRs:

  {"schema_version": 4, "arch": ..., "batch": ..., "workload": {...},
   "prefill_chunk": C, "admission_budget": k, "mesh": "1x8" | null,
   "generational": {"tokens": N, "seconds": s, "tok_s": r, "decode_steps": d,
                    "ttft_s": {"mean": m, "p50": p, "p95": q, "p99": Q,
                               "max": M},
                    "tpot_s": {... same percentile keys ...}},
   "continuous":   {... same keys, plus "admission_steps"/"sched_steps"
                    and "queue_wait_s" mean/p50/max ...},
   "speedup": continuous.tok_s / generational.tok_s,
   "ttft_ratio": continuous.ttft_s.max / generational.ttft_s.max,
   "prefix": {"enabled": bool, ...with --prefix-cache:
              "workload": {...}, "cold": {...}, "warm": {...},
              "prefix_hit_rate": h, "ttft_improvement":
              cold.shared_ttft_s.mean / warm.shared_ttft_s.mean},
   "speculative": {"enabled": bool, ...with --draft:
                   "draft": name, "spec_k": K, "twin_draft": true,
                   "target_layers": L, "workload": {"requests": n,
                   "new_tokens": t},
                   "tokens"/"seconds"/"tok_s"/"decode_steps"/"ttft_s"/
                   "tpot_s" as above, "spec_rounds": n,
                   "acceptance_rate": a, "drafted_tokens": D,
                   "accepted_drafted_tokens": A,
                   "tokens_per_decode_step": tokens / decode_steps,
                   "baseline_tok_s": r (the section's own non-spec run),
                   "speedup": tok_s / baseline_tok_s,
                   "byte_identical": spec stream == baseline stream}}

Schema v4 is v3 plus the ``speculative`` section, ``ttft_s`` tail
percentiles (p95/p99), and the per-request ``tpot_s``
(time-per-output-token) summary; v3 was v2 plus the ``prefix`` section
and the continuous path's ``queue_wait_s``.  Every pre-existing field is
unchanged, so older consumers (and the CI field-presence check, which
accepts v2+) keep working on old files.

``decode_steps`` counts steps that ran a decode; the continuous path's
admission-only steps (prompts still prefilling, nothing live to decode) are
reported separately as ``admission_steps``.  ``--mesh DxM`` runs both paths
on a sharded engine (TP on model, MoE EP on data) over forced host devices.

Run:  PYTHONPATH=src python benchmarks/serving_bench.py --smoke
      (CPU-friendly reduced config; full mode uses the registry smoke config
      unreduced).  Compile time is excluded via a warmup pass; the chunked
      admission path compiles one trace per chunk size regardless of the
      prompt-length mix.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

import jax
import numpy as np

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
import analysis  # noqa: E402  (benchmarks/analysis.py, same directory)

from repro.configs.registry import get_smoke_config
from repro.models.decode import quantize_for_serving
from repro.models.model import init_params
from repro.serving.engine import DecodeEngine, Request, SamplerConfig
from repro.serving.loadgen import (LoadGenerator, generate_trace,
                                   latency_summary, percentile)
from repro.serving.scheduler import ContinuousScheduler
from repro.serving.workload import get_scenario


def make_requests(n: int, short_new: int, long_new: int, long_every: int,
                  prompt_len: int, long_prompt_len: int,
                  long_prompt_every: int, vocab: int,
                  seed: int = 0) -> list[Request]:
    """Doubly skewed workload, drawn from a seeded rng rather than one
    hardcoded list: exactly ``n // long_every`` requests generate
    ``long_new`` tokens (vs ``short_new``) and exactly
    ``n // long_prompt_every`` carry a ``long_prompt_len`` prompt (vs
    ``prompt_len``) — the admission-latency case — but their *positions*
    in the arrival order and the prompt token *content* are sampled.  The
    same seed reproduces the same workload byte-for-byte (the CI
    tokens-equality check across batching paths relies on the exact
    counts), while different seeds give genuinely different skew mixes."""
    rng = np.random.default_rng([seed, 0x5EED])
    budgets = np.full(n, short_new, np.int64)
    budgets[rng.choice(n, n // long_every, replace=False)] = long_new
    plens = np.full(n, prompt_len, np.int64)
    plens[rng.choice(n, n // long_prompt_every, replace=False)] = \
        long_prompt_len
    return [Request(prompt=[int(t) for t in
                            rng.integers(2, vocab - 1, size=int(plens[i]))],
                    max_new_tokens=int(budgets[i]))
            for i in range(n)]


def make_shared_prefix_requests(n: int, prefix_len: int, suffix_len: int,
                                cold_every: int, cold_prompt_len: int,
                                new_tokens: int, vocab: int,
                                salt: int = 0) -> list[Request]:
    """Prefix-cache traffic shape: most requests share one long system
    prompt (plus a short unique suffix), every ``cold_every``-th request is
    a unique cold prompt.  ``salt`` varies the *unique* parts between runs
    so cold prompts never accidentally warm-hit across passes; the shared
    prefix is deliberately salt-independent."""
    shared = [2 + ((11 * j) % (vocab - 3)) for j in range(prefix_len)]
    reqs = []
    for i in range(n):
        cold = cold_every > 0 and i % cold_every == cold_every - 1
        if cold:
            prompt = [2 + ((5 * (i + 131 * salt) + 3 * j) % (vocab - 3))
                      for j in range(cold_prompt_len)]
        else:
            prompt = shared + [2 + ((7 * (i + 131 * salt) + j) % (vocab - 3))
                               for j in range(suffix_len)]
        r = Request(prompt=prompt, max_new_tokens=new_tokens)
        r.shared = not cold  # bench-side tag for the TTFT split
        reqs.append(r)
    return reqs


def _ttft_summary(vals: list[float]) -> dict:
    """mean/p50/p95/p99/max over per-request latencies (TTFT or TPOT) —
    tail percentiles included because speculation (and admission budgeting)
    claims are about the tail, not the mean.  Delegates to the repo's
    shared estimator (linear-interpolation percentiles, cross-checked
    against numpy in tests/test_workload.py)."""
    return latency_summary(vals, ndigits=4)


def _tpot_summary(token_times: dict[int, list[float]]) -> dict:
    """Per-request TPOT (time per output token: emission span / (n - 1))
    summarized across requests; single-token requests carry no inter-token
    gap and are excluded.  Speculative rounds emit their accepted window in
    one burst — those tokens share a timestamp, which is exactly the point:
    TPOT measures what a streaming client observes."""
    tpots = [(ts[-1] - ts[0]) / (len(ts) - 1)
             for ts in token_times.values() if len(ts) > 1]
    return _ttft_summary(tpots) if tpots else {}


def run_shared_prefix(engine: DecodeEngine, reqs: list[Request],
                      admission_budget: int | None) -> dict:
    """One pass of the shared-prefix workload with per-request TTFT split
    by shared vs cold, plus the scheduler queue-wait summary."""
    first_tok: dict[int, float] = {}

    def stamp(req, tok):
        first_tok.setdefault(req.rid, time.perf_counter())

    for r in reqs:
        r.on_token = stamp
    sched = ContinuousScheduler(engine, admission_budget=admission_budget)
    for r in reqs:
        sched.submit(r)
    t0 = time.perf_counter()
    sched.run(max_steps=100_000)
    dt = time.perf_counter() - t0
    ttft = {r.rid: first_tok[r.rid] - t0 for r in reqs}
    assert len(ttft) == len(reqs), "a request never emitted a first token"
    return {"tokens": sum(len(r.out) for r in reqs),
            "seconds": round(dt, 4),
            "ttft_s": _ttft_summary(list(ttft.values())),
            "shared_ttft_s": _ttft_summary(
                [ttft[r.rid] for r in reqs if r.shared]),
            "cold_ttft_s": _ttft_summary(
                [ttft[r.rid] for r in reqs if not r.shared]),
            "prefill_chunks": sched.stats.prefill_chunks,
            "affinity_reorders": sched.stats.affinity_reorders,
            "queue_wait_s": {k: round(v, 4) for k, v in
                             sched.stats.queue_wait_summary().items()}}


def bench_prefix(args, cfg, served, mesh, budget) -> dict:
    """Shared-prefix workload, cold vs warm: a no-store engine (recompute
    baseline) vs a prefix-cache engine whose store was populated by a full
    warmup pass.  The measured warm pass's block hit rate and shared-request
    TTFT improvement are the headline numbers."""
    from repro.serving.prefix_cache import PrefixStoreStats

    max_len = max(args.shared_prefix_len + args.shared_suffix_len,
                  args.cold_prompt_len) + args.shared_new + 1

    def mk(salt):
        return make_shared_prefix_requests(
            args.shared_requests, args.shared_prefix_len,
            args.shared_suffix_len, args.cold_every, args.cold_prompt_len,
            args.shared_new, cfg.vocab_size, salt=salt)

    def engine(prefix_cache):
        return DecodeEngine(served, cfg, batch_size=args.batch,
                            max_len=max_len, matmul_policy=args.policy,
                            prefill_chunk=args.prefill_chunk, mesh=mesh,
                            prefix_cache=prefix_cache,
                            prefix_cache_mb=args.prefix_cache_mb)

    e_cold = engine(False)
    run_shared_prefix(e_cold, mk(0), budget)  # warmup: compile
    cold = run_shared_prefix(e_cold, mk(1), budget)

    e_warm = engine(True)
    run_shared_prefix(e_warm, mk(2), budget)  # warmup: compile + publish
    e_warm.prefix_store.stats = PrefixStoreStats()  # measure one pass only
    warm = run_shared_prefix(e_warm, mk(3), budget)
    st = e_warm.prefix_store.stats

    out = {"enabled": True,
           "workload": {"requests": args.shared_requests,
                        "shared_prefix_len": args.shared_prefix_len,
                        "shared_suffix_len": args.shared_suffix_len,
                        "cold_every": args.cold_every,
                        "cold_prompt_len": args.cold_prompt_len,
                        "shared_new": args.shared_new},
           "cold": cold, "warm": warm,
           "prefix_hit_rate": round(st.hit_rate, 4),
           "hit_blocks": st.hit_blocks, "miss_blocks": st.miss_blocks,
           "reused_tokens": st.reused_tokens,
           "ttft_improvement": round(
               cold["shared_ttft_s"]["mean"]
               / max(warm["shared_ttft_s"]["mean"], 1e-9), 3)}
    print(f"[serving_bench] shared-prefix cold: shared ttft mean "
          f"{cold['shared_ttft_s']['mean']:.3f}s, cold-req mean "
          f"{cold['cold_ttft_s']['mean']:.3f}s")
    print(f"[serving_bench] shared-prefix warm: shared ttft mean "
          f"{warm['shared_ttft_s']['mean']:.3f}s, hit rate "
          f"{st.hit_rate:.0%} ({st.hit_blocks}/{st.lookups} blocks, "
          f"{st.reused_tokens} tokens spliced), ttft improvement "
          f"{out['ttft_improvement']:.2f}x")
    return out


def run_generational(engine: DecodeEngine, reqs: list[Request]) -> dict:
    """Seed baseline: batches of B run to the slowest request, sequentially."""
    steps = 0
    for i in range(0, len(reqs), engine.B):
        chunk = reqs[i:i + engine.B]
        engine.run(chunk)
        steps += max(len(r.out) for r in chunk)
    return {"decode_steps": steps}


def run_continuous(engine: DecodeEngine, reqs: list[Request],
                   admission_budget: int | None = None) -> dict:
    sched = ContinuousScheduler(engine, admission_budget=admission_budget)
    for r in reqs:
        sched.submit(r)
    sched.run(max_steps=100_000)
    # decode_steps counts steps that ran a decode; admission-only steps
    # (all slots still prefilling) are tallied separately so tok/step stays
    # an honest decode metric
    out = {"decode_steps": sched.stats.decode_steps,
           "admission_steps": sched.stats.admission_steps,
           "sched_steps": sched.stats.steps,
           "queue_wait_s": {k: round(v, 4) for k, v in
                            sched.stats.queue_wait_summary().items()}}
    if sched.stats.spec_rounds:
        out.update(
            spec_rounds=sched.stats.spec_rounds,
            drafted_tokens=sched.stats.drafted_tokens,
            accepted_drafted_tokens=sched.stats.accepted_drafted_tokens,
            acceptance_rate=round(sched.stats.acceptance_rate, 4))
    return out


def bench(path_fn, engine, mk_reqs) -> tuple[dict, list[list[int]]]:
    """Measure one batching path: warmup pass (compile), then a timed pass
    with per-token timestamps keyed on ``Request.rid``.  Returns the metric
    dict AND the emitted token streams in request order — the speculative
    section's byte-identity gate compares streams across paths."""
    path_fn(engine, mk_reqs())  # warmup: compile prefill chunks + decode step
    reqs = mk_reqs()
    token_times: dict[int, list[float]] = {}

    def stamp(req, tok):
        token_times.setdefault(req.rid, []).append(time.perf_counter())

    for r in reqs:
        r.on_token = stamp
    t0 = time.perf_counter()
    step_stats = path_fn(engine, reqs)
    dt = time.perf_counter() - t0
    tokens = sum(len(r.out) for r in reqs)
    assert all(r.done or len(r.out) == r.max_new_tokens for r in reqs)
    ttft = [token_times[r.rid][0] - t0 for r in reqs if r.rid in token_times]
    assert len(ttft) == len(reqs), "a request never emitted a first token"
    return ({"tokens": tokens, "seconds": round(dt, 4),
             "tok_s": round(tokens / dt, 2), **step_stats,
             "ttft_s": _ttft_summary(ttft),
             "tpot_s": _tpot_summary(token_times)},
            [list(r.out) for r in reqs])


def _zero_tail_wo(d: dict, under_wo: bool = False) -> dict:
    """Zero the ``wo``-projection scales of every layer but the first, on a
    stacked-blocks param tree.  A packed ternary projection contributes
    ``scale * (packed_matmul)`` to the residual stream, so zeroed tail
    scales make layers 1..L-1 exact no-ops: the L-layer model *computes the
    same function* as its 1-layer slice while paying L layers of real
    ternary compute."""
    out = {}
    for k, v in d.items():
        if isinstance(v, dict):
            out[k] = _zero_tail_wo(v, under_wo=(k == "wo"))
        elif under_wo and k == "scale":
            out[k] = v.at[1:].set(0)
        else:
            out[k] = v
    return out


def make_spec_pair(args, cfg):
    """Build the speculative section's (target, draft) pair: a **zero-tail
    function twin**.

    The target is the bench config deepened to at least 8 layers, with the
    attention/FFN output scales of layers 1..L-1 zeroed after quantization —
    those layers' residual deltas are exactly 0.0, so the deep target
    computes the same function as its first layer alone while paying the
    full L-layer decode cost.  The draft is literally the target's first
    layer (``blocks`` sliced to ``[:1]``, shared embed/lm_head/final_norm)
    under the ``--draft`` arch's registry name, so drafting is ~L× cheaper
    than a target step and every greedy proposal matches the target's
    argmax — acceptance is 1.0 *by construction*.

    This makes the section a measurement of the speculative machinery's
    per-round amortization ceiling (fused K-token verify vs K sequential
    decode steps) and of the byte-identity guarantee, NOT of a realistic
    draft/target acceptance rate — real drafts accept less and the speedup
    scales with their acceptance.  The output is labeled ``twin_draft`` so
    downstream consumers can't mistake it for a trained-draft result."""
    from repro.configs.registry import get_config

    scfg = cfg.with_(n_layers=max(cfg.n_layers, 8))
    sparams = quantize_for_serving(init_params(scfg, jax.random.PRNGKey(0)),
                                   scfg)
    sparams = dict(sparams, blocks=_zero_tail_wo(sparams["blocks"]))
    dparams = dict(sparams,
                   blocks=jax.tree.map(lambda x: x[:1], sparams["blocks"]))
    # structural knobs stay the target's (the sliced params must parse);
    # the registry lookup resolves module-style aliases (qwen3_0p6b)
    dcfg = scfg.with_(n_layers=1, name=get_config(args.draft).name)
    return scfg, sparams, dparams, dcfg


def bench_speculative(args, cfg, mesh) -> dict:
    """Speculative continuous serving vs its own non-speculative baseline.

    Self-contained by design: the doubly-skewed main workload is admission-
    heavy (most requests generate 2 tokens), which would measure prefill
    overlap rather than speculation.  This section instead runs a
    decode-heavy workload (``--spec-requests`` × ``--spec-new`` tokens,
    short varied prompts) through TWO fresh engines built on the zero-tail
    twin pair (:func:`make_spec_pair`) — one plain continuous, one
    speculative — and reports: tok/s for both, acceptance rate, tokens per
    decode step (the claim: ≈ spec_k — each round retires its accepted
    window through ONE fused draft+verify+rollback call), and byte-identity
    of the greedy streams (must be True: dense verify is scatter-first
    exact, so speculation changes how many steps the tokens take, never the
    tokens)."""
    import numpy as np

    scfg, sparams, dparams, dcfg = make_spec_pair(args, cfg)
    rng = np.random.default_rng(0)
    prompts = [[int(t) for t in rng.integers(2, scfg.vocab_size - 2,
                                             int(rng.integers(4, 11)))]
               for _ in range(args.spec_requests)]
    max_len = max(len(p) for p in prompts) + args.spec_new + 1
    max_len = -(-max_len // 16) * 16

    def mk_spec_reqs():
        return [Request(prompt=list(p), max_new_tokens=args.spec_new)
                for p in prompts]

    runs = {}
    outs = {}
    for name, draft in (("baseline", None), ("spec", (dparams, dcfg))):
        # canonical (bf16-argmax) greedy on BOTH engines: the speculative
        # round always selects canonically, so the baseline must too for
        # the streams to be byte-comparable
        engine = DecodeEngine(sparams, scfg, batch_size=args.batch,
                              max_len=max_len, matmul_policy=args.policy,
                              prefill_chunk=args.prefill_chunk, mesh=mesh,
                              sampler=SamplerConfig(canonical_greedy=True),
                              draft=draft,
                              spec_k=args.spec_k if draft else 2)
        runs[name], outs[name] = bench(
            lambda e, r: run_continuous(e, r), engine, mk_spec_reqs)
    spec = runs["spec"]
    out = {"enabled": True, "draft": dcfg.name, "spec_k": args.spec_k,
           "twin_draft": True, "target_layers": scfg.n_layers,
           "workload": {"requests": args.spec_requests,
                        "new_tokens": args.spec_new}, **spec,
           "tokens_per_decode_step": round(
               spec["tokens"] / max(spec["decode_steps"], 1), 3),
           "baseline_tok_s": runs["baseline"]["tok_s"],
           "speedup": round(spec["tok_s"] / runs["baseline"]["tok_s"], 3),
           "byte_identical": outs["spec"] == outs["baseline"]}
    print(f"[serving_bench]  speculative: {spec['tokens']} tok in "
          f"{spec['seconds']:.2f}s = {spec['tok_s']:.1f} tok/s vs baseline "
          f"{out['baseline_tok_s']:.1f} tok/s ({spec['decode_steps']} decode "
          f"steps, {out['tokens_per_decode_step']:.2f} tok/step, acceptance "
          f"{spec.get('acceptance_rate', 0.0):.0%}, speedup "
          f"{out['speedup']:.2f}x, byte-identical: "
          f"{out['byte_identical']})")
    return out


def bench_scenario(args, cfg, served, mesh, budget) -> tuple[dict, dict | None]:
    """Replay a named multi-tenant scenario through the load generator and
    report the schema-v5 ``workload`` section (per-tenant p50/p95/p99
    TTFT+TPOT, SLO attainment, goodput) plus, with ``--saturate``, the
    doubling+bisection sweep for max sustainable QPS.

    One engine serves every probe (same compiled traces; scaling changes
    arrival rates, never shapes).  Under the default virtual clock each run
    is fully deterministic — same seed, byte-identical ``workload`` section
    — and compile time cannot pollute the metrics; ``--clock wall``
    measures real time instead (a warmup replay absorbs compilation)."""
    scenario = get_scenario(args.scenario)
    if args.smoke:
        scenario = scenario.smoke()
    if args.qps_scale != 1.0:
        scenario = scenario.scaled(args.qps_scale)
    max_len = scenario.max_prompt_len() + scenario.max_new_tokens() + 1
    max_len = -(-max_len // 16) * 16
    engine = DecodeEngine(served, cfg, batch_size=args.batch,
                          max_len=max_len, matmul_policy=args.policy,
                          prefill_chunk=args.prefill_chunk, mesh=mesh,
                          prefix_cache=args.prefix_cache,
                          prefix_cache_mb=args.prefix_cache_mb)

    def run_at(scale: float, clock: str):
        sc = scenario.scaled(scale) if scale != 1.0 else scenario
        trace = generate_trace(sc, cfg.vocab_size, args.seed)
        gen = LoadGenerator(engine, trace, clock=clock,
                            decode_step_cost_s=args.step_cost_decode,
                            prefill_chunk_cost_s=args.step_cost_prefill,
                            admission_budget=budget)
        return sc, gen.run()

    if args.clock == "wall":
        run_at(1.0, "wall")  # warmup: compile every chunk/step trace
    sc, result = run_at(1.0, args.clock)
    workload = analysis.scenario_report(sc, result, args.seed)
    for name, t in workload["tenants"].items():
        print(f"[serving_bench] scenario {scenario.name}/{name}: "
              f"{t['requests']} reqs, ttft p50/p99 {t['ttft_s']['p50']:.4f}/"
              f"{t['ttft_s']['p99']:.4f}s, tpot p50 {t['tpot_s']['p50']:.4f}"
              f"s, slo attainment {t['slo_attainment']:.0%}")
    print(f"[serving_bench] scenario {scenario.name}: offered "
          f"{workload['offered_qps']:.2f} qps, achieved "
          f"{workload['achieved_qps']:.2f} qps, overall attainment "
          f"{workload['slo_attainment']:.0%}, goodput "
          f"{workload['goodput_qps']:.2f} qps")
    saturation = None
    if args.saturate:

        def p99_at(scale):
            _, res = run_at(scale, "virtual")
            return percentile([r.ttft_s for r in res.records
                               if r.ttft_s is not None], 99)

        saturation = analysis.saturation_sweep(
            p99_at, scenario.offered_qps(), scenario.slo_ttft_budget(),
            max_doublings=args.saturate_doublings,
            bisect_iters=args.saturate_bisects, log=print)
        print(f"[serving_bench] scenario {scenario.name}: max sustainable "
              f"{saturation['max_sustainable_qps']:.2f} qps at p99 ttft <= "
              f"{saturation['slo_ttft_s']}s "
              f"({'bracketed' if saturation['saturated'] else 'lower bound'}"
              f", {len(saturation['probes'])} probes)")
    return workload, saturation


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="bitnet-b1.58-2b")
    ap.add_argument("--smoke", action="store_true",
                    help="CPU-friendly reduction (CI mode)")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--short-new", type=int, default=2)
    ap.add_argument("--long-new", type=int, default=32)
    ap.add_argument("--long-every", type=int, default=4,
                    help="every k-th request is long (generation-skew knob)")
    ap.add_argument("--prompt-len", type=int, default=3)
    ap.add_argument("--long-prompt-len", type=int, default=48,
                    help="prompt length of the long-prompt requests "
                    "(admission-skew knob)")
    ap.add_argument("--long-prompt-every", type=int, default=5,
                    help="every k-th request has a long prompt")
    ap.add_argument("--prefill-chunk", type=int, default=16,
                    help="admission prefill chunk size (bucket granularity)")
    ap.add_argument("--admission-budget", type=int, default=1,
                    help="prefill chunks per scheduler step for the "
                    "continuous path (0 = unbounded)")
    ap.add_argument("--policy", default="auto",
                    help="ternary-matmul dispatch policy for both paths")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="also run the shared-prefix workload cold vs warm "
                    "and report prefix_hit_rate + shared-request TTFT "
                    "improvement in a schema-v3 'prefix' section")
    ap.add_argument("--prefix-cache-mb", type=float, default=64.0,
                    help="prefix-cache byte budget in MiB (LRU eviction)")
    ap.add_argument("--shared-requests", type=int, default=12,
                    help="shared-prefix workload size")
    ap.add_argument("--shared-prefix-len", type=int, default=96,
                    help="length of the shared system prompt (reusable "
                    "blocks = full --prefill-chunk multiples below this)")
    ap.add_argument("--shared-suffix-len", type=int, default=2,
                    help="unique per-request suffix after the shared prefix")
    ap.add_argument("--cold-every", type=int, default=4,
                    help="every k-th shared-prefix-workload request is a "
                    "unique cold prompt (0 = all shared)")
    ap.add_argument("--cold-prompt-len", type=int, default=48,
                    help="prompt length of the cold requests")
    ap.add_argument("--shared-new", type=int, default=4,
                    help="tokens generated per shared-prefix-workload "
                    "request (short: TTFT is the metric, not decode)")
    ap.add_argument("--draft", default=None,
                    help="draft arch name for speculative decoding (registry "
                    "name or module alias, e.g. qwen3_0p6b); adds the "
                    "schema-v4 'speculative' section — a decode-heavy "
                    "workload through a zero-tail twin target/draft pair, "
                    "spec vs non-spec, gated byte-identical (tests the "
                    "machinery and amortization ceiling, not draft quality)")
    ap.add_argument("--spec-k", type=int, default=4,
                    help="candidates per speculative verify step (1 free "
                    "target token + spec-k - 1 drafted)")
    ap.add_argument("--spec-requests", type=int, default=8,
                    help="speculative-section workload size")
    ap.add_argument("--spec-new", type=int, default=48,
                    help="tokens generated per speculative-section request "
                    "(decode-heavy: speculation is a decode optimization)")
    ap.add_argument("--mesh", default=None,
                    help="run both paths sharded over a DxM (data x model) "
                    "mesh, e.g. 1x8; axis product must equal the device "
                    "count (CPU: XLA_FLAGS=--xla_force_host_platform_"
                    "device_count=N)")
    ap.add_argument("--seed", type=int, default=0,
                    help="workload seed: the skewed request mix AND any "
                    "--scenario arrival trace are drawn from it "
                    "deterministically")
    ap.add_argument("--scenario", default=None,
                    help="also replay a named multi-tenant workload "
                    "(chat | rag | agentic | code) through the open-loop "
                    "load generator and emit the schema-v5 'workload' "
                    "section (per-tenant p50/p95/p99 TTFT+TPOT, SLO "
                    "attainment, goodput)")
    ap.add_argument("--clock", default="virtual",
                    choices=("virtual", "wall"),
                    help="scenario clock: 'virtual' (deterministic "
                    "simulated time, byte-reproducible percentiles) or "
                    "'wall' (real time on this machine)")
    ap.add_argument("--qps-scale", type=float, default=1.0,
                    help="multiply every tenant's arrival rate in the "
                    "measured scenario run")
    ap.add_argument("--saturate", action="store_true",
                    help="run the doubling+bisection saturation sweep and "
                    "report max sustainable QPS (p99 TTFT under the "
                    "scenario's loosest tenant budget); virtual clock only")
    ap.add_argument("--saturate-doublings", type=int, default=3,
                    help="rate doublings before declaring a lower bound")
    ap.add_argument("--saturate-bisects", type=int, default=3,
                    help="bisection rounds after the first failing probe")
    ap.add_argument("--step-cost-decode", type=float, default=0.01,
                    help="virtual-clock seconds per decode step")
    ap.add_argument("--step-cost-prefill", type=float, default=0.02,
                    help="virtual-clock seconds per prefill chunk")
    ap.add_argument("--out", default="BENCH_serving.json")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    if args.smoke:
        cfg = cfg.with_(n_layers=2, d_model=128, n_heads=2, n_kv_heads=2,
                        head_dim=64, d_ff=256, vocab_size=512, loss_chunk=64)
    max_prompt = max(args.prompt_len, args.long_prompt_len)
    max_len = max_prompt + args.long_new + 1
    budget = args.admission_budget if args.admission_budget > 0 else None
    mesh = None
    if args.mesh:
        from repro.launch.mesh import make_serving_mesh

        mesh = make_serving_mesh(args.mesh)
    params = init_params(cfg, jax.random.PRNGKey(0))
    served = quantize_for_serving(params, cfg)

    def mk_reqs():
        return make_requests(args.requests, args.short_new, args.long_new,
                             args.long_every, args.prompt_len,
                             args.long_prompt_len, args.long_prompt_every,
                             cfg.vocab_size, seed=args.seed)

    request_mix = {"requests": args.requests,
                   "short_new": args.short_new,
                   "long_new": args.long_new,
                   "long_every": args.long_every,
                   "prompt_len": args.prompt_len,
                   "long_prompt_len": args.long_prompt_len,
                   "long_prompt_every": args.long_prompt_every}
    # schema v5: + "seed", + "mode" ("paths" | "scenario").  In scenario
    # mode the "workload" key carries the per-tenant scenario report (the
    # classic request-mix params move to "request_mix"); in paths mode
    # "workload" keeps its v2+ meaning, so old consumers are untouched.
    mode = "scenario" if args.scenario else "paths"
    results = {"schema_version": 5, "arch": cfg.name, "batch": args.batch,
               "policy": args.policy, "smoke": bool(args.smoke),
               "mesh": args.mesh, "mode": mode, "seed": args.seed,
               "prefill_chunk": args.prefill_chunk,
               "admission_budget": args.admission_budget,
               ("request_mix" if mode == "scenario" else "workload"):
               request_mix}
    paths = [("generational", run_generational),
             ("continuous",
              lambda e, r: run_continuous(e, r, admission_budget=budget))]
    outs: dict[str, list[list[int]]] = {}
    for name, fn in paths:
        # fresh engine per path: identical PRNG/jit state, no cross-warming
        engine = DecodeEngine(served, cfg, batch_size=args.batch,
                              max_len=max_len, matmul_policy=args.policy,
                              prefill_chunk=args.prefill_chunk, mesh=mesh)
        # record the EFFECTIVE chunk (the engine clamps to the ring length
        # on windowed configs), not the requested flag
        results["prefill_chunk"] = engine.prefill_chunk
        results[name], outs[name] = bench(fn, engine, mk_reqs)
        r = results[name]
        print(f"[serving_bench] {name:>12}: {r['tokens']} tok in "
              f"{r['seconds']:.2f}s = {r['tok_s']:.1f} tok/s "
              f"({r['decode_steps']} decode steps, ttft mean/max "
              f"{r['ttft_s']['mean']:.3f}/{r['ttft_s']['max']:.3f}s)")

    results["speedup"] = round(
        results["continuous"]["tok_s"] / results["generational"]["tok_s"], 3)
    results["ttft_ratio"] = round(
        results["continuous"]["ttft_s"]["max"]
        / max(results["generational"]["ttft_s"]["max"], 1e-9), 3)
    print(f"[serving_bench] continuous / generational speedup: "
          f"{results['speedup']:.2f}x; worst-case ttft ratio: "
          f"{results['ttft_ratio']:.2f}")
    results["prefix"] = (bench_prefix(args, cfg, served, mesh, budget)
                         if args.prefix_cache else {"enabled": False})
    results["speculative"] = (bench_speculative(args, cfg, mesh)
                              if args.draft else {"enabled": False})
    if args.scenario:
        results["workload"], results["saturation"] = bench_scenario(
            args, cfg, served, mesh, budget)
    analysis.check_schema(results)
    with open(args.out, "w") as f:
        json.dump(results, f, indent=2)
        f.write("\n")
    print(f"[serving_bench] wrote {os.path.abspath(args.out)}")


if __name__ == "__main__":
    main()
