"""Design-space exploration walkthrough — the paper's §VI/§VII story.

Sweeps the (mu, L, K, dtype) space with the calibrated cost model, prints the
per-submodule breakdown (Fig. 5), the baseline comparison (Table IV), tile
scaling (Fig. 7), geometry (Fig. 8) and the SOTA reconfiguration (Table V).

Run:  PYTHONPATH=src python examples/dse_explore.py
"""

from benchmarks.paper_tables import ALL


def main():
    for name, fn in ALL.items():
        rows, derived = fn()
        print(f"\n=== {name} ===")
        print(f"  {derived}")
        for r in rows[:12]:
            print("   ", ", ".join(f"{k}={v}" for k, v in r.items()))
        if len(rows) > 12:
            print(f"    ... ({len(rows) - 12} more rows)")


if __name__ == "__main__":
    main()
