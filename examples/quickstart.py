"""Quickstart: the paper's pipeline in one page.

1. Quantize a weight matrix to ternary (BitNet b1.58 absmean).
2. Encode it with the paper's dense offline encoding (~1.6 bits/weight).
3. Run the two-phase LUT matmul (build + fetch/accumulate) and check it
   equals the plain matmul.
4. Generate the accelerator for a design point, print its netlist/area, and
   ask the DSE for the area-optimal configuration at the same throughput.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import jax.numpy as jnp
import numpy as np

from repro.core import dse, encoding, lut_algorithm
from repro.core.generator import LUTCoreConfig, generate
from repro.core.quantization import ternarize

rng = np.random.default_rng(0)

# 1. ternary quantization
w = jnp.asarray(rng.normal(size=(64, 256)), jnp.float32)
w_t, scale = ternarize(w)
print(f"ternary weights: {float((w_t == 0).mean()) * 100:.0f}% zeros, "
      f"scale={float(scale):.4f}")

# 2. offline dense encoding (paper §III-D)
mu = 3
keys = encoding.encode_weight_matrix(w_t, mu)
print(f"encoded at {encoding.key_bits(mu)} bits per {mu} weights "
      f"= {encoding.bits_per_weight(mu):.3f} b/w "
      f"(info-theoretic limit {np.log2(3):.3f})")

# 3. LUT-based matmul == plain matmul
x = jnp.asarray(rng.normal(size=(4, w.shape[1])), jnp.float32)
y_lut = lut_algorithm.lut_matmul_keys(
    jnp.pad(x, ((0, 0), (0, keys.shape[1] * mu - x.shape[1]))), keys, mu)
y_ref = x @ w_t.astype(jnp.float32).T
print(f"LUT matmul max err vs matmul: {float(jnp.max(jnp.abs(y_lut - y_ref))):.2e}")

# 4. hardware generation + DSE
design = generate(LUTCoreConfig(mu=3, L=32, K=32, act_dtype="fp16"))
print("\n" + design.module_hierarchy())
print("\n" + design.report())

best = dse.optimal_config_at_throughput(design.config.throughput_mul_per_cycle,
                                        "fp16")
print(f"\nDSE: area-optimal config at the same throughput: "
      f"(L={best.L}, mu={best.mu}, K={best.K}) "
      f"→ {best.area_mm2():.4f} mm² vs {design.area_mm2:.4f} mm²")
