"""End-to-end serving driver (the paper's deployment scenario).

Builds a BitNet-style ternary LM, converts it to the packed 1.6-bit serving
artifact, and serves a skewed batch of requests through the
continuous-batching scheduler: more requests than slots, FIFO admission,
finished slots refilled mid-flight, tokens streamed per step — the
memory-bound regime the LUT accelerator targets.  Reports tokens generated,
decode steps used, and the weight-byte savings vs bf16.

Run:  PYTHONPATH=src python examples/serve_ternary.py [--arch bitnet-b1.58-2b]
      (--full uses the unreduced config; default is a CPU-friendly reduction)
"""

import argparse
import time

import jax

from repro.configs.registry import get_config, get_smoke_config
from repro.models.decode import packed_bits_per_weight, quantize_for_serving
from repro.models.model import init_params
from repro.serving.engine import DecodeEngine, Request, SamplerConfig
from repro.serving.scheduler import ContinuousScheduler


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="bitnet-b1.58-2b")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--requests", type=int, default=5)
    ap.add_argument("--new-tokens", type=int, default=16)
    args = ap.parse_args()

    cfg = get_config(args.arch) if args.full else get_smoke_config(args.arch)
    print(f"[serve] {cfg.name}: {cfg.param_count()/1e6:.1f}M params "
          f"({'full' if args.full else 'reduced smoke'} config)")

    params = init_params(cfg, jax.random.PRNGKey(0))
    t0 = time.time()
    served = quantize_for_serving(params, cfg)
    bpw = packed_bits_per_weight(served)
    print(f"[serve] packed ternary artifact: {bpw:.3f} bits/weight "
          f"({16/bpw:.1f}x smaller than bf16), quantized in {time.time()-t0:.1f}s")

    engine = DecodeEngine(served, cfg, batch_size=args.batch,
                          max_len=8 + 2 * args.new_tokens,
                          sampler=SamplerConfig(temperature=0.8, top_k=40, seed=0))
    # skewed lengths: generational batching would hold every slot hostage to
    # the longest request; the scheduler turns slots over independently
    reqs = [Request(prompt=[10 + i, 20 + i, 30 + i],
                    max_new_tokens=args.new_tokens if i % 3 == 0
                    else max(2, args.new_tokens // 4))
            for i in range(args.requests)]

    sched = ContinuousScheduler(engine)
    for r in reqs:
        sched.submit(r)
    t0 = time.time()
    sched.run()
    dt = time.time() - t0
    total = sum(len(r.out) for r in reqs)
    print(f"[serve] generated {total} tokens over {args.requests} requests "
          f"({args.batch} slots, {sched.stats.steps} decode steps) "
          f"in {dt:.1f}s ({total/dt:.1f} tok/s on this host)")
    for i, r in enumerate(reqs):
        print(f"  request {i}: {r.prompt} -> {r.out}")


if __name__ == "__main__":
    main()
