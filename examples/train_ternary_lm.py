"""End-to-end QAT training driver: BitNet b1.58-style ternary training with
the fault-tolerant loop (checkpoint/resume, straggler watchdog, optional
gradient compression), then conversion to the packed serving artifact.

Run:  PYTHONPATH=src python examples/train_ternary_lm.py \
          [--arch bitnet-b1.58-2b] [--steps 200] [--dim 256] [--layers 4]

The default is a ~10M-parameter reduction that trains in minutes on CPU; on
a pod, drop --dim/--layers to use the full config with the production mesh.
"""

import argparse

import jax

from repro.configs.registry import get_config
from repro.launch.train import train
from repro.models.config import reduced
from repro.models.decode import packed_bits_per_weight, quantize_for_serving


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="bitnet-b1.58-2b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--dim", type=int, default=256)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--ckpt-dir", default="/tmp/ternary_lm_ckpt")
    ap.add_argument("--compress-grads", action="store_true")
    args = ap.parse_args()

    cfg = reduced(get_config(args.arch), d_model=args.dim, n_layers=args.layers,
                  n_heads=max(args.dim // 64, 1),
                  n_kv_heads=max(args.dim // 128, 1),
                  head_dim=64, d_ff=args.dim * 4, vocab_size=4096,
                  loss_chunk=128)
    print(f"[train] {cfg.name} reduced to {cfg.param_count()/1e6:.1f}M params; "
          f"QAT with STE ternary weights")

    n = jax.device_count()
    mesh = jax.make_mesh((n, 1), ("data", "model"))
    out = train(cfg, steps=args.steps, global_batch=args.global_batch,
                seq_len=args.seq_len, mesh=mesh, ckpt_dir=args.ckpt_dir,
                checkpoint_every=50, compress_grads=args.compress_grads,
                lr=1e-3, log_every=20)
    h = out["history"]
    print(f"[train] loss {h[0]:.3f} -> {h[-1]:.3f} over {len(h)} steps "
          f"({out['exit']})")

    served = quantize_for_serving(out["params"], cfg)
    print(f"[train] serving artifact: {packed_bits_per_weight(served):.3f} "
          f"bits/weight — ready for examples/serve_ternary.py")


if __name__ == "__main__":
    main()
