"""repro.checkpoint subsystem."""
