"""Sharded, atomic, resumable checkpointing (no external deps).

Layout:
  <dir>/step_000123/
      manifest.json        — step, leaf index, shapes/dtypes, checksums
      shard_00000.npz      — flattened leaves (split across shard files)
      _COMMITTED           — written last; restore ignores dirs without it

Fault-tolerance properties:
  * atomic: the step directory is staged as ``.tmp-step_X`` and renamed after
    the commit marker is written — a killed writer never corrupts state;
  * validated:每 leaf crc32 recorded and checked on restore;
  * elastic: leaves are stored logically (full arrays, host-gathered); a
    restart may use a different mesh/process count — shardings are re-applied
    by the caller (``launch/train.py``) via device_put;
  * async: ``save_async`` hands the host copy to a worker thread so the train
    loop overlaps the disk write (one in flight at a time).
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import zlib
from typing import Any

import jax
import ml_dtypes
import numpy as np

_MARKER = "_COMMITTED"
_worker: threading.Thread | None = None

# npz cannot represent ml_dtypes (bfloat16, fp8); store them as same-width
# uint views and restore via the manifest's dtype string.
_VIEW_AS = {"bfloat16": np.uint16, "float8_e4m3fn": np.uint8,
            "float8_e5m2": np.uint8}


def _to_savable(a: np.ndarray) -> np.ndarray:
    if a.dtype.name in _VIEW_AS:
        return a.view(_VIEW_AS[a.dtype.name])
    return a


def _from_saved(a: np.ndarray, dtype_name: str) -> np.ndarray:
    if dtype_name in _VIEW_AS:
        return a.view(getattr(ml_dtypes, dtype_name))
    return a


def _flatten(tree: Any):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def save(path: str, step: int, tree: Any, max_shard_bytes: int = 1 << 30) -> str:
    """Blocking save.  Returns the committed directory."""
    leaves, _ = _flatten(tree)
    arrs = [np.asarray(x) for x in leaves]
    final = os.path.join(path, f"step_{step:08d}")
    tmp = os.path.join(path, f".tmp-step_{step:08d}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)

    manifest = {"step": step, "leaves": [], "shards": []}
    shard, shard_bytes, shard_id = {}, 0, 0

    def flush():
        nonlocal shard, shard_bytes, shard_id
        if not shard:
            return
        fname = f"shard_{shard_id:05d}.npz"
        np.savez(os.path.join(tmp, fname), **shard)
        manifest["shards"].append(fname)
        shard, shard_bytes, shard_id = {}, 0, shard_id + 1

    for i, a in enumerate(arrs):
        key = f"leaf_{i:06d}"
        manifest["leaves"].append({
            "key": key, "shard": shard_id, "shape": list(a.shape),
            "dtype": a.dtype.name, "crc32": zlib.crc32(a.tobytes()),
        })
        shard[key] = _to_savable(a)
        shard_bytes += a.nbytes
        if shard_bytes >= max_shard_bytes:
            flush()
    flush()

    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    with open(os.path.join(tmp, _MARKER), "w") as f:
        f.write("ok")
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    return final


def save_async(path: str, step: int, tree: Any) -> None:
    """Overlapped save: host-copy now, disk write on a worker thread."""
    global _worker
    wait()
    arrs = jax.tree.map(lambda x: np.asarray(x), tree)  # host copy (sync point)
    _worker = threading.Thread(target=save, args=(path, step, arrs), daemon=True)
    _worker.start()


def wait() -> None:
    global _worker
    if _worker is not None:
        _worker.join()
        _worker = None


def latest_step(path: str) -> int | None:
    if not os.path.isdir(path):
        return None
    steps = []
    for d in os.listdir(path):
        if d.startswith("step_") and \
                os.path.exists(os.path.join(path, d, _MARKER)):
            steps.append(int(d.split("_")[1]))
    return max(steps) if steps else None


def restore(path: str, step: int, like: Any, *, validate: bool = True) -> Any:
    """Restore into the structure of ``like`` (a pytree of arrays or
    ShapeDtypeStructs).  Resharding is the caller's job (device_put with the
    current mesh's shardings) — this is what makes restarts elastic."""
    d = os.path.join(path, f"step_{step:08d}")
    if not os.path.exists(os.path.join(d, _MARKER)):
        raise FileNotFoundError(f"no committed checkpoint at {d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    shards = {}
    for fname in manifest["shards"]:
        shards.update(np.load(os.path.join(d, fname)))
    leaves, treedef = _flatten(like)
    out = []
    for i, (spec, meta) in enumerate(zip(leaves, manifest["leaves"])):
        a = _from_saved(shards[meta["key"]], meta["dtype"])
        if validate and zlib.crc32(a.tobytes()) != meta["crc32"]:
            raise IOError(f"checksum mismatch for {meta['key']} in {d}")
        if list(a.shape) != list(spec.shape):
            raise ValueError(f"shape mismatch {a.shape} vs {spec.shape} "
                             f"(leaf {i}) — elastic reshape not supported for "
                             f"param leaves")
        out.append(a)
    return jax.tree_util.tree_unflatten(treedef, out)


def restore_latest(path: str, like: Any):
    s = latest_step(path)
    if s is None:
        return None, None
    return s, restore(path, s, like)
