"""Architecture configs: one module per assigned architecture + the
paper-native BitNet config.  See registry.py for lookup + input specs."""
