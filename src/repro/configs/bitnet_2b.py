"""bitnet-b1.58-2b — the paper's native model family (BitNet b1.58 2B4T
class): W1.58A8 with INT8 activation fake-quant enabled, the operating point
the LUT accelerator is built for (Table I)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="bitnet-b1.58-2b", family="dense",
    n_layers=30, d_model=2560, n_heads=20, n_kv_heads=5, d_ff=6912,
    vocab_size=128_256, act_fn="silu",
    quantize_acts=True,
)
