"""gemma-7b [dense]: GeGLU, head_dim 256, RMSNorm(1+w), scaled embeddings,
tied LM head.  [arXiv:2403.08295; hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma-7b", family="dense",
    n_layers=28, d_model=3072, n_heads=16, n_kv_heads=16, d_ff=24_576,
    vocab_size=256_000, head_dim=256, act_fn="gelu",
    rmsnorm_offset=True, embed_scale=True, tie_embeddings=True,
)
