"""internvl2-2b [vlm]: InternViT frontend (stub) + InternLM2-1.8B GQA backbone.
[arXiv:2404.16821; hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-2b", family="vlm",
    n_layers=24, d_model=2048, n_heads=16, n_kv_heads=8, d_ff=8192,
    vocab_size=92_553, act_fn="silu",
    frontend="vit_stub", vision_tokens=64,
)
