"""llama4-maverick-400b-a17b [moe]: 128 experts top-1 + shared expert, MoE on
every other layer with 2x dense FFN between (matches the release's ~400B
total / ~17B active).  [hf:meta-llama/Llama-4; unverified]  Early-fusion VLM
aspect reduced to the token backbone per the assignment's LM shapes."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b", family="moe",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8, d_ff=8192,
    vocab_size=202_048, act_fn="silu",
    n_experts=128, experts_per_token=1, moe_shared_expert=True,
    moe_every=2, dense_ff=16_384,
    optimizer="adafactor", capacity_factor=1.25,
)
