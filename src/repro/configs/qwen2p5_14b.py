"""qwen2.5-14b [dense]: GQA with QKV bias.  [hf:Qwen/Qwen2.5; hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-14b", family="dense",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8, d_ff=13_824,
    vocab_size=152_064, act_fn="silu", qkv_bias=True, rope_theta=1_000_000.0,
)
