"""qwen3-0.6b [dense]: qk_norm, GQA, head_dim 128 (> d_model/n_heads),
tied embeddings.  [hf:Qwen/Qwen3; hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-0.6b", family="dense",
    n_layers=28, d_model=1024, n_heads=16, n_kv_heads=8, d_ff=3072,
    vocab_size=151_936, head_dim=128, act_fn="silu", qk_norm=True,
    tie_embeddings=True, rope_theta=1_000_000.0,
)
