"""Architecture registry + per-(arch × shape) input specs.

``input_specs`` returns ShapeDtypeStruct stand-ins for every model input of a
dry-run cell — weak-type-correct, shardable, no device allocation (the same
pattern the dry-run harness lowers against).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs import (
    bitnet_2b,
    gemma_7b,
    internvl2_2b,
    llama4_maverick_400b,
    phi3p5_moe,
    qwen2p5_14b,
    qwen3_0p6b,
    whisper_large_v3,
    xlstm_125m,
    yi_34b,
    zamba2_2p7b,
)
from repro.configs.shapes import SHAPES, Shape, cells_for
from repro.models.config import ModelConfig, reduced

_MODULES = [internvl2_2b, zamba2_2p7b, yi_34b, gemma_7b, qwen2p5_14b,
            qwen3_0p6b, llama4_maverick_400b, phi3p5_moe, whisper_large_v3,
            xlstm_125m, bitnet_2b]

ARCHS: dict[str, ModelConfig] = {m.CONFIG.name: m.CONFIG for m in _MODULES}

#: the ten assigned architectures (bitnet-b1.58-2b is the paper-native extra)
ASSIGNED = [n for n in ARCHS if n != "bitnet-b1.58-2b"]


def _resolve(name: str) -> str:
    """Accept module-style aliases for registry names: ``qwen3_0p6b`` →
    ``qwen3-0.6b`` (underscores are hyphens, ``p`` between digits is a
    decimal point) — so CLI flags can name archs the way the config modules
    do."""
    if name in ARCHS:
        return name
    import re

    cand = re.sub(r"(?<=\d)p(?=\d)", ".", name.replace("_", "-"))
    if cand in ARCHS:
        return cand
    raise KeyError(f"unknown arch {name!r}; available: {sorted(ARCHS)}")


def get_config(name: str) -> ModelConfig:
    return ARCHS[_resolve(name)]


def get_smoke_config(name: str, **overrides) -> ModelConfig:
    return reduced(get_config(name), **overrides)


def shape_adapted_config(cfg: ModelConfig, shape: Shape) -> ModelConfig:
    """Per-shape config adjustments (documented in DESIGN.md §5):
    zamba2's shared attention gets a 4096 sliding window at 500k context
    (the sub-quadratic adaptation for hybrid archs)."""
    if shape.name == "long_500k" and cfg.block_pattern == "zamba2":
        return cfg.with_(window=4096)
    return cfg


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def train_input_specs(cfg: ModelConfig, shape: Shape) -> dict:
    B, S = shape.global_batch, shape.seq_len
    specs = {
        "tokens": _sds((B, S), jnp.int32),
        "labels": _sds((B, S), jnp.int32),
        "loss_mask": _sds((B, S), jnp.float32),
    }
    if cfg.frontend == "audio_stub":
        specs["frames"] = _sds((B, cfg.enc_seq, cfg.d_model), jnp.bfloat16)
    if cfg.frontend == "vit_stub":
        specs["vision_embeds"] = _sds((B, cfg.vision_tokens, cfg.d_model), jnp.bfloat16)
    return specs


def prefill_input_specs(cfg: ModelConfig, shape: Shape) -> dict:
    specs = train_input_specs(cfg, shape)
    specs.pop("labels")
    specs.pop("loss_mask")
    return specs


def decode_input_specs(cfg: ModelConfig, shape: Shape) -> dict:
    """Inputs for serve_step: one new token against a seq_len cache."""
    from repro.models.decode import init_cache

    B, S = shape.global_batch, shape.seq_len
    cache = jax.eval_shape(lambda: init_cache(cfg, B, S))
    specs = {
        "tokens": _sds((B,), jnp.int32),
        "index": _sds((), jnp.int32),
        "cache": cache,
    }
    if cfg.frontend == "audio_stub":
        # cross-KV lives inside the cache; no frames needed per step
        pass
    return specs


def input_specs(arch: str, shape_name: str) -> tuple[ModelConfig, Shape, dict]:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    cfg = shape_adapted_config(cfg, shape)
    if shape.kind == "train":
        return cfg, shape, train_input_specs(cfg, shape)
    if shape.kind == "prefill":
        return cfg, shape, prefill_input_specs(cfg, shape)
    return cfg, shape, decode_input_specs(cfg, shape)


def all_cells() -> list[tuple[str, str]]:
    """Every (arch × shape) dry-run cell (skips applied per DESIGN.md)."""
    return [(a, s) for a in ASSIGNED for s in cells_for(a)]
