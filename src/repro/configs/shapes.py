"""Assigned input shapes and per-(arch × shape) dry-run cells.

Four shapes per LM architecture (assignment block):
  train_4k     seq 4,096   global_batch 256   → lowers ``train_step``
  prefill_32k  seq 32,768  global_batch 32    → lowers ``prefill``
  decode_32k   seq 32,768  global_batch 128   → lowers ``serve_step`` (1 new
                                                 token, KV cache of seq_len)
  long_500k    seq 524,288 global_batch 1     → ``serve_step``; sub-quadratic
                                                 archs only (SSM/hybrid)
"""

from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class Shape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": Shape("train_4k", 4_096, 256, "train"),
    "prefill_32k": Shape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": Shape("decode_32k", 32_768, 128, "decode"),
    "long_500k": Shape("long_500k", 524_288, 1, "decode"),
}

#: archs with O(1)-state (or windowed) decode that run the 500k cell
SUBQUADRATIC = {"zamba2-2.7b", "xlstm-125m"}


def cells_for(arch_name: str) -> list[str]:
    """Dry-run cells for an arch (long_500k only for sub-quadratic archs —
    skips documented in DESIGN.md §5)."""
    cells = ["train_4k", "prefill_32k", "decode_32k"]
    if arch_name in SUBQUADRATIC:
        cells.append("long_500k")
    return cells
