"""whisper-large-v3 [audio]: enc-dec transformer; conv/mel frontend is a STUB
per the assignment (input_specs provides precomputed frame embeddings).
[arXiv:2212.04356; unverified]  Plain (non-gated) GELU FFN, sinusoidal
positions, MHA (kv == heads).  Assigned decode shapes apply to the decoder
self-attention cache; cross-attention covers the 1500 encoder frames."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3", family="audio",
    n_layers=32, d_model=1280, n_heads=20, n_kv_heads=20, d_ff=5120,
    vocab_size=51_866, act_fn="gelu", ffn_gated=False,
    enc_layers=32, enc_seq=1500, frontend="audio_stub",
)
