"""xlstm-125m [ssm]: alternating mLSTM/sLSTM blocks, d_ff=0 (projection-only
blocks).  [arXiv:2405.04517; unverified]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-125m", family="ssm",
    n_layers=12, d_model=768, n_heads=4, n_kv_heads=4, d_ff=0,
    vocab_size=50_304, block_pattern="xlstm",
)
