"""zamba2-2.7b [hybrid]: Mamba2 backbone + shared attention block.
[arXiv:2411.15242; hf]  Shared transformer block applied every 6th Mamba2
block with reused parameters (9 invocations over 54 layers)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b", family="hybrid",
    n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32, d_ff=10_240,
    vocab_size=32_000, act_fn="silu",
    block_pattern="zamba2", ssm_state=64, ssm_expand=2, ssm_head_dim=64,
    attn_every=6,
)
