"""Core reproduction of the paper's contribution: ternary quantization, the
offline dense encoding, the two-phase LUT algorithm, the hardware generator
(netlist + functional simulator), the §IV analytical cost model, and the DSE
engine."""

from repro.core import (  # noqa: F401
    cost_model,
    dse,
    encoding,
    lut_algorithm,
    netlist,
    quantization,
)
from repro.core.generator import LUTCoreConfig, LUTCoreDesign, generate  # noqa: F401
