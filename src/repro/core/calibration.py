"""Coefficient calibration against the paper's published anchors (§V-B).

The paper calibrates ``a_add, a_mux, a_inv, a_reg`` by synthesizing isolated
unit cells in TSMC 16nm and then fits a per-dtype global factor γ.  Without
EDA tools we instead fit gate-count coefficients (inside standard-cell
plausibility bounds) so that the model reproduces the paper's *published
results*, then solve γ analytically from the absolute-area anchors:

FP16 targets
  T1  argmin_mu area(32×32) = 3                       (Fig. 5 / Table IV)
  T2  dequant-baseline / LUT(mu=3) area = 2.23        (Table IV)
  T3  sign-flip-baseline / LUT(mu=3) area = 1.64      (Table IV)
  T4  optimal geometry at fixed throughput has K > L·mu  (Fig. 8)
  A1  area(mu=3, 32×32) = 0.120 mm²                   (Table IV, sets γ_fp16)

INT8 targets
  T5  argmin_mu area(32×32) ∈ {1, 2}; area(mu=1)/area(opt) ≤ 1.15 ("minimal
      LUT benefit", Fig. 6a)
  T6  TENET (L,mu,K)=(32,2,32) within ~1% of matched-throughput optimum
      (Table V model prediction 1.004×)
  T7  TeLLMe-v2 (28,3,16) vs optimum ≈ 1.22× (soft — published number is in
      FPGA LUTs, a different cost domain; we report the ASIC-model value)
  T8  optimal geometry has L·mu > K                   (Fig. 8)
  A2  area((34,2,30)) = 33 125 µm² @16nm              (Table V, sets γ_int8)

Run ``python -m repro.core.calibration`` to re-fit and print the table; the
fitted values are installed as the defaults in ``repro.core.cost_model``.
"""

from __future__ import annotations

import numpy as np

from repro.core import cost_model as cm
from repro.core import dse

RNG = np.random.default_rng(0)

# Plausibility bounds (NAND2-equivalents) for each coefficient.
BOUNDS_FP16 = dict(a_add=(350, 1100), a_mul=(350, 1100), a_mux=(10, 36),
                   a_inv=(1, 8), a_reg=(70, 160), a_deq=(10, 60))
BOUNDS_INT8 = dict(a_add=(28, 100), a_mul=(90, 260), a_mux=(8, 20),
                   a_inv=(14, 34), a_reg=(70, 200), a_deq=(4, 24))


def _area(mu, n, m, c):
    return cm.area_gates_lut(mu, n, m, c, mode="paper")


def _score_fp16(c: cm.Coeffs) -> float:
    areas = {mu: _area(mu, 32, 32, c) for mu in range(1, 6)}
    pen = 0.0
    # strict mu=3 optimum with >=2.5% margin (robust to coefficient rounding)
    if not (areas[3] < 0.975 * areas[2] and areas[3] < 0.975 * areas[4]):
        pen += 10.0
    lut3 = areas[3]
    deq = cm.area_gates_dequant_baseline(32, 32, c)
    sf = cm.area_gates_signflip_baseline(32, 32, c)
    pen += (deq / lut3 / 2.23 - 1.0) ** 2 * 100
    pen += (sf / lut3 / 1.64 - 1.0) ** 2 * 100
    # Fig. 8 geometry: continuous-relaxation optimum n/m = sqrt(a_reg / bcoef)
    bcoef = c.a_add * 3.069**3 / (1.938 * 3)
    if c.a_reg >= bcoef:  # must favor m > n
        pen += 10.0
    # plausibility nudge: FP16 adder within ~2.6x of multiplier either way
    # (deeply pipelined FP adders carry large staging-register overhead)
    r = c.a_add / c.a_mul
    if r < 0.5 or r > 2.6:
        pen += (min(abs(r - 0.5), abs(r - 2.6))) ** 2 * 0.5
    return pen


def _score_int8(c: cm.Coeffs) -> float:
    areas = {mu: _area(mu, 32, 32, c) for mu in range(1, 6)}
    pen = 0.0
    opt = min(areas, key=areas.get)
    if opt not in (1, 2):
        pen += 10.0
    pen += max(0.0, areas[1] / areas[opt] - 1.25) ** 2 * 60
    with _temp_coeffs("int8", c):
        # T6: TENET near-optimal at matched throughput
        tenet = dse.DesignPoint(mu=2, L=32, K=32, dtype="int8")
        best = dse.optimal_config_at_throughput(2048, "int8")
        ratio_tenet = (tenet.area_gates() / tenet.throughput) / \
                      (best.area_gates() / best.throughput)
        tellme = dse.DesignPoint(mu=3, L=28, K=16, dtype="int8")
        best_t = dse.optimal_config_at_throughput(1344, "int8")
        ratio_tellme = (tellme.area_gates() / tellme.throughput) / \
                       (best_t.area_gates() / best_t.throughput)
        # T8: discrete geometry optimum must favor L*mu > K (Fig. 8)
        for tgt in (1024, 2048):
            g = dse.optimal_geometry(tgt, "int8")
            if g.n <= g.m:
                pen += 5.0
    pen += (ratio_tenet / 1.004 - 1.0) ** 2 * 60
    pen += (ratio_tellme / 1.22 - 1.0) ** 2 * 8  # soft (FPGA domain)
    return pen


class _temp_coeffs:
    def __init__(self, dtype, c):
        self.dtype, self.c = dtype, c

    def __enter__(self):
        self.old = cm.COEFFS[self.dtype]
        cm.COEFFS[self.dtype] = self.c

    def __exit__(self, *a):
        cm.COEFFS[self.dtype] = self.old


def _sample(bounds, base=None, jitter=0.0):
    out = {}
    for k, (lo, hi) in bounds.items():
        if base is None:
            out[k] = RNG.uniform(lo, hi)
        else:
            span = (hi - lo) * jitter
            out[k] = float(np.clip(base[k] + RNG.uniform(-span, span), lo, hi))
    return out


def fit(dtype: str, n_random: int = 3000, n_refine: int = 1500) -> cm.Coeffs:
    bounds = BOUNDS_FP16 if dtype == "fp16" else BOUNDS_INT8
    score = _score_fp16 if dtype == "fp16" else _score_int8
    best_kw, best_s = None, np.inf
    for _ in range(n_random):
        kw = _sample(bounds)
        s = score(cm.Coeffs(name=dtype, gamma=1.0, **kw))
        if s < best_s:
            best_kw, best_s = kw, s
    for i in range(n_refine):
        kw = _sample(bounds, base=best_kw, jitter=0.15 * (1 - i / n_refine) + 0.01)
        s = score(cm.Coeffs(name=dtype, gamma=1.0, **kw))
        if s < best_s:
            best_kw, best_s = kw, s
    c = cm.Coeffs(name=dtype, gamma=1.0, **{k: round(v, 1) for k, v in best_kw.items()})
    # γ from the absolute anchor.
    if dtype == "fp16":
        raw = cm.area_mm2(_area(3, 32, 32, c), c)  # gamma=1
        gamma = 0.120 / raw
    else:
        raw = cm.area_um2(_area(2, 68, 30, c), c)
        gamma = 33_125.0 / raw
    c = cm.Coeffs(name=dtype, gamma=round(float(gamma), 4),
                  **{k: round(v, 1) for k, v in best_kw.items()})
    return c, best_s


def report(c: cm.Coeffs) -> None:
    print(f"== {c.name} ==  {c}")
    with _temp_coeffs(c.name, c):
        areas = {mu: _area(mu, 32, 32, c) for mu in range(1, 6)}
        opt = min(areas, key=areas.get)
        print(f"  argmin mu @32x32: {opt}; rel areas:",
              {mu: round(a / areas[opt], 3) for mu, a in areas.items()})
        if c.name == "fp16":
            lut3 = areas[3]
            print(f"  dequant ratio  = {cm.area_gates_dequant_baseline(32,32,c)/lut3:.3f}  (paper 2.23)")
            print(f"  signflip ratio = {cm.area_gates_signflip_baseline(32,32,c)/lut3:.3f}  (paper 1.64)")
            print(f"  area(mu=3,32x32) = {cm.lut_core_area_mm2(3,32,32,'fp16'):.4f} mm^2  (paper 0.120)")
        else:
            tenet = dse.DesignPoint(mu=2, L=32, K=32, dtype="int8")
            best = dse.optimal_config_at_throughput(2048, "int8")
            print(f"  TENET ratio = {tenet.area_gates()/best.area_gates():.4f} (paper 1.004), "
                  f"opt={best.mu,best.L,best.K}")
            tellme = dse.DesignPoint(mu=3, L=28, K=16, dtype="int8")
            best_t = dse.optimal_config_at_throughput(1344, "int8")
            print(f"  TeLLMe ratio = {tellme.area_gates()/best_t.area_gates():.4f} (paper 1.22 in FPGA LUTs), "
                  f"opt={best_t.mu,best_t.L,best_t.K}")
            print(f"  area((34,2,30)) = {dse.DesignPoint(mu=2,L=34,K=30,dtype='int8').area_um2():.0f} um^2 (paper 33125)")
        g = dse.optimal_geometry(1024, c.name)
        print(f"  optimal geometry @1024: n={g.n} m={g.m} mu={g.mu} "
              f"({'K>L*mu' if g.m > g.n else 'L*mu>K'})")


def main():
    for dtype in ("fp16", "int8"):
        c, s = fit(dtype)
        cm.COEFFS[dtype] = c
        print(f"fit score {s:.4f}")
        report(c)
        print(f"  -> install in cost_model.py: {c!r}")


if __name__ == "__main__":
    main()
