"""Companion analytical cost model (paper §IV, Eqs. 5-10).

Area is expressed in NAND2-gate-equivalents of the fundamental unit cells
(a technology-neutral proxy for the paper's unit-cell synthesis runs), scaled
by a per-technology gate area and a per-dtype global factor ``gamma`` — the
same two-stage calibration the paper performs against TSMC-16nm synthesis.

Because this container has no EDA tools, the unit-cell coefficients are
calibrated (``repro.core.calibration``) against the paper's *published
anchors*:

  * Table IV: 32×32 FP16 tile, optimal mu=3 → 0.120 mm²; dequant baseline
    2.23× larger; sign-flip baseline 1.64× larger.
  * Fig. 5/6: optimal mu = 3 for FP16 at 32×32; INT8 nearly flat in mu.
  * Fig. 8: FP16 optimum has K > L·mu; INT8 optimum has L·mu > K.
  * Table V: (L,mu,K) = (34,2,30) INT8 @ 16nm → 33 125 µm².

The *formulas* below are the paper's, verbatim; only the coefficients are
fit.  ``mode="exact"`` swaps Eq. 5's curve fit for the exact constructive
netlist counts of :mod:`repro.core.netlist`.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, replace

from repro.core import netlist as nl
from repro.core.encoding import table_size

# ---------------------------------------------------------------------------
# Technology constants
# ---------------------------------------------------------------------------

#: µm² per NAND2-equivalent gate, TSMC16-class high-density library.
UM2_PER_GATE_16NM = 0.20

#: Stillmaker-Baas 28nm → 16nm scaling (paper Table V footnote [18]).
SCALE_28_TO_16_AREA = 0.41
SCALE_28_TO_16_DELAY = 0.62

#: Clock targets used for TOPS/mm² (paper: 500 MHz synthesis, 800 MHz @16nm).
F_CLK_SYNTH = 500e6
F_CLK_16NM = 800e6


@dataclass(frozen=True)
class Coeffs:
    """Unit-cell areas in NAND2-equivalents (paper §IV-B coefficients)."""

    name: str
    a_add: float   # scalar adder of the activation dtype (pipelined)
    a_mul: float   # scalar multiplier (dequant baseline only)
    a_mux: float   # word-sized 2:1 mux
    a_inv: float   # sign-inversion overhead, amortized per mux unit (Eq. 9)
    a_reg: float   # word-sized register
    a_deq: float   # ternary→word dequant cell (dequant baseline only)
    gamma: float   # per-dtype global scaling factor (paper §V-B)


# Calibrated by repro/core/calibration.py (targets listed in module docstring).
# Fit report (2026-07-14): FP16 — argmin mu @32x32 = 3 ✓, dequant ratio 2.239
# (paper 2.23), signflip ratio 1.693 (paper 1.64), abs area 0.1200 mm² ✓,
# geometry K > L·mu ✓.  INT8 — argmin mu = 2 with mu=1 within 13.4%
# ("minimal LUT benefit"), TENET ratio 1.015 (paper 1.004), abs 33126 µm²
# (paper 33125) ✓, geometry L·mu > K ✓, TeLLMe 1.595 (paper reports 1.22 in
# FPGA-LUT units — different cost domain, see DESIGN.md).
# Provenance: gate counts are within standard-cell plausibility ranges
# (deeply pipelined FP16 adder carries large staging-flop overhead; INT8
# adder ≈ tens of gates; registers ≈ 5-6 gates/bit incl. enable).
FP16 = Coeffs(name="fp16", a_add=1041.2, a_mul=393.0, a_mux=24.4, a_inv=7.3,
              a_reg=150.6, a_deq=18.7, gamma=0.9002)
INT8 = Coeffs(name="int8", a_add=72.6, a_mul=150.8, a_mux=8.0, a_inv=14.0,
              a_reg=200.0, a_deq=11.1, gamma=0.911)

COEFFS = {"fp16": FP16, "int8": INT8}


def get_coeffs(dtype: str) -> Coeffs:
    return COEFFS[dtype.lower()]


def set_coeffs(dtype: str, **kw) -> None:
    """Used by calibration to install fitted coefficients."""
    COEFFS[dtype.lower()] = replace(COEFFS[dtype.lower()], **kw)


# ---------------------------------------------------------------------------
# Scaling formulas (Eqs. 5-8) — unit counts, no coefficients
# ---------------------------------------------------------------------------


def build_cost(mu: int, n: int, mode: str = "paper") -> float:
    """Eq. 5: Build+ adders ≈ (3.069^mu / 1.938) · (n/mu).

    ``mode="exact"`` uses the constructive netlist count; ``mode="bound"``
    uses Eq. 2's closed-form bound.
    """
    n_luts = n / mu
    if mode == "paper":
        return (3.069**mu / 1.938) * n_luts
    if mode == "bound":
        return nl.bound_adders(mu) * n_luts
    if mode == "exact":
        return nl.constructive_adders(mu) * n_luts
    raise ValueError(mode)


def accumulate_cost(mu: int, n: int, m: int) -> float:
    """Eq. 6: L·K = n·m/mu accumulate adders."""
    return n * m / mu


def mux_cost(mu: int, n: int, m: int) -> float:
    """Eq. 7: (n·m/mu) · (3^mu - 1)/2 two-to-one mux equivalents."""
    return (n * m / mu) * table_size(mu)


def outreg_cost(m: int) -> float:
    """Eq. 8: K = m output accumulator registers."""
    return float(m)


# ---------------------------------------------------------------------------
# Area model (Eq. 9) and baselines (§VI-A, Fig. 1)
# ---------------------------------------------------------------------------


def area_gates_lut(mu: int, n: int, m: int, c: Coeffs, mode: str = "paper",
                   include_lut_regs: bool = False) -> float:
    """Eq. 9 in NAND2-equivalents.  ``include_lut_regs`` adds explicit LUT
    storage registers (beyond-paper refinement; the paper folds them into γ)."""
    a = c.a_add * (build_cost(mu, n, mode) + accumulate_cost(mu, n, m))
    a += (c.a_mux + c.a_inv) * mux_cost(mu, n, m)
    a += c.a_reg * outreg_cost(m)
    if include_lut_regs:
        a += c.a_reg * table_size(mu) * (n / mu)
    return a


def area_gates_dequant_baseline(n: int, m: int, c: Coeffs) -> float:
    """Fig. 1 left: dequantize ternary→word, full-width multiply, accumulate."""
    return n * m * (c.a_mul + c.a_add + c.a_deq) + c.a_reg * m


def area_gates_signflip_baseline(n: int, m: int, c: Coeffs) -> float:
    """Fig. 1 middle: 3:1 mux (x, -x, 0) + accumulate adder per PE.

    A 3:1 word mux ≈ 2 two-to-one muxes; the -x arm needs the dtype's sign
    inversion (cheap for FP16 sign bit, an adder-class negate for INT8).
    """
    per_pe = c.a_add + 2 * c.a_mux + c.a_inv
    return n * m * per_pe + c.a_reg * m


def area_um2(gates: float, c: Coeffs, um2_per_gate: float = UM2_PER_GATE_16NM) -> float:
    return gates * um2_per_gate * c.gamma


def area_mm2(gates: float, c: Coeffs) -> float:
    return area_um2(gates, c) / 1e6


def lut_core_area_mm2(mu: int, n: int, m: int, dtype: str, mode: str = "paper") -> float:
    c = get_coeffs(dtype)
    return area_mm2(area_gates_lut(mu, n, m, c, mode), c)


# ---------------------------------------------------------------------------
# Derived metrics (Eq. 1, Eq. 10)
# ---------------------------------------------------------------------------


def throughput_mul_per_cycle(n: int, m: int) -> int:
    return n * m


def tops(n: int, m: int, f_clk: float = F_CLK_16NM) -> float:
    """Tera-ops/s counting each ternary MAC as 2 ops."""
    return 2 * n * m * f_clk / 1e12


def area_per_throughput(mu: int, n: int, m: int, c: Coeffs, mode: str = "paper") -> float:
    """Eq. 10: gates per (mul/cycle).  Overhead terms vanish as 1/m and 1/n."""
    return area_gates_lut(mu, n, m, c, mode) / (n * m)


def tops_per_mm2(mu: int, n: int, m: int, dtype: str, f_clk: float = F_CLK_16NM,
                 mode: str = "paper") -> float:
    return tops(n, m, f_clk) / lut_core_area_mm2(mu, n, m, dtype, mode)


def optimal_mu(n: int, m: int, dtype: str, mu_range=range(1, 7), mode: str = "paper") -> int:
    c = get_coeffs(dtype)
    return min(mu_range, key=lambda mu: area_gates_lut(mu, n, m, c, mode))


def roundtrip_16nm_from_28nm(area_um2_28: float) -> float:
    """Scale a published 28nm area to 16nm (Stillmaker-Baas, as in Table V)."""
    return area_um2_28 * SCALE_28_TO_16_AREA


def breakdown(mu: int, n: int, m: int, dtype: str, mode: str = "paper") -> dict:
    """Per-submodule area split (Fig. 5a reproduction)."""
    c = get_coeffs(dtype)
    parts = {
        "build_add": c.a_add * build_cost(mu, n, mode),
        "accumulate_add": c.a_add * accumulate_cost(mu, n, m),
        "mux": (c.a_mux + c.a_inv) * mux_cost(mu, n, m),
        "out_reg": c.a_reg * outreg_cost(m),
    }
    um2 = {k: area_um2(v, c) for k, v in parts.items()}
    um2["total"] = sum(um2.values())
    return um2


def power_proxy_breakdown(mu: int, n: int, m: int, dtype: str) -> dict:
    """Fig. 5b: the paper finds VCD power tracks area with the same optimum.

    We model power as area × activity (builds toggle every tile; muxes/regs
    toggle every cycle) — a documented proxy, reported alongside area.
    """
    act = {"build_add": 1.0, "accumulate_add": 1.0, "mux": 0.8, "out_reg": 0.6}
    um2 = breakdown(mu, n, m, dtype)
    mw = {k: um2[k] * act.get(k, 1.0) for k in act}
    mw["total"] = sum(mw.values())
    return mw
