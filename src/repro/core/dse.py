"""Design-space exploration engine (paper §VI-VII).

Sweeps the ``(mu, L, K, dtype)`` space with the analytical cost model,
reproduces the paper's exploration figures/tables, and re-derives the
state-of-the-art comparison (Table V): given a published design's throughput,
find the area-optimal configuration at matched throughput and report the
model-predicted improvement.
"""

from __future__ import annotations

import math
from dataclasses import dataclass

from repro.core import cost_model as cm


@dataclass(frozen=True)
class DesignPoint:
    mu: int
    L: int
    K: int
    dtype: str

    @property
    def n(self) -> int:
        return self.L * self.mu

    @property
    def m(self) -> int:
        return self.K

    @property
    def throughput(self) -> int:
        return self.n * self.m

    def area_gates(self, mode: str = "paper") -> float:
        return cm.area_gates_lut(self.mu, self.n, self.m, cm.get_coeffs(self.dtype), mode)

    def area_mm2(self, mode: str = "paper") -> float:
        return cm.lut_core_area_mm2(self.mu, self.n, self.m, self.dtype, mode)

    def area_um2(self, mode: str = "paper") -> float:
        return self.area_mm2(mode) * 1e6

    def tops_per_mm2(self, f_clk: float = cm.F_CLK_16NM, mode: str = "paper") -> float:
        return cm.tops(self.n, self.m, f_clk) / self.area_mm2(mode)


def sweep_square_tiles(tile_sizes=(8, 32, 64, 96), mus=(1, 2, 3, 4, 5),
                       dtypes=("int8", "fp16"), mode: str = "paper") -> list[dict]:
    """The Table III grid: square tiles × group sizes × dtypes."""
    out = []
    for dt in dtypes:
        for t in tile_sizes:
            for mu in mus:
                if t % mu:
                    continue  # L = n/mu must be integral
                p = DesignPoint(mu=mu, L=t // mu, K=t, dtype=dt)
                out.append({
                    "dtype": dt, "tile": t, "mu": mu, "L": p.L, "K": p.K,
                    "area_mm2": p.area_mm2(mode),
                    "tops_per_mm2": p.tops_per_mm2(mode=mode),
                })
    return out


def optimal_mu_for_tile(n: int, m: int, dtype: str, mus=range(1, 6), mode="paper") -> int:
    valid = [mu for mu in mus if n % mu == 0]
    return min(valid, key=lambda mu: cm.area_gates_lut(mu, n, m, cm.get_coeffs(dtype), mode))


def optimal_config_at_throughput(target: int, dtype: str, tol: float = 0.02,
                                 mus=range(1, 6), mode: str = "paper") -> DesignPoint:
    """Area-optimal (L, mu, K) whose throughput is within ``tol`` of target
    without exceeding it (the paper matches from below: 2040 ≤ 2048,
    1334 ≤ 1344).  Vectorized with numpy: the calibration loop calls this
    thousands of times."""
    import numpy as np

    c = cm.get_coeffs(dtype)
    best = None
    best_area = math.inf
    for mu in mus:
        K = np.arange(1, target // mu + 1)
        L_hi = target // (mu * K)
        # candidate L values: floor and floor-1 (throughput from below)
        for L in (L_hi, np.maximum(L_hi - 1, 1)):
            t = L * mu * K
            ok = (t >= target * (1 - tol)) & (t <= target) & (L >= 1)
            if not ok.any():
                continue
            Lv, Kv = L[ok], K[ok]
            n = Lv * mu
            m = Kv
            if mode == "paper":
                badd = (3.069**mu / 1.938) * (n / mu)
            else:
                from repro.core import netlist as nl
                per = nl.constructive_adders(mu) if mode == "exact" else nl.bound_adders(mu)
                badd = per * (n / mu)
            T = (3**mu - 1) // 2
            area = (c.a_add * (badd + n * m / mu)
                    + (c.a_mux + c.a_inv) * (n * m / mu) * T
                    + c.a_reg * m)
            # Normalize by achieved throughput so the within-tolerance band
            # does not bias toward lower-throughput (hence smaller) designs.
            eff = area / t[ok]
            i = int(np.argmin(eff))
            if eff[i] < best_area:
                best_area = float(eff[i])
                best = DesignPoint(mu=mu, L=int(Lv[i]), K=int(Kv[i]), dtype=dtype)
    assert best is not None
    return best


def optimal_geometry(throughput: int, dtype: str, mus=range(1, 6),
                     mode: str = "paper") -> DesignPoint:
    """Unconstrained-aspect optimum at ~exact throughput (Fig. 8)."""
    return optimal_config_at_throughput(throughput, dtype, tol=0.05, mus=mus, mode=mode)


def geometry_sweep(throughput: int, dtype: str, mode: str = "paper") -> list[dict]:
    """Fig. 8: area across aspect ratios at fixed throughput, each point using
    its own optimal mu.  Returns records with n, m, mu, area and Δ vs square."""
    recs = []
    for m in range(4, throughput // 4 + 1):
        n = throughput // m
        if n * m != throughput or n < 4:
            continue
        mus = [mu for mu in range(1, 6) if n % mu == 0]
        if not mus:
            continue
        mu = min(mus, key=lambda u: cm.area_gates_lut(u, n, m, cm.get_coeffs(dtype), mode))
        recs.append({
            "n": n, "m": m, "mu": mu, "aspect": n / m,
            "area_mm2": cm.lut_core_area_mm2(mu, n, m, dtype, mode),
        })
    side = int(round(math.sqrt(throughput)))
    square = min(recs, key=lambda r: abs(r["n"] - side))
    for r in recs:
        r["delta_vs_square"] = 1.0 - r["area_mm2"] / square["area_mm2"]
    return recs


# ---------------------------------------------------------------------------
# State-of-the-art reconfiguration (Table V)
# ---------------------------------------------------------------------------

#: Published designs (paper Table II / V).  TeLLMe-v2's "ours" row lists
#: (26,2,23) with throughput 1334; 26·2·23 = 1196 ≠ 1334 while 29·2·23 = 1334,
#: so we take L=29 as the intended value (typo in the paper) and report both.
SOTA = {
    "tenet": dict(L=32, mu=2, K=32, dtype="int8", tech="28nm",
                  area_um2=640_000.0, throughput=2048),
    "tellme_v2": dict(L=28, mu=3, K=16, dtype="int8", tech="fpga",
                      area_lut=35_200, throughput=1344),
    "slim_llama": dict(L=8, mu=3, K=2, dtype="int8", tech="28nm",
                       throughput=48),
    "figlut": dict(L=32, mu=4, K=32, dtype="fp16", tech=None, throughput=4096),
}


def sota_comparison(mode: str = "paper") -> list[dict]:
    """Reproduce Table V: for each published design, find the model-optimal
    matched-throughput configuration and the predicted area ratio."""
    rows = []
    for name, spec in SOTA.items():
        theirs = DesignPoint(mu=spec["mu"], L=spec["L"], K=spec["K"], dtype=spec["dtype"])
        ours = optimal_config_at_throughput(spec["throughput"], spec["dtype"], mode=mode)
        ratio = theirs.area_gates(mode) / ours.area_gates(mode)
        row = {
            "work": name,
            "theirs": (theirs.L, theirs.mu, theirs.K),
            "theirs_throughput": theirs.throughput,
            "ours": (ours.L, ours.mu, ours.K),
            "ours_throughput": ours.throughput,
            "model_prediction": ratio,
            "ours_area_um2": ours.area_um2(mode),
        }
        if spec.get("tech") == "28nm" and "area_um2" in spec:
            row["theirs_area_16nm_um2"] = cm.roundtrip_16nm_from_28nm(spec["area_um2"])
            row["area_decrease_vs_published"] = row["theirs_area_16nm_um2"] / row["ours_area_um2"]
        rows.append(row)
    return rows


def frontier(dtype: str, throughputs=(256, 512, 1024, 2048, 4096), mode="paper") -> list[dict]:
    """Efficiency frontier: optimal design per throughput target."""
    out = []
    for t in throughputs:
        p = optimal_config_at_throughput(t, dtype, mode=mode)
        out.append({"throughput": t, "mu": p.mu, "L": p.L, "K": p.K,
                    "n": p.n, "m": p.m, "area_mm2": p.area_mm2(mode),
                    "tops_per_mm2": p.tops_per_mm2(mode=mode)})
    return out
