"""Offline dense ternary weight encoding (paper §III-D) + byte packings.

The paper encodes a group of ``mu`` ternary weights as one key of width
``ceil(log2((3^mu - 1)/2)) + 1`` bits: the MSB is a *symmetry flag* (fetch the
stored positive-half entry and invert), the low bits are the MUX select index.
At ``mu = 5`` this is 8 bits / 5 weights = **1.600 bits per weight**, within 1%
of the information-theoretic ``log2(3) ≈ 1.585`` and 20% denser than a naive
2-bit encoding — the paper's bandwidth claim.

Canonical enumeration used throughout this repo (encoder, oracle, kernels,
netlist and simulator must all agree):

* a ternary combo ``c ∈ {-1,0,+1}^mu`` maps to the base-3 value
  ``v = Σ_i (c_i + 1) · 3^i``  (weight position ``i`` = base-3 digit ``i``);
* ``center = (3^mu - 1)/2`` is the all-zero combo; a combo and its negation
  satisfy ``v + v' = 3^mu - 1``;
* the stored *positive half* is ``v > center``, table index
  ``idx = v - center - 1 ∈ [0, T)`` with ``T = (3^mu - 1)/2``;
* key = ``sym << idx_bits | idx``.  The all-zero group is given the reserved
  index ``T`` (the fetch path hardwires entry ``T`` to 0).

Faithfulness note: reserving an index for the all-zero group makes the exact
key width ``ceil(log2(T + 1)) + 1``.  This equals the paper's formula for
``mu ∈ {3,4,5,...}`` (e.g. mu=5 → 8 bits, mu=3 → 5 bits, matching §III-D) but
is one bit wider at ``mu ∈ {1,2}``, where the paper's width cannot represent
the all-zero group distinctly.  We keep exact representability and report both
widths.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np


def table_size(mu: int) -> int:
    """T = number of stored (positive-half) LUT entries."""
    return (3**mu - 1) // 2


def idx_bits(mu: int) -> int:
    """Bits for the MUX select index (zero-group representable)."""
    return max(1, int(np.ceil(np.log2(table_size(mu) + 1))))


def key_bits(mu: int) -> int:
    """Exact key width: index bits + symmetry bit."""
    return idx_bits(mu) + 1


def key_bits_paper(mu: int) -> int:
    """The paper's §III-D width formula, ceil(log2(T)) + 1."""
    return max(1, int(np.ceil(np.log2(table_size(mu))))) + 1


def bits_per_weight(mu: int, paper_formula: bool = False) -> float:
    return (key_bits_paper(mu) if paper_formula else key_bits(mu)) / mu


def key_dtype(mu: int):
    return jnp.uint8 if key_bits(mu) <= 8 else jnp.uint16


@functools.lru_cache(maxsize=None)
def combo_matrix_np(mu: int) -> np.ndarray:
    """[T+1, mu] int8: row t = the ternary combo stored at table index t.

    Row ``T`` (the reserved zero entry) is all zeros.  The LUT *build phase*
    is exactly ``table = x_groups @ C.T`` — this matrix IS the adder tree's
    functional specification.
    """
    T = table_size(mu)
    center = T  # (3^mu - 1)/2
    vals = np.arange(center + 1, 3**mu, dtype=np.int64)  # positive half
    digits = np.stack([(vals // 3**i) % 3 - 1 for i in range(mu)], axis=1)
    out = np.concatenate([digits, np.zeros((1, mu), dtype=np.int64)], axis=0)
    return out.astype(np.int8)


def combo_matrix(mu: int) -> jax.Array:
    return jnp.asarray(combo_matrix_np(mu))


# ---------------------------------------------------------------------------
# Group-key encoding (the paper's offline encoding)
# ---------------------------------------------------------------------------


def encode_groups(w_t: jax.Array, mu: int) -> jax.Array:
    """Encode ternary weights into group keys.

    Args:
      w_t: int8 in {-1,0,1}, shape ``[..., G, mu]`` (group the caller's last
        weight dim into ``G = N/mu`` groups of ``mu``).
      mu:  group size.

    Returns:
      keys, uint8/uint16, shape ``[..., G]``.
    """
    T = table_size(mu)
    center = T
    powers = jnp.asarray([3**i for i in range(mu)], dtype=jnp.int32)
    v = jnp.sum((w_t.astype(jnp.int32) + 1) * powers, axis=-1)  # [..., G]
    sym = (v < center).astype(jnp.int32)
    v_pos = jnp.where(sym == 1, (3**mu - 1) - v, v)
    idx = jnp.where(v_pos == center, T, v_pos - center - 1)  # zero-group -> T
    sym = jnp.where(v_pos == center, 0, sym)
    key = (sym << idx_bits(mu)) | idx
    return key.astype(key_dtype(mu))


def decode_groups(keys: jax.Array, mu: int) -> jax.Array:
    """Inverse of :func:`encode_groups` → int8 trits ``[..., G, mu]``."""
    C = combo_matrix(mu)  # [T+1, mu]
    ib = idx_bits(mu)
    k = keys.astype(jnp.int32)
    sym = k >> ib
    idx = k & ((1 << ib) - 1)
    trits = C[idx]  # [..., G, mu]
    sign = jnp.where(sym == 1, -1, 1).astype(jnp.int8)[..., None]
    return (trits * sign).astype(jnp.int8)


def split_key(keys: jax.Array, mu: int) -> tuple[jax.Array, jax.Array]:
    """(sym, idx) int32 views of a key array."""
    ib = idx_bits(mu)
    k = keys.astype(jnp.int32)
    return k >> ib, k & ((1 << ib) - 1)


def encode_weight_matrix(w_t: jax.Array, mu: int) -> jax.Array:
    """[O, N] ternary → [O, N/mu] keys (N padded to a multiple of mu with 0)."""
    O, N = w_t.shape
    pad = (-N) % mu
    if pad:
        w_t = jnp.pad(w_t, ((0, 0), (0, pad)))
    return encode_groups(w_t.reshape(O, (N + pad) // mu, mu), mu)


# ---------------------------------------------------------------------------
# Base-3 byte packing (deployment/storage format, 1.6 bits/weight exactly)
# ---------------------------------------------------------------------------

TRITS_PER_BYTE = 5  # 3^5 = 243 <= 256


def pack_base3(w_t: jax.Array) -> jax.Array:
    """Pack ternary {-1,0,1} → uint8, 5 trits/byte along the last axis.

    Last axis is zero-padded to a multiple of 5.  1.6 bits/weight — identical
    density to the paper's mu=5 group encoding, used as the HBM storage format
    for the serving path ("the memory-bound decode stage", §I).
    """
    *lead, N = w_t.shape
    pad = (-N) % TRITS_PER_BYTE
    if pad:
        w_t = jnp.pad(w_t, [(0, 0)] * len(lead) + [(0, pad)])
    grp = w_t.reshape(*lead, -1, TRITS_PER_BYTE).astype(jnp.int32) + 1
    powers = jnp.asarray([3**i for i in range(TRITS_PER_BYTE)], dtype=jnp.int32)
    return jnp.sum(grp * powers, axis=-1).astype(jnp.uint8)


@functools.lru_cache(maxsize=None)
def _base3_decode_table() -> np.ndarray:
    """[256, 5] int8 decode LUT: byte value → 5 trits (LUT-style decode)."""
    vals = np.arange(256, dtype=np.int64)
    digits = np.stack([(vals // 3**i) % 3 - 1 for i in range(TRITS_PER_BYTE)], axis=1)
    return digits.astype(np.int8)


def unpack_base3(packed: jax.Array, n: int) -> jax.Array:
    """uint8 [..., ceil(n/5)] → int8 trits [..., n].

    Decoding is itself a lookup (a 256×5 table) — the software analogue of the
    paper's LUT-based read-out, and cheap on the TPU VPU.
    """
    return unpack_base3_to(packed, n, jnp.int8)


def unpack_base3_to(packed: jax.Array, n: int, dtype) -> jax.Array:
    """uint8 [..., ceil(n/5)] → trits [..., n] directly in ``dtype``.

    Typing the decode table at the compute dtype makes the whole decode ONE
    gather — no int8 intermediate and no upcast pass over the dense matrix,
    which on XLA backends roughly halves the decode cost of the streaming
    paths (the int8 table is the ``dtype=int8`` special case).
    """
    tbl = jnp.asarray(_base3_decode_table(), dtype)
    trits = tbl[packed.astype(jnp.int32)]  # [..., B, 5]
    trits = trits.reshape(*packed.shape[:-1], -1)
    return trits[..., :n]


def pack_2bit(w_t: jax.Array) -> jax.Array:
    """Naive 2-bit packing (baseline for the 20% bandwidth claim)."""
    *lead, N = w_t.shape
    pad = (-N) % 4
    if pad:
        w_t = jnp.pad(w_t, [(0, 0)] * len(lead) + [(0, pad)])
    grp = (w_t.reshape(*lead, -1, 4).astype(jnp.int32) + 1) & 0b11
    shifts = jnp.asarray([0, 2, 4, 6], dtype=jnp.int32)
    return jnp.sum(grp << shifts, axis=-1).astype(jnp.uint8)


def unpack_2bit(packed: jax.Array, n: int) -> jax.Array:
    shifts = jnp.asarray([0, 2, 4, 6], dtype=jnp.int32)
    trits = ((packed.astype(jnp.int32)[..., None] >> shifts) & 0b11) - 1
    trits = trits.reshape(*packed.shape[:-1], -1)
    return trits[..., :n].astype(jnp.int8)


@dataclass(frozen=True)
class PackedTernary:
    """A ternary weight matrix in deployment form.

    ``data`` is uint8 base-3 packed along the *input* (reduction) dim so the
    decode→matmul path streams it contiguously; ``scale`` is the BitNet
    absmean scale (per-tensor scalar or per-out-channel vector).
    ``shape`` is the logical (out, in) shape.
    """

    data: jax.Array  # uint8 [O, ceil(N/5)]
    scale: jax.Array
    shape: tuple[int, int]

    @property
    def bits_per_weight(self) -> float:
        return self.data.size * 8 / (self.shape[0] * self.shape[1])


def pack_ternary_matrix(w_t: jax.Array, scale: jax.Array) -> PackedTernary:
    O, N = w_t.shape
    return PackedTernary(data=pack_base3(w_t), scale=scale, shape=(O, N))


def unpack_ternary_matrix(p: PackedTernary) -> jax.Array:
    return unpack_base3(p.data, p.shape[1])
