"""LUT-core hardware generator (paper §III) — JAX/TPU edition.

The paper's generator emits Chisel RTL for any ``(mu, L, K, dtype)`` point.
Ours emits, for the same design point:

  1. a structural :class:`~repro.core.netlist.Netlist` with the three LUT
     optimizations applied exactly (consumed by the cost model and the
     functional simulator — the "RTL"),
  2. an area/throughput report from the §IV cost model,
  3. a *kernel plan*: the Pallas launch geometry (BlockSpec tile shapes) that
     realizes the same tiling on a TPU, where ``L·mu`` maps to the reduction
     block and ``K`` to the output block,
  4. a human-readable module hierarchy (Fig. 3) for documentation/tests.

This is the single entry point the rest of the framework uses: model configs
carry a ``LUTCoreConfig`` and the serving path asks it for the kernel plan.
"""

from __future__ import annotations

from dataclasses import dataclass, field

from repro.core import cost_model as cm
from repro.core import netlist as nl
from repro.core.encoding import key_bits, table_size


@dataclass(frozen=True)
class LUTCoreConfig:
    """A point in the paper's design space."""

    mu: int = 3
    L: int = 32
    K: int = 32
    act_dtype: str = "fp16"  # cost-model domain: "fp16" | "int8"

    def __post_init__(self):
        if not (1 <= self.mu <= 8):
            raise ValueError(f"mu={self.mu} out of supported range [1, 8]")
        if self.L < 1 or self.K < 1:
            raise ValueError("L and K must be >= 1")
        if self.act_dtype not in cm.COEFFS:
            raise ValueError(f"unknown activation dtype {self.act_dtype!r}")

    @property
    def n(self) -> int:
        return self.L * self.mu

    @property
    def m(self) -> int:
        return self.K

    @property
    def tile(self) -> tuple[int, int]:
        return (self.n, self.m)

    @property
    def throughput_mul_per_cycle(self) -> int:
        return self.n * self.m


@dataclass(frozen=True)
class KernelPlan:
    """Pallas launch geometry derived from the core config.

    ``block_n`` (reduction) and ``block_m`` (outputs) are the VMEM tile shape;
    they are hardware-aligned multiples of the core tile so one kernel "step"
    corresponds to an integral number of core cycles.
    """

    mu: int
    block_n: int
    block_m: int
    table_entries: int  # (3^mu - 1)/2 + 1 (reserved zero row)
    key_bits: int

    @property
    def vmem_table_words(self) -> int:
        return (self.block_n // self.mu) * self.table_entries


@dataclass(frozen=True)
class LUTCoreDesign:
    """Everything the generator knows about one instantiated design point."""

    config: LUTCoreConfig
    netlist: nl.Netlist
    build_program: nl.BuildProgram = field(repr=False)
    area_mm2: float
    tops_per_mm2: float
    kernel_plan: KernelPlan

    def module_hierarchy(self) -> str:
        """Fig. 3 block diagram as text (what the Chisel generator elaborates)."""
        c = self.config
        T = table_size(c.mu)
        return "\n".join([
            f"LutCore_u{c.mu}_L{c.L}_K{c.K}_{c.act_dtype}",
            f"├── ActivationBuffer[{c.n} x {c.act_dtype}]",
            f"├── LutArray[L={c.L}]",
            f"│   ├── BuildAdderTree(mu={c.mu}, adders={self.netlist.build_adders // c.L},"
            f" depth={self.netlist.build_pipeline_depth})   # symmetry+redundancy+sparsity",
            f"│   └── EntryRegisters[{T} x {c.act_dtype}]  (+ hardwired zero entry)",
            f"├── FacArray[K={c.K}]",
            f"│   ├── ReadoutMux[{T + 1}:1] x {c.L}   (key = {key_bits(c.mu)}b: 1 sym + idx)",
            f"│   ├── SignFlip x {c.L}",
            f"│   └── ReductionAdderTree[L={c.L}] + Accumulate",
            f"└── OutputBuffer[{c.K} x acc]",
        ])

    def report(self) -> str:
        c = self.config
        return (
            f"{self.netlist.summary()}\n"
            f"  area      : {self.area_mm2 * 1e6:,.0f} um^2 ({self.area_mm2:.4f} mm^2)\n"
            f"  peak      : {cm.tops(c.n, c.m):.3f} TOPS @ {cm.F_CLK_16NM/1e6:.0f} MHz"
            f" -> {self.tops_per_mm2:.1f} TOPS/mm^2\n"
            f"  encoding  : {key_bits(c.mu)} bits / {c.mu} weights"
            f" = {key_bits(c.mu)/c.mu:.3f} b/w"
        )


def generate(config: LUTCoreConfig, mode: str = "paper") -> LUTCoreDesign:
    """Instantiate a design point (the generator's main entry)."""
    net = nl.make_netlist(config.mu, config.L, config.K)
    prog = nl.build_program(config.mu)
    area = cm.lut_core_area_mm2(config.mu, config.n, config.m, config.act_dtype, mode)
    eff = cm.tops_per_mm2(config.mu, config.n, config.m, config.act_dtype, mode=mode)

    def _align(x: int, a: int) -> int:
        return max(a, ((x + a - 1) // a) * a)

    # TPU-aligned kernel tile: reduction and output blocks are multiples of
    # 128 (MXU/VREG lane width) that cover at least one core tile.
    plan = KernelPlan(
        mu=config.mu,
        block_n=_align(config.n, 128),
        block_m=_align(config.m, 128),
        table_entries=table_size(config.mu) + 1,
        key_bits=key_bits(config.mu),
    )
    return LUTCoreDesign(config=config, netlist=net, build_program=prog,
                         area_mm2=area, tops_per_mm2=eff, kernel_plan=plan)


def generate_optimal(throughput: int, act_dtype: str, mode: str = "paper") -> LUTCoreDesign:
    """Generator + DSE: emit the area-optimal core at a throughput target."""
    from repro.core import dse

    p = dse.optimal_config_at_throughput(throughput, act_dtype, mode=mode)
    return generate(LUTCoreConfig(mu=p.mu, L=p.L, K=p.K, act_dtype=act_dtype), mode)
