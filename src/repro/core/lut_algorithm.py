"""Pure-jnp oracle of the two-phase LUT GEMV/GEMM algorithm (paper §II-B, Fig 2).

Phase 1 — **LUT Build**: for every group of ``mu`` activations, precompute the
``T = (3^mu - 1)/2`` symmetry-reduced partial sums (plus a hardwired 0 entry).
Functionally this is ``tables = x_groups @ C.T`` with the combo matrix ``C``.

Phase 2 — **Fetch & Accumulate**: each output channel holds one encoded key
per group; fetch ``tables[g, idx]``, conditionally invert by the symmetry bit,
and accumulate over groups.

This module is the *reference oracle* for:
  * ``repro.kernels.lut_matmul`` (Pallas TPU kernel, validated allclose),
  * ``repro.core.simulator`` (cycle-structured netlist simulation, bit-exact),
and it must itself equal a plain matmul exactly on integer inputs.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import encoding


def group_activations(x: jax.Array, mu: int) -> jax.Array:
    """[..., N] → [..., G, mu] with zero padding to a multiple of mu."""
    *lead, N = x.shape
    pad = (-N) % mu
    if pad:
        x = jnp.pad(x, [(0, 0)] * len(lead) + [(0, pad)])
    return x.reshape(*lead, (N + pad) // mu, mu)


def lut_build(x_groups: jax.Array, mu: int) -> jax.Array:
    """Build phase: [..., G, mu] → [..., G, T+1] partial-sum tables.

    Entry ``[..., g, t]`` = ``dot(C[t], x_groups[..., g, :])``; entry ``T`` is
    the hardwired zero.  In hardware this is the (symmetry/redundancy/sparsity
    optimized) adder tree; functionally a tiny matmul.
    """
    C = encoding.combo_matrix(mu).astype(x_groups.dtype)  # [T+1, mu]
    return x_groups @ C.T


def lut_fetch_accumulate(tables: jax.Array, keys: jax.Array, mu: int) -> jax.Array:
    """Fetch & accumulate phase.

    Args:
      tables: [..., G, T+1] built tables.
      keys:   [O, G] encoded weight keys (shared across leading batch dims).

    Returns:
      [..., O] accumulated outputs.
    """
    sym, idx = encoding.split_key(keys, mu)  # [O, G] each
    # Gather tables[..., g, idx[o, g]] for all o: use take_along_axis over T.
    # tables[..., G, T+1], idx.T → [G, O] broadcast over leading dims.
    gathered = jnp.take_along_axis(tables, idx.T[(None,) * (tables.ndim - 2)], axis=-1)
    # gathered: [..., G, O]
    sign = jnp.where(sym == 1, -1, 1).astype(tables.dtype)  # [O, G]
    return jnp.sum(gathered * sign.T, axis=-2)


def lut_matmul_keys(x: jax.Array, keys: jax.Array, mu: int) -> jax.Array:
    """y[..., o] = Σ_n x[..., n] · decode(keys)[o, n] via the two-phase algorithm."""
    xg = group_activations(x, mu)
    tables = lut_build(xg, mu)
    return lut_fetch_accumulate(tables, keys, mu)


def lut_matmul(x: jax.Array, w_t: jax.Array, mu: int) -> jax.Array:
    """Reference LUT matmul against a raw ternary matrix ``w_t [O, N]``.

    Exactly equal to ``x @ w_t.T`` (integer inputs) / allclose (float).
    """
    keys = encoding.encode_weight_matrix(w_t, mu)
    return lut_matmul_keys(x, keys, mu)


def lut_matmul_onehot(x: jax.Array, keys: jax.Array, mu: int) -> jax.Array:
    """MXU-friendly reformulation of the fetch phase (hardware adaptation).

    The gather in :func:`lut_fetch_accumulate` runs on the TPU VPU.  An
    alternative lowering turns the fetch into a matmul:
    ``y[o] = Σ_g Σ_t onehot(keys)[o,g,t] · tables[g,t]`` — signed one-hot rows
    make the symmetry flip free.  This trades (3^mu-1)/2 × more MACs for MXU
    residency; profitable only when T is tiny (mu ≤ 2).  Kept as the oracle
    for the kernel's ``fetch="onehot"`` mode.
    """
    T = encoding.table_size(mu)
    sym, idx = encoding.split_key(keys, mu)
    sign = jnp.where(sym == 1, -1, 1)
    onehot = jax.nn.one_hot(idx, T + 1, dtype=x.dtype) * sign[..., None].astype(x.dtype)
    xg = group_activations(x, mu)
    tables = lut_build(xg, mu)  # [..., G, T+1]
    return jnp.einsum("ogt,...gt->...o", onehot, tables)
