"""Structural netlist construction for the LUT core (paper §III-B/C, Fig 3-4).

This is the reproduction of the paper's *hardware generator*: for a design
point ``(mu, L, K)`` we construct the actual adder DAG of the LUT Build phase
with the paper's three optimizations applied **explicitly** —

  1. *Symmetry reduction*  — only the positive half of the 3^mu combos is
     built/stored; negatives come from the FAC sign-flip.
  2. *Redundancy elimination* — every multi-input entry is computed from a
     previously-computed entry plus one input (maximal common-subexpression
     reuse), so each stored entry with ≥2 non-zeros costs exactly one adder.
  3. *Sparsity* — zero trits never enter the tree; single-non-zero entries are
     passthrough wires.

and we count every adder/mux/register of the full core.  The closed forms of
paper Eqs. 2–4 are implemented alongside and cross-checked in tests; our
constructive count ((3^mu-1)/2 - mu) is *tighter* than the paper's bound for
mu ≥ 4 (36 vs 44 at mu=4) — the bound is stated as "≤" in the paper.

The emitted ``BuildProgram`` is an executable description (consumed by
``repro.core.simulator`` for bit-exact datapath simulation) — the moral
equivalent of the generated RTL.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core.encoding import combo_matrix_np, table_size


# ---------------------------------------------------------------------------
# Paper closed forms (Eqs. 2-4) and baselines
# ---------------------------------------------------------------------------


def naive_adders(mu: int) -> int:
    """The paper's naive baseline, (mu-1)·3^mu (denominator of the 81.89% claim)."""
    return (mu - 1) * 3**mu


def naive_adders_nonzero(mu: int) -> int:
    """§III-B text variant: (mu-1)·(3^mu - 1)."""
    return (mu - 1) * (3**mu - 1)


def symmetry_adders(mu: int) -> int:
    """After symmetry reduction only: (mu-1)·(3^mu-1)/2."""
    return (mu - 1) * (3**mu - 1) // 2


def S_redundancy(mu: int) -> int:
    """Eq. 3: redundancy savings recurrence.  S(2)=1, S(mu)=S(mu-1)+3^(mu-2)."""
    if mu < 2:
        return 0
    s = 1
    for m in range(3, mu + 1):
        s += 3 ** (m - 2)
    return s


def R_sparsity(mu: int) -> int:
    """Eq. 4: sparsity savings.  R(mu) = 2·Σ_{k=0}^{mu-3} 2^k·(3^{mu-2-k} - 1)."""
    if mu < 3:
        return 0
    return 2 * sum(2**k * (3 ** (mu - 2 - k) - 1) for k in range(mu - 2))


def bound_adders(mu: int) -> int:
    """Eq. 2 upper bound on adders/LUT after all three optimizations."""
    if mu == 1:
        return 0
    return symmetry_adders(mu) - R_sparsity(mu) - mu * S_redundancy(mu)


def adder_reduction_vs_naive(mu: int) -> float:
    """Fraction of adders removed vs naive — paper: 81.89% at mu=4."""
    if mu == 1:
        return 1.0  # naive needs 0 adders at mu=1; nothing to reduce
    return 1.0 - bound_adders(mu) / naive_adders(mu)


def constructive_adders(mu: int) -> int:
    """Exact adder count of our constructive DAG: (3^mu - 1)/2 - mu.

    One adder per stored entry with ≥2 non-zero trits (entries with ≤1
    non-zero are wires).  Equals Eq. 2's bound at mu ∈ {2,3} and beats it for
    mu ≥ 4.
    """
    if mu == 1:
        return 0
    return table_size(mu) - mu


# ---------------------------------------------------------------------------
# Constructive adder DAG ("the generated RTL")
# ---------------------------------------------------------------------------

# Operand reference: ("x", i) input wire, ("e", t) stored entry t, ("zero",).
Ref = tuple


@dataclass(frozen=True)
class BuildOp:
    """One node of the Build-phase DAG: entry[out] = a ± b."""

    out: int  # table index written
    a: Ref
    b: Ref | None  # None => passthrough wire (out = a, possibly negated)
    negate_a: bool = False
    negate_b: bool = False

    @property
    def is_adder(self) -> bool:
        return self.b is not None


@dataclass
class BuildProgram:
    """Executable Build-phase program for one LUT of group size mu."""

    mu: int
    ops: list[BuildOp] = field(default_factory=list)

    @property
    def n_adders(self) -> int:
        return sum(op.is_adder for op in self.ops)

    @property
    def depth(self) -> int:
        """Pipeline depth (longest adder chain) of the DAG."""
        d = {}
        for op in self.ops:
            da = d.get(op.a, 0) if op.a[0] == "e" else 0
            db = d.get(op.b, 0) if (op.b and op.b[0] == "e") else 0
            d[("e", op.out)] = max(da, db) + (1 if op.is_adder else 0)
        return max(d.values(), default=0)


def _msnz(combo: np.ndarray) -> int:
    """Index of the most significant non-zero trit (combo is positive-half)."""
    nz = np.nonzero(combo)[0]
    return int(nz[-1])


def build_program(mu: int) -> BuildProgram:
    """Construct the optimized Build-phase DAG for one LUT.

    For each stored (positive-half) entry ``c``:
      * nnz=0 → nothing (hardwired 0, reserved entry T);
      * nnz=1 → passthrough wire from the single ±x_i;
      * nnz≥2 → strip the most-significant trit (always +1 for positive-half
        combos): value(c) = x_j + value(c'), reusing value(c') which is either
        a stored entry (positive half), the negation of one (the FAC-style
        free sign flip, a subtractor here), or a bare ±x_i.  Exactly one adder
        per such entry — symmetry + redundancy + sparsity applied by
        construction.
    """
    C = combo_matrix_np(mu)  # [T+1, mu], row T = zeros
    T = table_size(mu)
    center = T

    def combo_value(c: np.ndarray) -> int:
        return int(np.sum((c.astype(np.int64) + 1) * 3 ** np.arange(mu)))

    def ref_of(c: np.ndarray) -> tuple[Ref, bool]:
        """Reference to an already-available signal equal to combo c.

        Returns (ref, negate).  c may be any combo (positive, negative,
        single, or zero).
        """
        nnz = np.nonzero(c)[0]
        if len(nnz) == 0:
            return ("zero",), False
        if len(nnz) == 1:
            i = int(nnz[0])
            return ("x", i), c[i] < 0
        v = combo_value(c)
        if v > center:
            return ("e", v - center - 1), False
        return ("e", (3**mu - 1 - v) - center - 1), True  # negated stored entry

    prog = BuildProgram(mu=mu)
    # Entries must be emitted so that dependencies (fewer trits / lower msnz)
    # come first; iterating by msnz then index achieves that because stripping
    # the MSB trit strictly lowers msnz.
    order = sorted(range(T), key=lambda t: (_msnz(C[t]), t))
    for t in order:
        c = C[t].copy()
        nnz = np.nonzero(c)[0]
        if len(nnz) == 1:
            i = int(nnz[0])
            prog.ops.append(BuildOp(out=t, a=("x", i), b=None, negate_a=bool(c[i] < 0)))
            continue
        j = _msnz(c)
        assert c[j] == 1, "positive-half combos have a +1 MSB trit"
        c_rest = c.copy()
        c_rest[j] = 0
        ref, neg = ref_of(c_rest)
        prog.ops.append(BuildOp(out=t, a=("x", j), b=ref, negate_b=neg))
    assert prog.n_adders == constructive_adders(mu), (
        prog.n_adders,
        constructive_adders(mu),
    )
    return prog


# ---------------------------------------------------------------------------
# Full-core netlist (Fig. 3 module hierarchy)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Netlist:
    """Unit-cell counts for one LUT core instance (Fig. 3 submodules).

    ``*_paper`` fields use the paper's closed forms (what the cost model of
    §IV consumes); plain fields are our exact constructive counts.
    """

    mu: int
    L: int
    K: int
    # Build phase (Pre+)
    build_adders: int          # exact constructive count, all L LUTs
    build_adders_paper: int    # Eq. 2 bound × L
    lut_regs: int              # stored entries (post-symmetry) × L
    build_pipeline_depth: int
    # Fetch & Accumulate (MUXs + Post+)
    mux2_equiv: int            # exact: (T-1) 2:1-mux equivalents per fetcher
    mux2_equiv_paper: int      # Eq. 7: T per fetcher
    inverters: int             # 1 sign-flip per fetcher
    acc_adders: int            # Eq. 6: L·K (L-1 reduction + 1 accumulate, ×K)
    # Output buffers
    out_regs: int              # Eq. 8: K accumulator registers

    @property
    def n(self) -> int:
        return self.L * self.mu

    @property
    def m(self) -> int:
        return self.K

    @property
    def throughput(self) -> int:
        """Ternary multiplications per cycle (Eq. 1 numerator)."""
        return self.n * self.m

    def summary(self) -> str:
        return (
            f"LUTCore(mu={self.mu}, L={self.L}, K={self.K}) "
            f"tile {self.n}x{self.m} ({self.throughput} mul/cyc)\n"
            f"  Build+ : {self.build_adders} adders (paper bound {self.build_adders_paper}), "
            f"{self.lut_regs} LUT regs, depth {self.build_pipeline_depth}\n"
            f"  FAC    : {self.mux2_equiv} mux2-eq (paper {self.mux2_equiv_paper}), "
            f"{self.inverters} inverters, {self.acc_adders} accumulate adders\n"
            f"  OutBuf : {self.out_regs} registers"
        )


def make_netlist(mu: int, L: int, K: int) -> Netlist:
    T = table_size(mu)
    prog = build_program(mu)
    return Netlist(
        mu=mu,
        L=L,
        K=K,
        build_adders=prog.n_adders * L,
        build_adders_paper=bound_adders(mu) * L,
        lut_regs=T * L,
        build_pipeline_depth=prog.depth,
        mux2_equiv=max(T - 1, 0) * L * K,
        mux2_equiv_paper=T * L * K,
        inverters=L * K,
        acc_adders=L * K,
        out_regs=K,
    )
