"""BitNet b1.58 ternary quantization (paper §II-A).

Weight quantization follows BitNet b1.58 [Ma et al., 2024]: per-tensor absmean
scaling followed by round-to-nearest-ternary {-1, 0, +1}.  Activations are
quantized per-token to INT8 with absmax scaling, matching the "INT8 activation"
operating point the paper's accelerator targets (Table I).

All functions are pure-jnp and differentiable where relevant (straight-through
estimator for QAT).  These are the *reference semantics*; the kernels in
``repro.kernels`` and the packed serving path in ``repro.core.encoding`` must
agree with them bit-exactly on the ternary values.
"""

from __future__ import annotations

import functools
from typing import Literal

import jax
import jax.numpy as jnp

EPS = 1e-6

QuantMode = Literal["fp", "dequant", "packed", "lut"]


def absmean_scale(w: jax.Array, axis=None) -> jax.Array:
    """BitNet b1.58 scale: mean of absolute values (per-tensor by default)."""
    return jnp.clip(jnp.mean(jnp.abs(w), axis=axis, keepdims=axis is not None), EPS, None)


def ternarize(w: jax.Array, axis=None) -> tuple[jax.Array, jax.Array]:
    """Quantize weights to {-1, 0, +1} with absmean scale.

    Returns ``(w_t, scale)`` with ``w_t`` int8 in {-1, 0, 1} and
    ``w ≈ w_t * scale``.  ``axis=None`` gives the per-tensor BitNet b1.58
    recipe; pass an axis tuple for per-channel scales.
    """
    scale = absmean_scale(w, axis=axis)
    w_t = jnp.clip(jnp.round(w / scale), -1, 1).astype(jnp.int8)
    return w_t, scale.astype(w.dtype)


def dequantize(w_t: jax.Array, scale: jax.Array, dtype=jnp.bfloat16) -> jax.Array:
    return w_t.astype(dtype) * scale.astype(dtype)


@jax.custom_vjp
def ste_ternarize(w: jax.Array) -> jax.Array:
    """Fake-quantized weights for QAT: forward = dequant(ternarize(w)),
    backward = identity (straight-through estimator, as in BitNet training)."""
    w_t, scale = ternarize(w)
    return dequantize(w_t, scale, dtype=w.dtype)


def _ste_fwd(w):
    return ste_ternarize(w), None


def _ste_bwd(_, g):
    return (g,)


ste_ternarize.defvjp(_ste_fwd, _ste_bwd)


def fake_quant_ternary(w: jax.Array, axis=None) -> jax.Array:
    """STE fake-quant via the stop-gradient identity (supports per-channel
    ``axis``, e.g. per-expert scales on stacked MoE weights)."""
    w_t, scale = ternarize(w, axis=axis)
    wq = dequantize(w_t, scale, dtype=w.dtype)
    return w + jax.lax.stop_gradient(wq - w)


def fake_quant_acts(x: jax.Array) -> jax.Array:
    """STE INT8 per-token activation fake-quant (stop-gradient identity)."""
    x_q, scale = quantize_activations_int8(x)
    xq = (x_q.astype(jnp.float32) * scale).astype(x.dtype)
    return x + jax.lax.stop_gradient(xq - x)


def quantize_activations_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Per-token (last-axis) absmax INT8 activation quantization.

    Returns ``(x_q, scale)`` with ``x ≈ x_q * scale`` and x_q int8 in
    [-127, 127].

    Edge cases are hardened rather than propagated: an all-zero token row
    quantizes to all-zero codes with a finite (EPS-derived) scale instead of a
    0/0 NaN, a row containing ±inf gets a finite scale (f32 max) so its codes
    saturate at ±127 instead of casting NaN→int8 (which wraps on some
    backends), and NaN activations quantize to 0.
    """
    absmax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True)
    absmax = jnp.where(jnp.isfinite(absmax), absmax,
                       jnp.finfo(jnp.float32).max)
    absmax = jnp.clip(absmax, EPS, None)  # all-zero row → EPS, never /0
    scale = (absmax / 127.0).astype(jnp.float32)
    q = jnp.round(x.astype(jnp.float32) / scale)
    q = jnp.where(jnp.isnan(q), 0.0, q)  # NaN input → zero code
    # clip BEFORE the int8 cast: out-of-range f32→int8 wraps, clip saturates
    x_q = jnp.clip(q, -127, 127).astype(jnp.int8)
    return x_q, scale


@jax.custom_vjp
def ste_quantize_activations(x: jax.Array) -> jax.Array:
    """Fake-quantized INT8 activations with STE backward."""
    x_q, scale = quantize_activations_int8(x)
    return (x_q.astype(jnp.float32) * scale).astype(x.dtype)


def _act_fwd(x):
    return ste_quantize_activations(x), None


def _act_bwd(_, g):
    return (g,)


ste_quantize_activations.defvjp(_act_fwd, _act_bwd)


def fake_quant_matmul(x: jax.Array, w: jax.Array, quantize_acts: bool = True) -> jax.Array:
    """QAT forward for a linear layer: y = act_q(x) @ ternary_q(w).

    ``w`` is the bf16/fp32 master weight; both quantizers use STE so the
    backward pass flows full-precision gradients to ``w`` and ``x``.
    """
    wq = ste_ternarize(w)
    xq = ste_quantize_activations(x) if quantize_acts else x
    return xq @ wq


@functools.partial(jax.jit, static_argnames=("dtype",))
def ternary_weight_stats(w_t: jax.Array, dtype=jnp.float32):
    """Diagnostics: fraction of -1/0/+1 (sparsity drives the paper's S/R savings)."""
    w_t = w_t.astype(jnp.int32)
    n = w_t.size
    neg = jnp.sum(w_t == -1) / n
    zero = jnp.sum(w_t == 0) / n
    pos = jnp.sum(w_t == 1) / n
    return {"neg": neg.astype(dtype), "zero": zero.astype(dtype), "pos": pos.astype(dtype)}
