"""Functional, cycle-structured simulation of the generated LUT core.

This is the reproduction's stand-in for RTL simulation: it executes the exact
``BuildProgram`` adder DAG emitted by the generator (one evaluation per LUT
per build phase), the FAC read-out (mux select by encoded key, conditional
sign inversion, L-way reduction) and the output-stationary accumulation loop
over matrix tiles — and must agree **bit-exactly** with ``W @ x`` for integer
activations (tests enforce this), and to float tolerance for FP activations.

It also reports cycle counts, so throughput claims (Eq. 1) can be checked
against the simulated schedule rather than assumed.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core.encoding import combo_matrix_np, idx_bits, table_size
from repro.core.generator import LUTCoreConfig, LUTCoreDesign, generate
from repro.core.netlist import BuildProgram


@dataclass
class SimStats:
    cycles: int
    build_phases: int
    fetch_cycles: int
    mac_equiv: int  # ternary multiplies performed

    @property
    def muls_per_cycle(self) -> float:
        return self.mac_equiv / max(self.cycles, 1)


def _run_build_program(prog: BuildProgram, x_group: np.ndarray) -> np.ndarray:
    """Evaluate the adder DAG for one LUT: x_group [mu] → entries [T+1]."""
    T = table_size(prog.mu)
    entries = np.zeros(T + 1, dtype=x_group.dtype)  # entry T = hardwired 0

    def val(ref, neg):
        if ref[0] == "zero":
            v = np.zeros((), dtype=x_group.dtype)
        elif ref[0] == "x":
            v = x_group[ref[1]]
        else:
            v = entries[ref[1]]
        return -v if neg else v

    for op in prog.ops:
        a = val(op.a, op.negate_a)
        entries[op.out] = a if op.b is None else a + val(op.b, op.negate_b)
    return entries


def _encode_np(w_group: np.ndarray, mu: int) -> tuple[int, int]:
    """Encode one ternary group → (sym, idx).  Mirrors encoding.encode_groups."""
    T = table_size(mu)
    v = int(np.sum((w_group.astype(np.int64) + 1) * 3 ** np.arange(mu)))
    if v == T:  # all-zero group
        return 0, T
    if v > T:
        return 0, v - T - 1
    return 1, (3**mu - 1 - v) - T - 1


def simulate_gemv(design: LUTCoreDesign, w_t: np.ndarray, x: np.ndarray,
                  acc_dtype=None) -> tuple[np.ndarray, SimStats]:
    """Run a full GEMV ``y = w_t @ x`` through the simulated core.

    Args:
      design: generated core (provides mu, L, K and the Build DAG).
      w_t:    [M, N] ternary weights in {-1, 0, +1}.
      x:      [N] activations (int for bit-exactness, float allowed).

    Returns:
      (y [M], SimStats).
    """
    cfg = design.config
    mu, L, K = cfg.mu, cfg.L, cfg.K
    n_tile = L * mu
    M, N = w_t.shape
    acc_dtype = acc_dtype or (np.int64 if np.issubdtype(x.dtype, np.integer) else np.float64)

    pad_n = (-N) % n_tile
    pad_m = (-M) % K
    xp = np.pad(x, (0, pad_n)).astype(acc_dtype)
    wp = np.pad(w_t, ((0, pad_m), (0, pad_n)))
    Np, Mp = N + pad_n, M + pad_m
    n_tiles, m_tiles = Np // n_tile, Mp // K

    y = np.zeros(Mp, dtype=acc_dtype)
    prog = design.build_program
    ib = idx_bits(mu)
    C = combo_matrix_np(mu)
    build_phases = fetch_cycles = 0

    # Output-stationary schedule (Fig. 3): for each output tile, sweep the
    # reduction dimension; LUTs rebuild at every reduction step and are read
    # by K parallel fetchers (spatial reuse).
    for mt in range(m_tiles):
        acc = np.zeros(K, dtype=acc_dtype)  # the K output registers
        for nt in range(n_tiles):
            xg = xp[nt * n_tile:(nt + 1) * n_tile].reshape(L, mu)
            tables = np.stack([_run_build_program(prog, xg[l]) for l in range(L)])
            build_phases += 1
            # sanity vs combo matrix (the "RTL" must equal the spec)
            # (cheap: only in tests; here we trust the DAG)
            wg = wp[mt * K:(mt + 1) * K, nt * n_tile:(nt + 1) * n_tile].reshape(K, L, mu)
            for k in range(K):  # K parallel FAC units (spatial; 1 cycle)
                s = acc_dtype(0) if not np.issubdtype(acc.dtype, np.floating) else 0.0
                for l in range(L):  # reduction adder tree (spatial)
                    sym, idx = _encode_np(wg[k, l], mu)
                    v = tables[l, idx]
                    s = s + (-v if sym else v)
                acc[k] += s
            fetch_cycles += 1
        y[mt * K:(mt + 1) * K] = acc

    depth = max(design.netlist.build_pipeline_depth, 1)
    # Pipelined schedule: builds overlap fetches except the first fill.
    cycles = m_tiles * n_tiles + depth
    stats = SimStats(cycles=cycles, build_phases=build_phases,
                     fetch_cycles=fetch_cycles, mac_equiv=Mp * Np)
    return y[:M], stats


def simulate_vs_reference(config: LUTCoreConfig, w_t: np.ndarray, x: np.ndarray):
    """Convenience: simulate and return (y_sim, y_ref, stats)."""
    design = generate(config)
    y_sim, stats = simulate_gemv(design, w_t, x)
    y_ref = w_t.astype(np.int64 if np.issubdtype(x.dtype, np.integer) else np.float64) @ \
        x.astype(np.int64 if np.issubdtype(x.dtype, np.integer) else np.float64)
    return y_sim, y_ref, stats
