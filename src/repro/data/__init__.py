"""repro.data subsystem."""
