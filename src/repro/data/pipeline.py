"""Deterministic synthetic token pipeline with shard-aware iteration.

Production shape: an infinite, seekable, host-sharded stream.  Every batch is
a pure function of (seed, step, host_shard), so

  * restart-from-checkpoint reproduces the exact token stream (fault
    tolerance: the loader has no state to checkpoint beyond the step),
  * each data-parallel host pulls only its shard (no cross-host traffic),
  * elastic re-sharding is a pure re-indexing (host count can change between
    restarts and the global stream stays identical).

The generator is a Zipf-ish LM-like distribution with induced bigram
structure so losses behave qualitatively like text (useful for the e2e
example runs), packed to fixed seq_len with an EOD token.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

EOD = 0


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 1234
    mean_doc_len: int = 512


class SyntheticLMStream:
    """Infinite deterministic stream of packed LM batches."""

    def __init__(self, cfg: DataConfig, host_id: int = 0, n_hosts: int = 1):
        assert cfg.global_batch % n_hosts == 0, "batch must divide hosts"
        self.cfg = cfg
        self.host_id = host_id
        self.n_hosts = n_hosts
        self.local_batch = cfg.global_batch // n_hosts
        # Zipf over the vocab (excluding EOD), fixed per seed.
        ranks = np.arange(1, cfg.vocab_size, dtype=np.float64)
        probs = 1.0 / ranks ** 1.1
        self._probs = probs / probs.sum()

    def _row_rng(self, step: int, global_row: int) -> np.random.Generator:
        return np.random.default_rng(
            np.random.SeedSequence([self.cfg.seed, step, global_row]))

    def _sample_row(self, rng: np.random.Generator) -> np.ndarray:
        c = self.cfg
        out = np.empty(c.seq_len + 1, dtype=np.int32)
        i = 0
        while i < len(out):
            doc_len = min(int(rng.geometric(1.0 / c.mean_doc_len)) + 8,
                          len(out) - i)
            toks = rng.choice(len(self._probs), size=doc_len, p=self._probs) + 1
            # induce bigram structure: every odd position correlates w/ prev
            toks[1::2] = (toks[0::2][: len(toks[1::2])] * 7 + 3) % (c.vocab_size - 1) + 1
            out[i:i + doc_len] = toks
            i += doc_len
            if i < len(out):
                out[i] = EOD
                i += 1
        return out

    def batch(self, step: int) -> dict:
        """Host-local batch for ``step``: tokens/labels/loss_mask
        [local_batch, seq_len]."""
        c = self.cfg
        rows = []
        for b in range(self.local_batch):
            global_row = self.host_id * self.local_batch + b
            rows.append(self._sample_row(self._row_rng(step, global_row)))
        arr = np.stack(rows)  # [B, S+1]
        return {
            "tokens": arr[:, :-1],
            "labels": arr[:, 1:].astype(np.int32),
            "loss_mask": (arr[:, 1:] != EOD).astype(np.float32),
        }

    def __iter__(self):
        step = 0
        while True:
            yield self.batch(step)
            step += 1
