"""Pallas TPU kernels for ternary matmul (LUT / sign-flip / packed-dequant).

Each kernel module holds the pl.pallas_call + BlockSpec implementation;
``ops.py`` is the jit'd public API and ``ref.py`` the pure-jnp oracles.
Kernels target TPU and are validated on CPU with interpret=True.
"""

from repro.kernels.ops import (  # noqa: F401
    encode_for_lut,
    encode_packed,
    ternary_linear_lut,
    ternary_linear_packed,
    ternary_linear_signflip,
)
