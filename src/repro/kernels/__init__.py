"""Pallas TPU kernels for ternary matmul (LUT / sign-flip / packed-dequant).

Each kernel module holds the pl.pallas_call + BlockSpec implementation;
``ops.py`` is the jit'd public API and ``ref.py`` the pure-jnp oracles.
Kernels target TPU and are validated on CPU with interpret=True.

``dispatch.py`` is the unified entry point: a registry of every ternary
matmul implementation with dtype/shape constraints, a cost-model static
prior, and a disk-persisted autotune cache.  New call sites should use
:func:`ternary_matmul` rather than binding to one kernel module.
"""

from repro.kernels.dispatch import (  # noqa: F401
    REGISTRY,
    AutotuneCache,
    GroupedTernaryWeight,
    KernelSpec,
    TernaryWeight,
    autotune,
    eligible_kernels,
    get_autotune_cache,
    get_kernel,
    grouped_ternary_matmul,
    kernel_names,
    register_kernel,
    reset_autotune_cache,
    select_kernel,
    static_prior,
    ternary_matmul,
)
from repro.kernels.ops import (  # noqa: F401
    encode_for_lut,
    encode_packed,
    ternary_linear_lut,
    ternary_linear_packed,
    ternary_linear_signflip,
)
