"""Pallas TPU kernel: packed-ternary dequantize + matmul (deployment path).

This is the kernel that realizes the paper's *bandwidth* win on TPU: weights
stream from HBM as base-3-packed uint8 (5 trits/byte = 1.6 bits/weight, the
paper's §III-D density) and are expanded to the activation dtype **in VMEM**,
then contracted on the MXU.  HBM traffic for weights drops 10× vs bf16 and
20% vs naive 2-bit packing — exactly the decode-stage bottleneck the paper
attacks.

Decode uses arithmetic base-3 digit extraction (5 div-mod-3 steps on the VPU)
rather than a table gather: divides by the constant 3 lower to
multiply-by-reciprocal, and the whole decode vectorizes across the 8×128 VREG
lanes with no dynamic addressing.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.encoding import TRITS_PER_BYTE


def _unpack_block(p: jax.Array, out_dtype) -> jax.Array:
    """[bo, bn/5] uint8 → [bo, bn] trits in out_dtype (arithmetic decode)."""
    v = p.astype(jnp.int32)
    digs = []
    for _ in range(TRITS_PER_BYTE):
        digs.append((v % 3 - 1).astype(out_dtype))
        v = v // 3
    # [bo, bn/5, 5] → [bo, bn]; trit i of byte j is weight 5*j + i.
    w = jnp.stack(digs, axis=-1)
    return w.reshape(p.shape[0], -1)


def _dequant_kernel(x_ref, p_ref, out_ref):
    """x_ref [bb, bn] float; p_ref [bo, bn//5] uint8; out [bb, bo] f32."""
    k = pl.program_id(2)
    x = x_ref[...]
    w = _unpack_block(p_ref[...], x.dtype)  # [bo, bn]
    partial = jax.lax.dot_general(
        x, w, dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += partial


@functools.partial(
    jax.jit, static_argnames=("n", "block_b", "block_o", "block_n", "interpret")
)
def packed_matmul(
    x: jax.Array,
    packed: jax.Array,
    n: int,
    *,
    block_b: int = 8,
    block_o: int = 128,
    block_n: int = 640,  # multiple of 5 (pack group) and 128 (lanes)
    interpret: bool = True,
) -> jax.Array:
    """y[b, o] = Σ_n x[b, n] · unpack(packed)[o, n].

    Args:
      x:      [B, N] activations (N may include padding up to 5·packed cols).
      packed: [O, ceil(N/5)] base-3 packed ternary weights.
      n:      logical N (unpacked columns beyond n are zero by construction).
    """
    B, N = x.shape
    O, NB = packed.shape
    if N < n or NB * TRITS_PER_BYTE < n:
        raise ValueError((N, NB, n))
    # pad x to the full unpacked width (pad trits decode to -1? no: pack_base3
    # zero-pads, and value-0 trits decode to 0, so extra x columns are safely
    # multiplied by 0; but x itself must cover NB*5 columns)
    full = NB * TRITS_PER_BYTE
    if N < full:
        x = jnp.pad(x, ((0, 0), (0, full - N)))
    N = full

    block_n = min(block_n, N)
    block_n -= block_n % TRITS_PER_BYTE
    block_b = min(block_b, B)
    block_o = min(block_o, O)
    pad_b = (-B) % block_b
    pad_o = (-O) % block_o
    pad_n = (-N) % block_n
    if pad_b or pad_n:
        x = jnp.pad(x, ((0, pad_b), (0, pad_n)))
    if pad_o or pad_n:
        packed = jnp.pad(packed, ((0, pad_o), (0, pad_n // TRITS_PER_BYTE)))
        # note: padded bytes are 0 → trits (-1,-1,-1,-1,-1)… but the matching
        # x columns are zero-padded, so the products vanish.  Padded *rows*
        # are sliced off below.
    Bp, Op, Np = B + pad_b, O + pad_o, N + pad_n

    out = pl.pallas_call(
        _dequant_kernel,
        grid=(Bp // block_b, Op // block_o, Np // block_n),
        in_specs=[
            pl.BlockSpec((block_b, block_n), lambda i, j, k: (i, k)),
            pl.BlockSpec((block_o, block_n // TRITS_PER_BYTE), lambda i, j, k: (j, k)),
        ],
        out_specs=pl.BlockSpec((block_b, block_o), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Bp, Op), jnp.float32),
        interpret=interpret,
    )(x, packed)
    return out[:B, :O]
