"""Unified ternary-matmul dispatch: one entry point, many kernels.

The paper's central finding is that the best ternary-matmul strategy depends
on activation dtype and operand shape (LUT wins FP16 compute, the benefit is
minimal at INT8; packed streaming wins when decode is bandwidth-bound).  This
module makes that trade-off a *runtime* decision instead of a per-callsite
hard-wiring:

  * a **registry** of every ternary matmul implementation in this package
    (``ref``, ``lut_onehot``, ``lut_gather``, ``dequant_packed``,
    ``signflip``, ``w2a8``, the TL2 two-trit LUT family ``tl2``/``tl2_ref``,
    plus the grouped batched-expert family
    ``grouped_ref``/``grouped_dequant``/``grouped_w2a8``/``grouped_tl2``)
    with its supported activation dtypes and shape constraints,
  * a **static prior** derived from the analytical cost model
    (:mod:`repro.core.cost_model`): per-MAC gate cost of each datapath plus a
    weight-bytes-streamed term, so small-M (decode) shapes lean to the packed
    1.6 b/w paths and large-M (prefill) shapes to the cheapest compute,
  * a **benchmark-driven autotune cache** keyed on
    ``(M, K, N, activation_dtype, backend)`` — grouped problems prepend the
    expert count ``E`` — persisted to disk (``REPRO_AUTOTUNE_CACHE``, default
    ``~/.cache/repro/autotune.json``), populated by :func:`autotune` /
    ``benchmarks/autotune_sweep.py``,
  * two public entry points::

        y = ternary_matmul(x, w, policy="auto")          # cache → prior
        y = ternary_matmul(x, w, policy="fixed:signflip")  # reproducible pin
        y = grouped_ternary_matmul(t, gw)  # [E, C, K] × stacked experts

Shape convention: ``x [..., K]`` activations, weights ``[N, K]`` (out-major,
as everywhere in this repo), result ``[..., N]``.  Grouped (MoE expert)
problems carry a leading expert dim on both operands: ``x [E, ..., K]``
against a :class:`GroupedTernaryWeight` holding stacked ``[E, N, K]`` trits
(stored as ``[E, N, ceil(K/5)]`` packed bytes) with a per-expert rank-1
scale.  All kernels consume *unscaled* trits; the BitNet absmean scale is
applied once on the way out.

On CPU the Pallas kernels run in interpret mode, which is functionally exact
but orders of magnitude slower than XLA — the prior carries a backend-aware
penalty so ``auto`` never routes a CPU serving path through an interpreted
kernel unless the autotune cache has measured otherwise.
"""

from __future__ import annotations

import json
import math
import os
import tempfile
import time
import warnings
from contextlib import contextmanager
from dataclasses import dataclass, field
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import encoding
from repro.core import cost_model as cm
from repro.kernels.dequant_matmul import packed_matmul
from repro.kernels.grouped_matmul import grouped_packed_matmul, grouped_w2a8_matmul
from repro.kernels.lut_matmul import lut_matmul
from repro.kernels.signflip_matmul import signflip_matmul
from repro.kernels.tl2_matmul import (TRITS_PER_WORD, pack_tl2,
                                      repack_base3_to_tl2, tl2_matmul,
                                      tl2_matmul_ref)
from repro.kernels.w2a8_matmul import w2a8_matmul

__all__ = [
    "TernaryWeight", "GroupedTernaryWeight", "KernelSpec", "REGISTRY",
    "register_kernel", "kernel_names", "get_kernel", "eligible_kernels",
    "select_kernel", "static_prior", "ternary_matmul",
    "grouped_ternary_matmul", "autotune",
    "AutotuneCache", "get_autotune_cache", "reset_autotune_cache",
    "ShardInfo", "shard_scope", "current_shard_info",
    "DEFAULT_POLICY_ENV",
]

DEFAULT_POLICY_ENV = "REPRO_TERNARY_POLICY"
CACHE_PATH_ENV = "REPRO_AUTOTUNE_CACHE"

#: roofline-ish exchange rate between the two prior terms: how many
#: gate-cycles of compute one byte of HBM weight traffic is "worth".
GATES_PER_BYTE = 2048.0

#: multiplier applied to Pallas kernels when the backend executes them in
#: interpret mode (CPU) — functional, but never competitive.
INTERPRET_PENALTY = 1e4


# ---------------------------------------------------------------------------
# Unified weight container
# ---------------------------------------------------------------------------


def _concrete(v: jax.Array) -> bool:
    """Derived encodings are cached only when concrete: a value computed
    while tracing (e.g. the weight arrived as a jit argument) is a Tracer
    and caching it would leak it into later traces
    (UnexpectedTracerError)."""
    return not isinstance(v, jax.core.Tracer)


class TernaryWeight:
    """A ternary weight matrix with lazily derived per-kernel encodings.

    Holds the logical ``[N, K]`` trit matrix (out-major) and its BitNet
    absmean ``scale``; the base-3 packed bytes (dequant/w2a8 paths) and the
    mu-group LUT keys are derived on first use and cached, so a weight
    prepared once can be routed through any registered kernel.
    """

    def __init__(self, w_t: jax.Array | None = None, scale=1.0, *,
                 packed: jax.Array | None = None, k: int | None = None,
                 mu: int = 3):
        if w_t is None and packed is None:
            raise ValueError("need trits or packed bytes")
        if w_t is not None and w_t.dtype != jnp.int8:
            w_t = w_t.astype(jnp.int8)
        self._w_t = w_t
        self._packed = packed
        self._k = int(w_t.shape[-1]) if w_t is not None else int(k)
        self.scale = scale
        self.mu = mu
        self._keys: dict[int, jax.Array] = {}
        self._tl2: jax.Array | None = None

    # -- constructors -------------------------------------------------------

    @classmethod
    def from_dense(cls, w: jax.Array, *, mu: int = 3) -> "TernaryWeight":
        """Master fp weights ``[N, K]`` → ternarized container."""
        from repro.core.quantization import ternarize

        w_t, scale = ternarize(w)
        return cls(w_t, scale, mu=mu)

    @classmethod
    def from_ternary(cls, w_t: jax.Array, scale=1.0, *, mu: int = 3) -> "TernaryWeight":
        return cls(w_t, scale, mu=mu)

    @classmethod
    def from_packed(cls, packed: jax.Array, scale, k: int, *,
                    mu: int = 3) -> "TernaryWeight":
        """Deployment artifact ``{"packed" [N, ceil(K/5)], "scale"}`` → container."""
        return cls(None, scale, packed=packed, k=k, mu=mu)

    # -- shapes -------------------------------------------------------------

    @property
    def out_features(self) -> int:
        src = self._w_t if self._w_t is not None else self._packed
        return int(src.shape[0])

    @property
    def in_features(self) -> int:
        return self._k

    # -- encodings (cached via module-level _concrete gate) ------------------

    def trits(self) -> jax.Array:
        """Dense ``[N, K]`` int8 trits (ref/signflip paths)."""
        if self._w_t is not None:
            return self._w_t
        w_t = encoding.unpack_base3(self._packed, self._k)
        if _concrete(w_t):
            self._w_t = w_t
        return w_t

    def packed(self) -> jax.Array:
        """Base-3 packed bytes ``[N, ceil(K/5)]`` (dequant/w2a8 paths)."""
        if self._packed is not None:
            return self._packed
        packed = encoding.pack_base3(self._w_t)
        if _concrete(packed):
            self._packed = packed
        return packed

    def keys(self, mu: int | None = None) -> jax.Array:
        """Group keys ``[N, ceil(K/mu)]`` (LUT paths)."""
        mu = mu or self.mu
        if mu in self._keys:
            return self._keys[mu]
        keys = encoding.encode_weight_matrix(self.trits(), mu)
        if _concrete(keys):
            self._keys[mu] = keys
        return keys

    def tl2(self) -> jax.Array:
        """TL2 base-9 words ``[N, ceil(K/10)]`` uint16 (tl2 paths)."""
        if self._tl2 is not None:
            return self._tl2
        if self._packed is not None:
            words = repack_base3_to_tl2(self._packed, self._k)
        else:
            words = pack_tl2(self._w_t)
        if _concrete(words):
            self._tl2 = words
        return words


def _as_weight(w, scale, mu) -> TernaryWeight:
    if isinstance(w, TernaryWeight):
        return w
    if isinstance(w, encoding.PackedTernary):
        return TernaryWeight.from_packed(w.data, w.scale, w.shape[1],
                                         mu=mu or 3)
    w = jnp.asarray(w)
    if w.dtype != jnp.int8:
        raise TypeError(
            "ternary_matmul weights must be a TernaryWeight, PackedTernary, "
            f"or int8 trit matrix; got dtype {w.dtype}. Use "
            "TernaryWeight.from_dense(w) to ternarize master weights.")
    return TernaryWeight.from_ternary(w, 1.0 if scale is None else scale,
                                      mu=mu or 3)


class GroupedTernaryWeight:
    """A stacked per-expert ternary weight ``[E, N, K]`` with per-expert
    scales ``[E]`` — the MoE analogue of :class:`TernaryWeight`.

    The serving artifact form is ``{"packed": uint8 [E, N, ceil(K/5)+pad],
    "scale": [E]}`` (``quantize_for_serving`` pads the byte dim for TP
    shardability; kernels slice decode at the logical ``K``).  Dense trits
    and packed bytes are derived lazily from each other, with the same
    concreteness-gated caching as the dense container.
    """

    def __init__(self, w_t: jax.Array | None = None, scale=1.0, *,
                 packed: jax.Array | None = None, k: int | None = None,
                 mu: int = 3):
        if w_t is None and packed is None:
            raise ValueError("need trits or packed bytes")
        if w_t is not None and w_t.dtype != jnp.int8:
            w_t = w_t.astype(jnp.int8)
        if (w_t if w_t is not None else packed).ndim != 3:
            raise ValueError(
                "grouped weights are stacked [E, N, K] trits / "
                f"[E, N, ceil(K/5)] bytes; got ndim "
                f"{(w_t if w_t is not None else packed).ndim}")
        self._w_t = w_t
        self._packed = packed
        self._k = int(w_t.shape[-1]) if w_t is not None else int(k)
        self.scale = scale
        self.mu = mu
        self._tl2: jax.Array | None = None

    @classmethod
    def from_ternary(cls, w_t: jax.Array, scale=1.0, *,
                     mu: int = 3) -> "GroupedTernaryWeight":
        return cls(w_t, scale, mu=mu)

    @classmethod
    def from_packed(cls, packed: jax.Array, scale, k: int, *,
                    mu: int = 3) -> "GroupedTernaryWeight":
        """Stacked deployment artifact ``{"packed" [E, N, ceil(K/5)],
        "scale" [E]}`` → container (this is what ``layers._expert_matmul``
        receives after the per-layer scan slice)."""
        return cls(None, scale, packed=packed, k=k, mu=mu)

    # -- shapes -------------------------------------------------------------

    @property
    def n_experts(self) -> int:
        src = self._w_t if self._w_t is not None else self._packed
        return int(src.shape[0])

    @property
    def out_features(self) -> int:
        src = self._w_t if self._w_t is not None else self._packed
        return int(src.shape[1])

    @property
    def in_features(self) -> int:
        return self._k

    # -- encodings (cached via module-level _concrete gate) ------------------

    def trits(self) -> jax.Array:
        """Dense stacked ``[E, N, K]`` int8 trits.  NOTE: this materializes
        the full expert stack — kernels should prefer :meth:`packed` and
        decode tile-by-tile (or per expert)."""
        if self._w_t is not None:
            return self._w_t
        w_t = encoding.unpack_base3(self._packed, self._k)
        if _concrete(w_t):
            self._w_t = w_t
        return w_t

    def packed(self) -> jax.Array:
        """Stacked base-3 packed bytes ``[E, N, ceil(K/5)]``."""
        if self._packed is not None:
            return self._packed
        packed = encoding.pack_base3(self._w_t)
        if _concrete(packed):
            self._packed = packed
        return packed

    def tl2(self) -> jax.Array:
        """Stacked TL2 base-9 words ``[E, N, ceil(K/10)]`` uint16."""
        if self._tl2 is not None:
            return self._tl2
        if self._packed is not None:
            words = repack_base3_to_tl2(self._packed, self._k)
        else:
            words = pack_tl2(self._w_t)
        if _concrete(words):
            self._tl2 = words
        return words


def _as_grouped_weight(w, scale, mu) -> GroupedTernaryWeight:
    if isinstance(w, GroupedTernaryWeight):
        return w
    w = jnp.asarray(w)
    if w.dtype != jnp.int8 or w.ndim != 3:
        raise TypeError(
            "grouped_ternary_matmul weights must be a GroupedTernaryWeight "
            f"or a stacked int8 trit array [E, N, K]; got dtype {w.dtype} "
            f"ndim {w.ndim}")
    return GroupedTernaryWeight.from_ternary(
        w, 1.0 if scale is None else scale, mu=mu or 3)


# ---------------------------------------------------------------------------
# Kernel registry
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class KernelSpec:
    """One registered ternary-matmul implementation.

    Dense kernels: ``run(x2, w, mu, interpret)`` consumes ``x2 [M, K]`` and
    returns the *unscaled* ``[M, N] float32`` product against ``w.trits()``.

    Grouped kernels (``grouped=True``): ``run(x3, gw, mu, interpret)``
    consumes ``x3 [E, C, K]`` against a :class:`GroupedTernaryWeight` and
    returns unscaled ``[E, C, N]`` (float32, or int32 cast to f32).  A
    grouped problem is keyed by its expert count ``e``; dense and grouped
    kernels are never eligible for each other's problems.
    """

    name: str
    run: Callable
    act_dtypes: frozenset
    pallas: bool                      # interpret-mode on CPU → prior penalty
    prior_per_mac: Callable           # (K, N, coeffs, mu) -> gates per MAC
    weight_bytes: Callable            # (K, N, mu) -> HBM bytes streamed
    describe: str = ""
    constraint: Callable | None = None  # (M, K, N, act_dtype) -> bool
    grouped: bool = False             # batched-expert (MoE) kernel
    grouped_variant: str | None = None  # dense kernel's grouped analogue
                                        # (fixed:<dense> pins map through it)

    def supports(self, m: int, k: int, n: int, act_dtype: str,
                 e: int | None = None) -> bool:
        if (e is not None) != self.grouped:
            return False
        if act_dtype not in self.act_dtypes:
            return False
        if self.constraint is not None and not self.constraint(m, k, n, act_dtype):
            return False
        return True


REGISTRY: dict[str, KernelSpec] = {}

_FLOAT_DTYPES = frozenset({"float32", "bfloat16", "float16"})
_ALL_DTYPES = _FLOAT_DTYPES | {"int8"}


def register_kernel(spec: KernelSpec) -> KernelSpec:
    if spec.name in REGISTRY:
        raise ValueError(f"kernel {spec.name!r} already registered")
    REGISTRY[spec.name] = spec
    return spec


def kernel_names() -> list[str]:
    return list(REGISTRY)


def get_kernel(name: str) -> KernelSpec:
    if name not in REGISTRY:
        raise KeyError(f"unknown kernel {name!r}; registered: {sorted(REGISTRY)}")
    return REGISTRY[name]


def eligible_kernels(m: int, k: int, n: int, act_dtype: str,
                     e: int | None = None) -> list[KernelSpec]:
    return [s for s in REGISTRY.values() if s.supports(m, k, n, act_dtype, e)]


# -- kernel adapters --------------------------------------------------------


def _to_f32(x2: jax.Array) -> jax.Array:
    return x2.astype(jnp.float32)


def _run_ref(x2, w, mu, interpret):
    # Pure-XLA oracle/deployment path: unpack (if packed) + dense f32 matmul.
    # This is both the correctness reference for every other kernel and the
    # fastest CPU execution of the packed serving artifact.
    wt = w.trits().astype(jnp.float32)
    return _to_f32(x2) @ wt.T


def _run_lut(fetch):
    def run(x2, w, mu, interpret):
        keys = w.keys(mu)
        G = keys.shape[-1]
        pad = G * mu - x2.shape[-1]
        if pad:
            x2 = jnp.pad(x2, ((0, 0), (0, pad)))
        return lut_matmul(_to_f32(x2), keys, mu, fetch=fetch, interpret=interpret)

    return run


def _run_dequant(x2, w, mu, interpret):
    return packed_matmul(_to_f32(x2), w.packed(), w.in_features,
                         interpret=interpret)


def _run_signflip(x2, w, mu, interpret):
    return signflip_matmul(_to_f32(x2), w.trits(), interpret=interpret)


def _run_w2a8(x2, w, mu, interpret):
    y = w2a8_matmul(x2, w.packed(), w.in_features, interpret=interpret)
    return y.astype(jnp.float32)


def _run_tl2(x2, w, mu, interpret):
    # tl2_matmul zero-pads x to the unpacked word width and casts to f32
    # itself (int8 casts losslessly), so int8 and float share one path.
    return tl2_matmul(x2, w.tl2(), w.in_features, interpret=interpret)


def _run_tl2_ref(x2, w, mu, interpret):
    return tl2_matmul_ref(x2, w.tl2(), w.in_features)


# -- grouped (batched-expert) adapters --------------------------------------


def _run_grouped_ref(x3, w, mu, interpret):
    # Pure-XLA grouped oracle/CPU-serving path: map over experts, decoding
    # each expert's packed bytes straight to f32 (one typed-table gather)
    # right before its matmul.  Only ONE expert's dense [N, K] tile is ever
    # live — the full-stack [E, N, K] dequant the eager einsum path paid
    # never materializes — and the jaxpr stays E-independent (a scan), so
    # llama4's E=128 stacks trace as fast as a 2-expert smoke config.
    k = w.in_features

    def one(args):
        xe, pe = args
        we = encoding.unpack_base3_to(pe, k, jnp.float32)  # [N, K] f32
        return _to_f32(xe) @ we.T

    return jax.lax.map(one, (x3, w.packed()))


def _run_grouped_dequant(x3, w, mu, interpret):
    return grouped_packed_matmul(_to_f32(x3), w.packed(), w.in_features,
                                 interpret=interpret)


def _run_grouped_w2a8(x3, w, mu, interpret):
    y = grouped_w2a8_matmul(x3, w.packed(), w.in_features,
                            interpret=interpret)
    return y.astype(jnp.float32)


def _run_grouped_tl2(x3, w, mu, interpret):
    # lax.map of the XLA pair-table ref over the expert stack: only one
    # expert's [C, N] tile plus its [N, G, 9] one-hot is live at a time and
    # the jaxpr stays E-independent (a scan), mirroring grouped_ref.
    k = w.in_features

    def one(args):
        xe, we = args
        return tl2_matmul_ref(xe, we, k)

    return jax.lax.map(one, (x3, w.tl2()))


# -- cost-model hooks (static prior) ----------------------------------------


def _per_mac_lut(k, n, c, mu):
    return cm.area_per_throughput(mu, max(k, mu), max(n, 1), c)


def _per_mac_dequant(k, n, c, mu):
    return cm.area_gates_dequant_baseline(k, n, c) / max(k * n, 1)


def _per_mac_signflip(k, n, c, mu):
    return cm.area_gates_signflip_baseline(k, n, c) / max(k * n, 1)


def _per_mac_dense(k, n, c, mu):
    # full-width multiplier + accumulator per MAC, no dequant cell
    return c.a_mul + c.a_add


def _bytes_dense(k, n, mu):
    return 2.0 * k * n          # bf16 dense weights


def _bytes_trits(k, n, mu):
    return float(k * n)         # int8 trit stream (signflip)


def _bytes_packed(k, n, mu):
    return n * math.ceil(k / encoding.TRITS_PER_BYTE)   # 1.6 b/w base-3


def _bytes_keys(k, n, mu):
    nbytes = 1 if encoding.key_bits(mu) <= 8 else 2
    return n * math.ceil(k / mu) * nbytes


def _per_mac_tl2(k, n, c, mu):
    # TL2 is the mu=2 point of the paper's LUT family: a trit *pair* keys a
    # 9-entry table, independent of the base-3 group size in play.
    return cm.area_per_throughput(2, max(k, 2), max(n, 1), c)


def _bytes_tl2(k, n, mu):
    return 2.0 * n * math.ceil(k / TRITS_PER_WORD)   # 1.6 b/w base-9 uint16


def _bytes_tl2_onehot_f32(k, n, mu):
    # the XLA TL2 refs materialize the decoded [N, ceil(K/2), 9] f32 one-hot
    # fetch operand through memory; charge that stream (as _bytes_decoded_f32
    # does for grouped_ref) so CPU serving keeps preferring the plain refs
    return 4.0 * 9.0 * n * math.ceil(k / 2)


register_kernel(KernelSpec(
    name="ref", run=_run_ref, act_dtypes=_ALL_DTYPES, pallas=False,
    prior_per_mac=_per_mac_dense, weight_bytes=_bytes_dense,
    grouped_variant="grouped_ref",
    describe="pure-XLA dense f32 matmul over decoded trits (oracle + CPU "
             "serving path)"))

register_kernel(KernelSpec(
    name="lut_onehot", run=_run_lut("onehot"), act_dtypes=_ALL_DTYPES,
    pallas=True, prior_per_mac=_per_mac_lut, weight_bytes=_bytes_keys,
    describe="two-phase LUT Pallas kernel, MXU-resident signed one-hot fetch",
    constraint=lambda m, k, n, d: True))

register_kernel(KernelSpec(
    name="lut_gather", run=_run_lut("gather"), act_dtypes=_ALL_DTYPES,
    pallas=True, prior_per_mac=_per_mac_lut, weight_bytes=_bytes_keys,
    describe="two-phase LUT Pallas kernel, VPU dynamic-gather fetch "
             "(literal read-out MUX)",
    constraint=lambda m, k, n, d: True))

register_kernel(KernelSpec(
    name="dequant_packed", run=_run_dequant, act_dtypes=_ALL_DTYPES,
    pallas=True, prior_per_mac=_per_mac_dequant, weight_bytes=_bytes_packed,
    grouped_variant="grouped_dequant",
    describe="base-3 packed streaming dequant Pallas kernel (1.6 b/w)"))

register_kernel(KernelSpec(
    name="signflip", run=_run_signflip, act_dtypes=_ALL_DTYPES,
    pallas=True, prior_per_mac=_per_mac_signflip, weight_bytes=_bytes_trits,
    describe="binary-plane MXU sign-flip baseline (Fig. 1 middle)"))

register_kernel(KernelSpec(
    name="w2a8", run=_run_w2a8, act_dtypes=frozenset({"int8"}),
    pallas=True, prior_per_mac=_per_mac_dequant, weight_bytes=_bytes_packed,
    grouped_variant="grouped_w2a8",
    describe="W1.58A8 exact int8×trit→int32 kernel (paper Table I operating "
             "point); requires pre-quantized int8 activations"))

register_kernel(KernelSpec(
    name="tl2", run=_run_tl2, act_dtypes=_ALL_DTYPES, pallas=True,
    prior_per_mac=_per_mac_tl2, weight_bytes=_bytes_tl2,
    grouped_variant="grouped_tl2",
    describe="TL2 two-trit → 9-entry LUT Pallas kernel (base-9 uint16 "
             "packing, 1.6 b/w; bitnet.cpp TL2 / T-MAC idiom)"))

register_kernel(KernelSpec(
    name="tl2_ref", run=_run_tl2_ref, act_dtypes=_ALL_DTYPES, pallas=False,
    prior_per_mac=_per_mac_tl2, weight_bytes=_bytes_tl2_onehot_f32,
    grouped_variant="grouped_tl2",
    describe="pure-XLA TL2 reference: dense pair-table build + one-hot "
             "fetch contractions over base-9 packed words"))


def _bytes_decoded_f32(k, n, mu):
    # grouped_ref streams the packed bytes AND round-trips a decoded f32
    # tile per expert through memory; charge the decoded stream so packed
    # in-VMEM decode wins the bandwidth-bound (decode) regime on hardware
    return 4.0 * k * n


register_kernel(KernelSpec(
    name="grouped_ref", run=_run_grouped_ref, act_dtypes=_ALL_DTYPES,
    pallas=False, grouped=True, prior_per_mac=_per_mac_dense,
    weight_bytes=_bytes_decoded_f32,
    describe="pure-XLA batched-expert matmul: lax.map over experts with "
             "per-expert f32 table decode (grouped oracle + CPU MoE serving "
             "path; no [E, N, K] dense intermediate)"))

register_kernel(KernelSpec(
    name="grouped_dequant", run=_run_grouped_dequant, act_dtypes=_ALL_DTYPES,
    pallas=True, grouped=True, prior_per_mac=_per_mac_dequant,
    weight_bytes=_bytes_packed,
    describe="grouped base-3 packed streaming dequant Pallas kernel: expert "
             "grid dim, tile-wise VMEM trit decode (1.6 b/w MoE path)"))

register_kernel(KernelSpec(
    name="grouped_w2a8", run=_run_grouped_w2a8,
    act_dtypes=frozenset({"int8"}), pallas=True, grouped=True,
    prior_per_mac=_per_mac_dequant, weight_bytes=_bytes_packed,
    describe="grouped W1.58A8 exact int8×trit→int32 Pallas kernel with an "
             "expert grid dim and per-expert rank-1 rescale on the way out"))

register_kernel(KernelSpec(
    name="grouped_tl2", run=_run_grouped_tl2, act_dtypes=_ALL_DTYPES,
    pallas=False, grouped=True, prior_per_mac=_per_mac_tl2,
    weight_bytes=_bytes_tl2_onehot_f32,
    describe="grouped TL2: lax.map of the XLA pair-table reference over the "
             "stacked base-9 expert words (no dense [E, N, K] intermediate)"))


# ---------------------------------------------------------------------------
# Static prior (analytical cost model)
# ---------------------------------------------------------------------------


def static_prior(spec: KernelSpec, m: int, k: int, n: int, act_dtype: str,
                 backend: str | None = None, mu: int = 3,
                 e: int | None = None) -> float:
    """Analytical cost score for running ``spec`` on an ``[m,k]×[n,k]``
    matmul: per-MAC gate cost from the paper's area model (Eqs. 5-10 /
    Fig. 1 baselines) × MAC count, plus the weight bytes streamed from HBM
    weighted at :data:`GATES_PER_BYTE`.  Lower is better.  On backends that
    interpret Pallas (CPU) the Pallas kernels carry
    :data:`INTERPRET_PENALTY` so the prior reflects wall-clock reality
    there; the autotune cache overrides the prior either way.

    Grouped problems pass the expert count ``e``; ``m`` is then the
    *per-expert* capacity ``C``.  Both terms scale by ``e`` — every expert's
    weights stream every step regardless of how many tokens routed to it, so
    at decode (tiny ``C``) the weight-bytes term dominates exactly as in the
    paper's decode-is-bandwidth-bound regime and the 1.6 b/w grouped paths
    prevail over dense-decoding ones.
    """
    backend = backend or jax.default_backend()
    coeffs = cm.get_coeffs("int8" if act_dtype == "int8" else "fp16")
    compute = float(m) * k * n * spec.prior_per_mac(k, n, coeffs, mu)
    traffic = GATES_PER_BYTE * spec.weight_bytes(k, n, mu)
    cost = (compute + traffic) * (e if e is not None else 1)
    if spec.pallas and backend != "tpu":
        cost *= INTERPRET_PENALTY
    return cost


# ---------------------------------------------------------------------------
# Autotune cache
# ---------------------------------------------------------------------------


def _default_cache_path() -> str:
    return os.environ.get(
        CACHE_PATH_ENV,
        os.path.join(os.path.expanduser("~"), ".cache", "repro", "autotune.json"))


#: current on-disk schema.  v2 added the grouped (batched-expert) key form
#: ``E<e>:M<C>:K..:N..`` — v1 files hold only dense keys, which are
#: unchanged, so v1 entries load as-is.
CACHE_SCHEMA_VERSION = 2
_COMPATIBLE_SCHEMAS = {1, CACHE_SCHEMA_VERSION}


@dataclass
class AutotuneCache:
    """Disk-persisted measurements: ``(M,K,N,dtype,backend) → {kernel: µs}``,
    grouped problems keyed with their expert count prepended.

    JSON format (schema_version 2)::

        {"schema_version": 2,
         "entries": {"M8:K1024:N512:mu3:float32:cpu": {"ref": 410.2, ...},
                     "E16:M4:K4096:N6400:mu3:bfloat16:tpu": {...}}}

    ``mu`` is part of the key: LUT key-decode cost and bytes streamed scale
    with the group size, so timings at one mu must not steer another.  For
    grouped keys ``M`` is the *per-expert* capacity ``C``.
    """

    path: str = field(default_factory=_default_cache_path)
    entries: dict = field(default_factory=dict)

    @staticmethod
    def key(m: int, k: int, n: int, act_dtype: str, backend: str, *,
            mu: int = 3, e: int | None = None) -> str:
        prefix = f"E{e}:" if e is not None else ""
        return f"{prefix}M{m}:K{k}:N{n}:mu{mu}:{act_dtype}:{backend}"

    @classmethod
    def load(cls, path: str | None = None) -> "AutotuneCache":
        path = path or _default_cache_path()
        entries = {}
        try:
            with open(path) as fh:
                doc = json.load(fh)
            if isinstance(doc, dict) and \
                    doc.get("schema_version") in _COMPATIBLE_SCHEMAS:
                entries = doc.get("entries", {})
        except (OSError, ValueError):
            pass
        return cls(path=path, entries=entries)

    def save(self) -> None:
        """Atomically persist: write a *unique* temp file in the target
        directory, fsync, then ``os.replace``.  Readers never observe a
        partial file (mid-write kill) and concurrent writers (parallel
        ``autotune_sweep.py`` runs) cannot interleave into each other's temp
        file — last replace wins whole."""
        d = os.path.dirname(self.path) or "."
        os.makedirs(d, exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=d, prefix=".autotune-", suffix=".tmp")
        try:
            with os.fdopen(fd, "w") as fh:
                json.dump({"schema_version": CACHE_SCHEMA_VERSION,
                           "entries": self.entries}, fh,
                          indent=1, sort_keys=True)
                fh.flush()
                os.fsync(fh.fileno())
            os.replace(tmp, self.path)
        except BaseException:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise

    def record(self, m: int, k: int, n: int, act_dtype: str, backend: str,
               kernel: str, us: float, *, mu: int = 3,
               e: int | None = None) -> None:
        key = self.key(m, k, n, act_dtype, backend, mu=mu, e=e)
        self.entries.setdefault(key, {})[kernel] = us

    def timings(self, m, k, n, act_dtype, backend, *, mu: int = 3,
                e: int | None = None) -> dict[str, float]:
        return dict(self.entries.get(
            self.key(m, k, n, act_dtype, backend, mu=mu, e=e), {}))

    def best(self, m: int, k: int, n: int, act_dtype: str,
             backend: str, *, mu: int = 3, e: int | None = None) -> str | None:
        t = self.timings(m, k, n, act_dtype, backend, mu=mu, e=e)
        t = {name: us for name, us in t.items() if name in REGISTRY}
        return min(t, key=t.get) if t else None

    def __len__(self) -> int:
        return len(self.entries)


_CACHE: AutotuneCache | None = None


def get_autotune_cache() -> AutotuneCache:
    global _CACHE
    if _CACHE is None:
        _CACHE = AutotuneCache.load()
    return _CACHE


def reset_autotune_cache() -> None:
    """Drop the in-process cache (re-reads REPRO_AUTOTUNE_CACHE on next use)."""
    global _CACHE
    _CACHE = None


# ---------------------------------------------------------------------------
# Mesh-sharded dispatch: per-shard problem localization
# ---------------------------------------------------------------------------


def _div(dim: int, parts: int) -> int:
    """Per-shard extent of ``dim`` split ``parts``-ways — only when the split
    is even (mirrors ``sharding._validate``: a non-divisible dim falls back
    to replication, so its dispatch extent stays global)."""
    return dim // parts if parts > 1 and dim % parts == 0 else dim


@dataclass(frozen=True)
class ShardInfo:
    """Trace-time mesh geometry for per-shard kernel dispatch.

    Under GSPMD the traced shapes are *global*, but each device executes the
    *local* shard of every matmul — so kernel selection and autotune-cache
    keys must be derived from the per-shard problem, not the global one.
    ``ShardInfo`` translates a global problem to its local shard using the
    same name-based TP/EP rules as ``repro.parallel.sharding``:

      * ``model``: TP degree — out-projection roles (``wq``/``wi``/...)
        shard N, in-projection roles (``wo``/``down``/...) shard K;
      * ``data``:  EP degree — grouped (MoE) problems shard the expert dim;
      * ``batch``: divisor for the dense M dim (batch-sharded activations;
        the engine sets it per entry point, since chunked prefill runs one
        request at a time and must not divide its M = chunk extent).

    An unknown/None role leaves K and N untouched (the weight is replicated).
    Activated via :func:`shard_scope`; dispatch outside any scope is exactly
    the single-device behavior.

    ``n_heads``/``n_kv_heads`` mirror the head-gated attention rule
    (``sharding.param_specs(heads=...)``): qkv projections only shard their
    out dim at whole-head granularity, so when the head count doesn't divide
    ``model`` the weight is replicated and N stays global here too.  Zero
    (the default) disables the gate — legacy flat-dim sharding.
    """

    model: int = 1
    data: int = 1
    batch: int = 1
    n_heads: int = 0
    n_kv_heads: int = 0

    def local_dense(self, role: str | None, m: int, k: int, n: int):
        from repro.parallel.sharding import (_NO_TP_ROLES, _SPLIT_ROLES,
                                             TP_IN_ROLES, TP_OUT_ROLES)

        m = _div(m, self.batch)
        if role in TP_OUT_ROLES:
            # partial-replication gate (ssm wz): replicated whenever batch
            # axes coexist with model parallelism — mirrors _param_spec
            if role in _NO_TP_ROLES:
                if self.data == 1:
                    n = _div(n, self.model)
                return m, k, n
            # split-site gate (xlstm ffn_up/up, ssm wx): architecture-
            # constant segment counts, always on — mirrors _param_spec
            seg = _SPLIT_ROLES.get(role)
            if seg is not None:
                if seg % self.model == 0:
                    n = _div(n, self.model)
                return m, k, n
            h = {"wq": self.n_heads, "wk": self.n_kv_heads,
                 "wv": self.n_kv_heads}.get(role, 0)
            if not h or h % self.model == 0:
                n = _div(n, self.model)
        elif role in TP_IN_ROLES:
            # column-parallel packed layout: the decode path's in-projections
            # shard dout (see sharding._IN_MODEL — byte-dim sharding breaks
            # the base-3 unpack's logical-K slice), so the local problem has
            # a full K and an N divided by the TP degree
            n = _div(n, self.model)
        return m, k, n

    def local_grouped(self, role: str | None, e: int, c: int, k: int, n: int):
        """MoE expert stacks: EP shards E on data; inside each expert the
        up-projections (``wi``/``wg``) shard N and the down-projection
        (``wo``/``down``) shards K on model — mirroring the ``moe`` packed
        rules in ``sharding._param_spec``.  Capacity C stays global (token
        routing is not capacity-sharded)."""
        e = _div(e, self.data)
        if role in ("wi", "wg"):
            n = _div(n, self.model)
        elif role in ("wo", "down"):
            k = _div(k, self.model)
        return e, c, k, n


_SHARD_INFO: ShardInfo | None = None


@contextmanager
def shard_scope(info: ShardInfo | None):
    """Activate ``info`` for every dispatch decision made inside the body.

    Entered at *trace* time (the mesh-mode engine wraps its jitted entry
    points) — selection happens while tracing, so the scope never needs to
    survive into compiled execution.  ``None`` is a no-op scope."""
    global _SHARD_INFO
    prev = _SHARD_INFO
    _SHARD_INFO = info
    try:
        yield
    finally:
        _SHARD_INFO = prev


def current_shard_info() -> ShardInfo | None:
    return _SHARD_INFO


# ---------------------------------------------------------------------------
# Selection + public entry point
# ---------------------------------------------------------------------------


def _act_dtype_name(x: jax.Array) -> str:
    return jnp.dtype(x.dtype).name


def select_kernel(m: int, k: int, n: int, act_dtype: str, *,
                  policy: str | None = None, backend: str | None = None,
                  cache: AutotuneCache | None = None,
                  mu: int = 3, e: int | None = None,
                  role: str | None = None) -> KernelSpec:
    """Resolve a policy to a registered kernel for the given problem.

    Policies:
      * ``"fixed:<name>"`` — always use ``<name>`` (reproducibility pin);
        raises if the kernel does not support the dtype/shape.
      * ``"auto"`` — autotune-cache best if measured, else analytical prior.
      * ``"prior"`` — analytical prior only (ignore the cache).

    ``policy=None`` reads ``$REPRO_TERNARY_POLICY``, defaulting to ``auto``.

    Grouped (batched-expert) problems pass ``e`` (the expert count, with
    ``m`` the per-expert capacity); only grouped kernels are then eligible.
    A ``fixed:<dense-kernel>`` pin resolves through the dense kernel's
    ``grouped_variant`` (``ref → grouped_ref`` etc.) so ONE policy string
    governs a whole model — MoE layers included; pinning a dense kernel with
    no grouped analogue (the LUT/sign-flip paths) raises on MoE problems.

    Under an active :func:`shard_scope`, the problem dims are first mapped
    to their per-device shard via ``role`` (the projection's parameter-leaf
    name, e.g. ``"wq"``/``"wo"``) — cache lookups and the prior then score
    the *local* problem each device actually executes, keyed with the
    unchanged schema-v2 key format at the local dims.
    """
    policy = policy or os.environ.get(DEFAULT_POLICY_ENV, "auto")
    backend = backend or jax.default_backend()
    info = _SHARD_INFO
    if info is not None:
        if e is not None:
            e, m, k, n = info.local_grouped(role, e, m, k, n)
        else:
            m, k, n = info.local_dense(role, m, k, n)

    if policy.startswith("fixed:"):
        spec = get_kernel(policy[len("fixed:"):])
        if e is not None and not spec.grouped:
            if spec.grouped_variant is None:
                raise ValueError(
                    f"kernel {spec.name!r} has no grouped (batched-expert) "
                    f"variant; MoE expert matmuls cannot honour policy "
                    f"'fixed:{spec.name}'. Pin one of "
                    f"{sorted(s.name for s in REGISTRY.values() if s.grouped)}"
                    f" or a dense kernel with a grouped analogue "
                    f"{sorted(s.name for s in REGISTRY.values() if s.grouped_variant)}")
            spec = get_kernel(spec.grouped_variant)
        if not spec.supports(m, k, n, act_dtype, e):
            raise ValueError(
                f"kernel {spec.name!r} does not support M={m} K={k} N={n} "
                f"E={e} act_dtype={act_dtype} (supported dtypes: "
                f"{sorted(spec.act_dtypes)}; grouped={spec.grouped})")
        return spec

    if policy not in ("auto", "prior"):
        raise ValueError(
            f"unknown policy {policy!r}; expected 'auto', 'prior', or "
            f"'fixed:<name>' with name in {sorted(REGISTRY)}")

    candidates = eligible_kernels(m, k, n, act_dtype, e)
    if not candidates:
        raise ValueError(f"no registered kernel supports M={m} K={k} N={n} "
                         f"E={e} act_dtype={act_dtype}")

    if policy == "auto":
        cache = cache or get_autotune_cache()
        best = cache.best(m, k, n, act_dtype, backend, mu=mu, e=e)
        if best is not None and get_kernel(best).supports(m, k, n, act_dtype, e):
            return get_kernel(best)

    # name tiebreak keeps selection deterministic across dict orderings
    return min(candidates,
               key=lambda s: (static_prior(s, m, k, n, act_dtype, backend,
                                           mu, e),
                              s.name))


def _default_interpret() -> bool:
    """Pallas interpret mode everywhere except real TPU hardware."""
    return jax.default_backend() != "tpu"


def ternary_matmul(x: jax.Array, w, *, scale=None, policy: str | None = None,
                   mu: int | None = None, interpret: bool | None = None,
                   backend: str | None = None,
                   cache: AutotuneCache | None = None,
                   role: str | None = None) -> jax.Array:
    """``y[..., n] = Σ_k x[..., k] · trits(w)[n, k] · scale`` via the best
    registered kernel for this (shape, dtype, backend).

    Args:
      x: ``[..., K]`` activations — float (fp32/bf16/fp16) or pre-quantized
        int8 (routes the W1.58A8 paths; caller applies the activation scale).
      w: :class:`TernaryWeight`, :class:`repro.core.encoding.PackedTernary`,
        or an int8 trit matrix ``[N, K]``.
      scale: overrides ``w``'s weight scale (rank-1 correction, applied once).
      policy: ``"auto"`` | ``"prior"`` | ``"fixed:<name>"``; ``None`` reads
        ``$REPRO_TERNARY_POLICY`` (default ``auto``).
      mu: LUT group size override (default: the weight's, typically 3).
      interpret: run Pallas kernels in interpret mode; ``None`` (default)
        resolves from the executing backend — compiled on real TPU,
        interpret everywhere else.
      role: the projection's parameter-leaf name (``"wq"``, ``"wo"``, ...);
        only consulted under an active :func:`shard_scope`, where it decides
        which dim the TP axis shards so dispatch keys on the local problem.

    Returns ``[..., N]`` in ``x``'s dtype (float inputs) or float32 (int8
    inputs).  Selection happens at Python/trace time from *static* shapes, so
    the call is jit-compatible; under jit the choice is frozen into the
    compiled executable.
    """
    tw = _as_weight(w, scale, mu)
    mu = mu or tw.mu
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    m, k = int(np.prod(lead)) if lead else 1, x.shape[-1]
    if k != tw.in_features:
        raise ValueError(f"x K={k} != weight K={tw.in_features}")
    n = tw.out_features
    act = _act_dtype_name(x)

    spec = select_kernel(m, k, n, act, policy=policy, backend=backend,
                         cache=cache, mu=mu, role=role)
    if interpret is None:
        interpret = _default_interpret()
    y = spec.run(x2, tw, mu, interpret)
    s = tw.scale if scale is None else scale
    if s is not None:
        y = y * jnp.asarray(s, jnp.float32)
    out_dtype = jnp.float32 if act == "int8" else x.dtype
    return y.reshape(*lead, n).astype(out_dtype)


def grouped_ternary_matmul(x: jax.Array, w, *, scale=None,
                           policy: str | None = None, mu: int | None = None,
                           interpret: bool | None = None,
                           backend: str | None = None,
                           cache: AutotuneCache | None = None,
                           role: str | None = None) -> jax.Array:
    """``y[e, ..., n] = Σ_k x[e, ..., k] · trits(w)[e, n, k] · scale[e]`` —
    the batched-expert (MoE) entry point of the dispatch layer.

    Args:
      x: ``[E, ..., K]`` per-expert activation rows (MoE dispatch buffers
        ``[E, C, K]``) — float, or pre-quantized int8 for the W1.58A8 path.
      w: :class:`GroupedTernaryWeight` or stacked int8 trits ``[E, N, K]``.
      scale: overrides ``w``'s per-expert scale ``[E]`` (rank-1, applied
        once on the way out).
      policy / mu / interpret / backend / cache / role: as
        :func:`ternary_matmul`; ``fixed:<dense>`` pins map through the dense
        kernel's grouped variant so one policy string governs dense and MoE
        layers alike, and ``role`` (under a :func:`shard_scope`) localizes
        the EP-sharded expert dim and the TP-sharded K or N.

    Returns ``[E, ..., N]`` in ``x``'s dtype (float in) or float32 (int8
    in).  Selection is static-shape/trace-time, keyed on
    ``(E, C, K, N, dtype, backend)``.
    """
    gw = _as_grouped_weight(w, scale, mu)
    mu = mu or gw.mu
    if x.ndim < 2 or x.shape[0] != gw.n_experts:
        raise ValueError(
            f"grouped activations must be [E, ..., K] with E="
            f"{gw.n_experts}; got shape {x.shape}")
    lead = x.shape[1:-1]
    k = x.shape[-1]
    if k != gw.in_features:
        raise ValueError(f"x K={k} != weight K={gw.in_features}")
    E, n = gw.n_experts, gw.out_features
    x3 = x.reshape(E, -1, k)
    c = int(np.prod(lead)) if lead else 1
    act = _act_dtype_name(x)

    spec = select_kernel(c, k, n, act, policy=policy, backend=backend,
                         cache=cache, mu=mu, e=E, role=role)
    if interpret is None:
        interpret = _default_interpret()
    y = spec.run(x3, gw, mu, interpret)
    s = gw.scale if scale is None else scale
    if s is not None:
        s = jnp.asarray(s, jnp.float32)
        y = y * (s.reshape(E, *([1] * (y.ndim - 1))) if s.ndim else s)
    out_dtype = jnp.float32 if act == "int8" else x.dtype
    return y.reshape(E, *lead, n).astype(out_dtype)


# ---------------------------------------------------------------------------
# Autotuning
# ---------------------------------------------------------------------------


def autotune(m: int, k: int, n: int, act_dtype: str = "float32", *,
             kernels: list[str] | None = None, reps: int = 3, seed: int = 0,
             interpret: bool | None = None, backend: str | None = None,
             cache: AutotuneCache | None = None, save: bool = True,
             mu: int = 3, e: int | None = None) -> dict[str, float]:
    """Benchmark every eligible kernel on an ``[m,k]×[n,k]`` problem and
    record the wall-times (µs) in the autotune cache.

    Timing reproduces the serving data path: the 1.6 b/w packed artifact
    enters the jitted function as an *argument*, so kernels that derive
    trits/keys (ref, signflip, lut_*) pay that per-step decode inside the
    measurement, exactly as ``layers.linear`` does — not from baked-in
    constants, which would bias selection against the in-kernel-decode paths.

    Pass ``e`` to tune a grouped (batched-expert) problem: ``m`` is then the
    per-expert capacity ``C``, operands are stacked ``[e, m, k]`` acts ×
    ``[e, n, ceil(k/5)]`` packed, and only grouped kernels run.

    Returns ``{kernel_name: µs}``.  Subsequent ``policy="auto"`` dispatches
    for the same ``(M, K, N, dtype, backend)`` (+ ``E`` if grouped) use the
    measured best.
    """
    if reps < 1:
        raise ValueError(f"reps must be >= 1, got {reps}")
    local = jax.default_backend()
    backend = backend or local
    if backend != local:
        # timings are taken on the local device; recording them under another
        # backend's cache key would poison that backend's auto dispatch
        raise ValueError(f"autotune measures on the local backend {local!r}; "
                         f"cannot record for backend={backend!r}")
    if interpret is None:
        interpret = _default_interpret()
    cache = cache or get_autotune_cache()
    rng = np.random.default_rng(seed)
    lead = () if e is None else (e,)
    if act_dtype == "int8":
        x = jnp.asarray(rng.integers(-127, 128, size=(*lead, m, k)), jnp.int8)
    else:
        x = jnp.asarray(rng.normal(size=(*lead, m, k)), act_dtype)
    packed = encoding.pack_base3(
        jnp.asarray(rng.integers(-1, 2, size=(*lead, n, k)), jnp.int8))

    names = kernels or [s.name
                        for s in eligible_kernels(m, k, n, act_dtype, e)]
    results: dict[str, float] = {}
    for name in names:
        spec = get_kernel(name)
        if not spec.supports(m, k, n, act_dtype, e):
            continue

        def call(xx, pk, run=spec.run):
            cls = TernaryWeight if e is None else GroupedTernaryWeight
            return run(xx, cls.from_packed(pk, 1.0, k, mu=mu), mu, interpret)

        fn = jax.jit(call)
        try:
            jax.block_until_ready(fn(x, packed))  # compile + warm
            t0 = time.perf_counter()
            for _ in range(reps):
                y = fn(x, packed)
            jax.block_until_ready(y)
            us = (time.perf_counter() - t0) / reps * 1e6
        except Exception as exc:  # pragma: no cover - kernel unavailable on backend
            tag = f"E{e} " if e is not None else ""
            warnings.warn(f"autotune: kernel {name!r} failed on "
                          f"{tag}M{m} K{k} N{n} {act_dtype}/{backend}: {exc}")
            continue
        results[name] = us
        cache.record(m, k, n, act_dtype, backend, name, us, mu=mu, e=e)
    if save and results:
        cache.save()
    return results
