"""Pallas TPU kernels: grouped (batched-expert) ternary matmuls.

The MoE datapath the paper's bandwidth math requires: expert weights stay in
HBM as stacked base-3 packed bytes ``[E, O, ceil(N/5)]`` (1.6 b/w) and every
expert's tile is expanded to trits **in VMEM** right before its MXU
contraction — the grid gains a leading expert dimension, so one kernel launch
covers the whole expert stack without ever materializing a dense
``[E, O, N]`` weight tensor.

Two variants mirror the dense kernel family:

  * :func:`grouped_packed_matmul` — float activations (bf16/f32 serving
    path), f32 accumulation: the grouped analogue of
    ``dequant_matmul.packed_matmul``;
  * :func:`grouped_w2a8_matmul` — pre-quantized int8 activations, exact
    int32 accumulation: the grouped analogue of ``w2a8_matmul`` (the paper's
    Table-I W1.58A8 operating point, per expert).

Per-expert absmean scales are a rank-1 correction applied by the caller on
the way out (``y * scale[:, None, None]``), same convention as the dense
kernels.  Decode-time expert capacity ``C`` is tiny (often 1), so the
activation block is padded up — the launch stays profitable because the win
is streamed weight bytes, not MACs.

Under a serving mesh the expert stack is expert-parallel on the ``data``
axis and each expert's matmul tensor-parallel on ``model`` (wi/wg shard N,
wo shards K — rules in ``repro/parallel/sharding.py``); dispatch sees the
**per-shard** problem (local ``E``/``K``/``N`` via
``kernels.dispatch.ShardInfo.local_grouped``), so autotune cache keys and
backend choice follow what each device actually runs, not the global shapes.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.encoding import TRITS_PER_BYTE
from repro.kernels.dequant_matmul import _unpack_block


def _grouped_kernel(acc_dtype):
    def kernel(x_ref, p_ref, out_ref):
        """x_ref [1, bc, bn]; p_ref [1, bo, bn//5]; out [1, bc, bo]."""
        k = pl.program_id(3)
        x = x_ref[0]
        w = _unpack_block(p_ref[0], x.dtype)  # [bo, bn] trits in act dtype
        partial = jax.lax.dot_general(
            x, w, dimension_numbers=(((1,), (1,)), ((), ())),
            preferred_element_type=acc_dtype,
        )

        @pl.when(k == 0)
        def _init():
            out_ref[...] = jnp.zeros_like(out_ref)

        out_ref[0] += partial

    return kernel


def _pad_and_call(x, packed, *, block_c, block_o, block_n, interpret,
                  acc_dtype):
    """Shared pad-to-blocks + pallas_call for both grouped variants.

    x: [E, C, N]; packed: [E, O, ceil(N/5)].  Returns [E, C, O] acc_dtype.
    Padding follows the dense kernels' scheme: x columns zero-pad to the full
    unpacked width (pad *bytes* decode to -1 trits but meet zero activations,
    so products vanish); padded C/O rows are sliced off after the call.
    """
    E, C, N = x.shape
    _, O, NB = packed.shape
    full = NB * TRITS_PER_BYTE
    if N < full:
        x = jnp.pad(x, ((0, 0), (0, 0), (0, full - N)))
    N = full
    block_n = min(block_n, N)
    block_n -= block_n % TRITS_PER_BYTE
    block_c = min(block_c, C)
    block_o = min(block_o, O)
    pad_c, pad_o, pad_n = (-C) % block_c, (-O) % block_o, (-N) % block_n
    if pad_c or pad_n:
        x = jnp.pad(x, ((0, 0), (0, pad_c), (0, pad_n)))
    if pad_o or pad_n:
        packed = jnp.pad(packed,
                         ((0, 0), (0, pad_o), (0, pad_n // TRITS_PER_BYTE)))
    Cp, Op, Np = C + pad_c, O + pad_o, N + pad_n

    out = pl.pallas_call(
        _grouped_kernel(acc_dtype),
        grid=(E, Cp // block_c, Op // block_o, Np // block_n),
        in_specs=[
            pl.BlockSpec((1, block_c, block_n),
                         lambda e, i, j, k: (e, i, k)),
            pl.BlockSpec((1, block_o, block_n // TRITS_PER_BYTE),
                         lambda e, i, j, k: (e, j, k)),
        ],
        out_specs=pl.BlockSpec((1, block_c, block_o),
                               lambda e, i, j, k: (e, i, j)),
        out_shape=jax.ShapeDtypeStruct((E, Cp, Op), acc_dtype),
        interpret=interpret,
    )(x, packed)
    return out[:, :C, :O]


@functools.partial(
    jax.jit,
    static_argnames=("n", "block_c", "block_o", "block_n", "interpret"))
def grouped_packed_matmul(
    x: jax.Array,
    packed: jax.Array,
    n: int,
    *,
    block_c: int = 8,
    block_o: int = 128,
    block_n: int = 640,  # multiple of 5 (pack group) and 128 (lanes)
    interpret: bool = True,
) -> jax.Array:
    """y[e, c, o] = Σ_n x[e, c, n] · unpack(packed[e])[o, n] (f32).

    Args:
      x:      [E, C, N] float activations (per-expert capacity rows).
      packed: [E, O, ceil(N/5)] stacked base-3 packed ternary weights (the
        byte dim may carry alignment padding past ``ceil(n/5)``).
      n:      logical N (columns beyond n are zero by construction).
    """
    if x.shape[0] != packed.shape[0]:
        raise ValueError(f"expert dims differ: x {x.shape} vs packed "
                         f"{packed.shape}")
    if x.shape[-1] < n or packed.shape[-1] * TRITS_PER_BYTE < n:
        raise ValueError((x.shape, packed.shape, n))
    return _pad_and_call(x, packed, block_c=block_c, block_o=block_o,
                         block_n=block_n, interpret=interpret,
                         acc_dtype=jnp.float32)


@functools.partial(
    jax.jit,
    static_argnames=("n", "block_c", "block_o", "block_n", "interpret"))
def grouped_w2a8_matmul(
    x_q: jax.Array,
    packed: jax.Array,
    n: int,
    *,
    block_c: int = 8,
    block_o: int = 128,
    block_n: int = 640,
    interpret: bool = True,
) -> jax.Array:
    """Exact int32 y[e, c, o] = Σ_n x_q[e, c, n] · trits(packed[e])[o, n].

    x_q: [E, C, N] int8 (per-token quantized activations, routed per expert).
    packed: [E, O, ceil(N/5)] stacked base-3 ternary weights.
    """
    if x_q.shape[0] != packed.shape[0]:
        raise ValueError(f"expert dims differ: x {x_q.shape} vs packed "
                         f"{packed.shape}")
    if x_q.shape[-1] < n or packed.shape[-1] * TRITS_PER_BYTE < n:
        raise ValueError((x_q.shape, packed.shape, n))
    return _pad_and_call(x_q, packed, block_c=block_c, block_o=block_o,
                         block_n=block_n, interpret=interpret,
                         acc_dtype=jnp.int32)
