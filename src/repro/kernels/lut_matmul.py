"""Pallas TPU kernel for the two-phase LUT ternary matmul (paper Fig. 2/3).

TPU mapping of the paper's architecture (see DESIGN.md §3):

* **Build phase** — for each group of ``mu`` activations, the symmetry-reduced
  partial-sum table is a tiny dense contraction ``x_groups @ C.T`` with the
  ternary combo matrix ``C`` [T+1, mu].  On TPU this runs on the MXU; the
  hardware's optimized adder tree *is* this contraction (C's zeros = sparsity
  pruning, its ±1 structure = conditional add).
* **Fetch & accumulate phase** — two selectable lowerings:
  - ``fetch="onehot"``: signed one-hot of the weight keys contracted against
    the tables (MXU-resident; the symmetry sign-flip is folded into the
    one-hot values — a "free" inversion exactly like the FAC unit's).
  - ``fetch="gather"``: ``take_along_axis`` per group (VPU dynamic gather,
    closest to the literal read-out MUX).

Tiling: grid = (B/bb, O/bo, G/bg); the reduction over group-tiles is the
innermost grid dim with a VMEM accumulator in the output ref, mirroring the
output-stationary Output Buffer of Fig. 3.  ``L`` (parallel LUTs) maps to the
``bg`` groups resident in VMEM; ``K`` (parallel fetchers) maps to ``bo``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import encoding


def _lut_kernel(x_ref, keys_ref, out_ref, *, mu: int, fetch: str):
    """One (bb, bo) output tile, one bg-group reduction step.

    x_ref:    [bb, bg*mu]   activation slice (float)
    keys_ref: [bo, bg]      encoded ternary weight keys (uint8/uint16)
    out_ref:  [bb, bo]      accumulator (float32)
    """
    k = pl.program_id(2)
    bb, bgmu = x_ref.shape
    bg = bgmu // mu
    T = encoding.table_size(mu)
    ib = encoding.idx_bits(mu)

    # ---- Build phase: tables[b, g, t] = dot(C[t], x[b, g*mu:(g+1)*mu]) ----
    # The combo matrix is synthesized in-kernel from iota arithmetic (Pallas
    # kernels cannot capture array constants): row t holds the base-3 digits
    # of v = center+1+t, minus 1; the reserved row T is the all-zero combo.
    ti = jax.lax.broadcasted_iota(jnp.int32, (T + 1, mu), 0)
    di = jax.lax.broadcasted_iota(jnp.int32, (T + 1, mu), 1)
    v = jnp.where(ti == T, T, T + 1 + ti)  # center == T
    C = (v // (3**di)) % 3 - 1  # [T+1, mu] in {-1,0,1}
    xg = x_ref[...].reshape(bb, bg, mu)
    tables = jax.lax.dot_general(
        xg, C.astype(xg.dtype),
        dimension_numbers=(((2,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # [bb, bg, T+1]

    # ---- Fetch & accumulate phase ----
    keys = keys_ref[...].astype(jnp.int32)  # [bo, bg]
    sym = keys >> ib
    idx = keys & ((1 << ib) - 1)
    sign = jnp.where(sym == 1, -1.0, 1.0).astype(jnp.float32)  # [bo, bg]

    if fetch == "onehot":
        # Signed one-hot: [bo, bg, T+1]; sign folded in (free inversion).
        iota = jax.lax.broadcasted_iota(jnp.int32, (*idx.shape, T + 1), 2)
        oh = jnp.where(iota == idx[..., None], sign[..., None], 0.0)
        partial = jax.lax.dot_general(
            tables.astype(jnp.float32), oh,
            dimension_numbers=(((1, 2), (1, 2)), ((), ())),
            preferred_element_type=jnp.float32,
        )  # [bb, bo]
    elif fetch == "gather":
        # Literal read-out MUX: gather entry idx[o, g] from tables[b, g, :].
        idx_b = jnp.broadcast_to(idx.T[None], (bb, bg, idx.shape[0]))  # [bb,bg,bo]
        fetched = jnp.take_along_axis(tables.astype(jnp.float32), idx_b, axis=2)
        partial = jnp.sum(fetched * sign.T[None], axis=1)  # [bb, bo]
    else:  # pragma: no cover - guarded by ops wrapper
        raise ValueError(fetch)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += partial


@functools.partial(
    jax.jit,
    static_argnames=("mu", "block_b", "block_o", "block_g", "fetch", "interpret"),
)
def lut_matmul(
    x: jax.Array,
    keys: jax.Array,
    mu: int,
    *,
    block_b: int = 8,
    block_o: int = 128,
    block_g: int = 128,
    fetch: str = "onehot",
    interpret: bool = True,
) -> jax.Array:
    """Two-phase LUT matmul: ``y[b, o] = Σ_n x[b, n] · decode(keys)[o, n]``.

    Args:
      x:    [B, N] activations (f32/bf16); N must equal keys.shape[1] * mu.
      keys: [O, G] encoded weight keys (``encoding.encode_weight_matrix``).
      mu:   LUT group size.
      block_*: VMEM tile sizes (the generator's KernelPlan supplies aligned
        values for real TPU; tests shrink them).
      interpret: run the kernel body in interpret mode (CPU container);
        False targets real TPU hardware.

    Returns:
      [B, O] float32.
    """
    B, N = x.shape
    O, G = keys.shape
    if N != G * mu:
        raise ValueError(f"N={N} != G*mu={G * mu}")

    block_b = min(block_b, B)
    block_o = min(block_o, O)
    block_g = min(block_g, G)
    pad_b = (-B) % block_b
    pad_o = (-O) % block_o
    pad_g = (-G) % block_g
    if pad_b or pad_g:
        x = jnp.pad(x, ((0, pad_b), (0, pad_g * mu)))
    if pad_o or pad_g:
        # padded groups encode all-zero (key 'T' with sym=0 fetches the
        # hardwired zero entry)
        zero_key = jnp.full((1,), encoding.table_size(mu), dtype=keys.dtype)
        keys = jnp.pad(keys, ((0, pad_o), (0, pad_g)), constant_values=zero_key[0])
    Bp, Op, Gp = B + pad_b, O + pad_o, G + pad_g

    grid = (Bp // block_b, Op // block_o, Gp // block_g)
    out = pl.pallas_call(
        functools.partial(_lut_kernel, mu=mu, fetch=fetch),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, block_g * mu), lambda i, j, k: (i, k)),
            pl.BlockSpec((block_o, block_g), lambda i, j, k: (j, k)),
        ],
        out_specs=pl.BlockSpec((block_b, block_o), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Bp, Op), jnp.float32),
        interpret=interpret,
    )(x, keys)
    return out[:B, :O]
