"""Public jit'd entry points for the ternary kernels.

``ternary_linear_*`` apply the BitNet scale handling around the raw kernels
(the kernels work on unscaled trits; the absmean weight scale and optional
INT8 activation scale are rank-1 corrections applied outside the hot loop).

``impl`` selection:
  * ``"lut"``      — two-phase LUT kernel (paper's architecture),
  * ``"signflip"`` — binary-plane MXU baseline (Fig. 1 middle),
  * ``"dequant"``  — packed 1.6-bit streaming dequant (deployment path),
all validated against ``ref.py`` in ``tests/test_kernels.py``.

On this CPU container kernels run with ``interpret=True``; on real TPU pass
``interpret=False`` (the launch geometry comes from the generator's
KernelPlan).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import encoding
from repro.kernels.dequant_matmul import packed_matmul
from repro.kernels.lut_matmul import lut_matmul
from repro.kernels.signflip_matmul import signflip_matmul


def _flatten_batch(x):
    lead = x.shape[:-1]
    return x.reshape(-1, x.shape[-1]), lead


def ternary_linear_lut(x, keys, scale, mu: int, *, interpret: bool = True,
                       fetch: str = "onehot", block_o: int = 128,
                       block_g: int = 128):
    """y = (x @ decode(keys).T) * scale via the LUT kernel.  x: [..., N]."""
    x2, lead = _flatten_batch(x)
    y = lut_matmul(x2.astype(jnp.float32), keys, mu, fetch=fetch,
                   block_o=block_o, block_g=block_g, interpret=interpret)
    y = y * jnp.asarray(scale, jnp.float32)
    return y.reshape(*lead, -1).astype(x.dtype)


def ternary_linear_signflip(x, w_t, scale, *, interpret: bool = True):
    x2, lead = _flatten_batch(x)
    y = signflip_matmul(x2.astype(jnp.float32), w_t, interpret=interpret)
    y = y * jnp.asarray(scale, jnp.float32)
    return y.reshape(*lead, -1).astype(x.dtype)


def ternary_linear_packed(x, packed, scale, n: int, *, interpret: bool = True):
    x2, lead = _flatten_batch(x)
    y = packed_matmul(x2.astype(jnp.float32), packed, n, interpret=interpret)
    y = y * jnp.asarray(scale, jnp.float32)
    return y.reshape(*lead, -1).astype(x.dtype)


@functools.partial(jax.jit, static_argnames=("mu",))
def encode_for_lut(w: jax.Array, mu: int):
    """Offline step: master weights → (keys, scale) for the LUT kernel."""
    from repro.core.quantization import ternarize

    w_t, scale = ternarize(w)
    keys = encoding.encode_weight_matrix(w_t, mu)
    return keys, scale


@jax.jit
def encode_packed(w: jax.Array):
    """Offline step: master weights → (packed, scale) deployment artifact."""
    from repro.core.quantization import ternarize

    w_t, scale = ternarize(w)
    return encoding.pack_base3(w_t), scale
