"""Pure-jnp oracles for every Pallas kernel in this package.

Each kernel's test sweeps shapes/dtypes and asserts allclose against these.
They are deliberately written as straight-line jnp with no tiling so they are
"obviously correct"; the LUT oracle additionally round-trips through
``repro.core.lut_algorithm`` which is itself proven equal to a plain matmul.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import encoding
from repro.core import lut_algorithm as la


def lut_matmul_ref(x: jax.Array, keys: jax.Array, mu: int) -> jax.Array:
    """y[..., o] = Σ_n x[..., n] · decode(keys)[o, n] via the two-phase LUT
    algorithm (which equals the plain matmul exactly)."""
    return la.lut_matmul_keys(x, keys, mu)


def signflip_matmul_ref(x: jax.Array, w_t: jax.Array) -> jax.Array:
    """Sign-flip baseline: conditional add, no multiplier.

    w_t: [O, N] in {-1, 0, +1}.  Written as the mux-select it models.
    """
    xe = x[..., None, :]  # [..., 1, N]
    sel = jnp.where(w_t > 0, xe, jnp.where(w_t < 0, -xe, jnp.zeros_like(xe)))
    return jnp.sum(sel, axis=-1)


def packed_matmul_ref(x: jax.Array, packed: jax.Array, n: int) -> jax.Array:
    """Dequant path: unpack base-3 bytes → ternary → full-width matmul."""
    w = encoding.unpack_base3(packed, n).astype(x.dtype)  # [O, N]
    return x @ w.T
