"""Pallas TPU kernel for the sign-flip ternary matmul baseline (Fig. 1 middle).

The ASIC baseline replaces each multiplier with a 3:1 mux selecting
``{+x, -x, 0}``.  The TPU-native equivalent decomposes the ternary matrix into
its two binary indicator planes and rides the MXU:

    y = x @ [w == +1]ᵀ  -  x @ [w == -1]ᵀ

i.e. two binary-mask matmuls — every "multiplication" is a conditional add,
exactly the baseline's arithmetic, but systolic.  The indicator construction
happens in VMEM on the VPU; weights stream as int8 trits.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _signflip_kernel(x_ref, w_ref, out_ref):
    """x_ref [bb, bn] float; w_ref [bo, bn] int8 trits; out_ref [bb, bo] f32."""
    k = pl.program_id(2)
    x = x_ref[...]
    w = w_ref[...]
    pos = (w == 1).astype(x.dtype)
    neg = (w == -1).astype(x.dtype)
    partial = jax.lax.dot_general(
        x, pos, dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    ) - jax.lax.dot_general(
        x, neg, dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += partial


@functools.partial(
    jax.jit, static_argnames=("block_b", "block_o", "block_n", "interpret")
)
def signflip_matmul(
    x: jax.Array,
    w_t: jax.Array,
    *,
    block_b: int = 8,
    block_o: int = 128,
    block_n: int = 512,
    interpret: bool = True,
) -> jax.Array:
    """y[b, o] = Σ_n x[b, n]·w_t[o, n] with w_t ∈ {-1,0,1} (int8), no multiplies."""
    B, N = x.shape
    O, N2 = w_t.shape
    assert N == N2, (N, N2)
    block_b = min(block_b, B)
    block_o = min(block_o, O)
    block_n = min(block_n, N)
    pad_b = (-B) % block_b
    pad_o = (-O) % block_o
    pad_n = (-N) % block_n
    if pad_b or pad_n:
        x = jnp.pad(x, ((0, pad_b), (0, pad_n)))
    if pad_o or pad_n:
        w_t = jnp.pad(w_t, ((0, pad_o), (0, pad_n)))
    Bp, Op, Np = B + pad_b, O + pad_o, N + pad_n

    out = pl.pallas_call(
        _signflip_kernel,
        grid=(Bp // block_b, Op // block_o, Np // block_n),
        in_specs=[
            pl.BlockSpec((block_b, block_n), lambda i, j, k: (i, k)),
            pl.BlockSpec((block_o, block_n), lambda i, j, k: (j, k)),
        ],
        out_specs=pl.BlockSpec((block_b, block_o), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Bp, Op), jnp.float32),
        interpret=interpret,
    )(x, w_t)
    return out[:B, :O]
