"""TL2-style two-trit LUT matmul: 9-entry tables, base-9 packed weights.

The bitnet.cpp TL2 typology (and T-MAC's LUT-centric mpGEMM) groups ternary
weights in *pairs*: a pair of trits has 9 states, so a per-pair activation
table ``T[g] = [x0·t0 + x1·t1 for (t0, t1) in {-1,0,1}²]`` has only 9 entries
and the fetch is a 9-way select — much smaller build cost than the base-3
mu-group encoding's ``(3^mu-1)/2`` entries, at the same storage density:

  * pair → base-9 digit ``d = (t0+1)·3 + (t1+1) ∈ [0, 9)``;
  * 5 digits pack into one uint16 (``9^5 = 59049 ≤ 65536``) → 16 bits per
    10 trits = **1.6 bits/weight exactly**, matching base-3's 5-trits/byte.

Two variants share the packing:

  * :func:`tl2_matmul_ref` — pure-XLA: pair-table build as one dense
    contraction against the [9, 2] combo matrix, one-hot fetch contraction
    (gather-free, MXU/XLA friendly).
  * :func:`tl2_matmul` — Pallas grid kernel mirroring ``lut_matmul``'s
    structure: in-kernel uint16 → digit decode (5 div-mod-9 VPU steps), in-
    kernel iota-synthesized combo matrix, one-hot fetch on the MXU, output-
    stationary VMEM accumulator over the reduction grid dim.

All math runs in f32; int8 activations cast losslessly, and because every
intermediate is integral (|pair sum| ≤ 254, products < 2^24 at practical K)
the int8 path is bit-exact against the dense trit reference.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.core.encoding import TRITS_PER_BYTE, unpack_base3

#: base-9 digits per packed uint16 word
PAIRS_PER_WORD = 5
#: trits per packed uint16 word → 16 / 10 = 1.6 bits per weight
TRITS_PER_WORD = 2 * PAIRS_PER_WORD


def tl2_bits_per_weight() -> float:
    return 16.0 / TRITS_PER_WORD


def pack_tl2(w_t: jax.Array) -> jax.Array:
    """Pack ternary {-1,0,1} → uint16, 10 trits (5 pairs) per word.

    The last axis is zero-padded to a multiple of 10; zero trits map to the
    pair digit 4, whose table entry is identically 0, so padded columns are
    inert in every fetch path.
    """
    *lead, N = w_t.shape
    pad = (-N) % TRITS_PER_WORD
    if pad:
        w_t = jnp.pad(w_t, [(0, 0)] * len(lead) + [(0, pad)])
    pairs = w_t.reshape(*lead, -1, 2).astype(jnp.int32) + 1
    digits = pairs[..., 0] * 3 + pairs[..., 1]          # [..., G] ∈ [0, 9)
    grp = digits.reshape(*lead, -1, PAIRS_PER_WORD)
    powers = jnp.asarray([9**i for i in range(PAIRS_PER_WORD)], jnp.int32)
    return jnp.sum(grp * powers, axis=-1).astype(jnp.uint16)


def repack_base3_to_tl2(packed: jax.Array, n: int) -> jax.Array:
    """Base-3 packed bytes ``[..., ceil(n/5)]`` → TL2 words
    ``[..., ceil(n/10)]`` — the serving-artifact repack (deployment checkpoints
    store base-3; the TL2 kernels re-encode once at load/first-use)."""
    return pack_tl2(unpack_base3(packed, n))


def unpack_tl2_digits(words: jax.Array) -> jax.Array:
    """uint16 [..., W] → base-9 pair digits int32 [..., W*5]."""
    v = words.astype(jnp.int32)
    digs = []
    for _ in range(PAIRS_PER_WORD):
        digs.append(v % 9)
        v = v // 9
    return jnp.stack(digs, axis=-1).reshape(*words.shape[:-1], -1)


def unpack_tl2(words: jax.Array, n: int, dtype=jnp.int8) -> jax.Array:
    """uint16 [..., ceil(n/10)] → trits [..., n] in ``dtype``."""
    d = unpack_tl2_digits(words)
    trits = jnp.stack([d // 3 - 1, d % 3 - 1], axis=-1)
    return trits.reshape(*words.shape[:-1], -1)[..., :n].astype(dtype)


@functools.lru_cache(maxsize=None)
def _combo9_np() -> np.ndarray:
    """[9, 2] int8: row d = the trit pair encoded by base-9 digit d."""
    d = np.arange(9, dtype=np.int64)
    return np.stack([d // 3 - 1, d % 3 - 1], axis=1).astype(np.int8)


def _pair_tables(x: jax.Array) -> jax.Array:
    """[B, G*2] f32 activations → [B, G, 9] per-pair tables (build phase)."""
    B = x.shape[0]
    xg = x.reshape(B, -1, 2)
    C9 = jnp.asarray(_combo9_np(), x.dtype)
    return jax.lax.dot_general(
        xg, C9, dimension_numbers=(((2,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)  # [B, G, 9]


@functools.partial(jax.jit, static_argnames=("n",))
def tl2_matmul_ref(x: jax.Array, words: jax.Array, n: int) -> jax.Array:
    """Pure-XLA TL2 matmul: ``y[b, o] = Σ_k x[b, k] · trits(words)[o, k]``.

    x:     [B, N'] f32/bf16/int8 activations with N' ≥ n padded to the full
           unpacked width ``words.shape[1] * 10`` (callers zero-pad).
    words: [O, W] uint16 TL2-packed weights.
    """
    B = x.shape[0]
    O, W = words.shape
    full = W * TRITS_PER_WORD
    if x.shape[1] < full:
        x = jnp.pad(x, ((0, 0), (0, full - x.shape[1])))
    tables = _pair_tables(x.astype(jnp.float32))        # [B, G, 9]
    digits = unpack_tl2_digits(words)                   # [O, G]
    iota = jax.lax.broadcasted_iota(jnp.int32, (*digits.shape, 9), 2)
    oh = (iota == digits[..., None]).astype(jnp.float32)  # [O, G, 9]
    return jax.lax.dot_general(
        tables, oh, dimension_numbers=(((1, 2), (1, 2)), ((), ())),
        preferred_element_type=jnp.float32)             # [B, O]


def _tl2_kernel(x_ref, w_ref, out_ref):
    """One (bb, bo) output tile, one bw-word reduction step.

    x_ref:  [bb, bw*10] f32 activation slice
    w_ref:  [bo, bw]    uint16 TL2 words
    out_ref:[bb, bo]    f32 accumulator
    """
    k = pl.program_id(2)
    bb = x_ref.shape[0]

    # ---- Build phase: per-pair 9-entry tables on the MXU.  The [9, 2]
    # combo matrix is synthesized from iota arithmetic (Pallas kernels
    # cannot capture array constants): row d = (d//3 - 1, d%3 - 1).
    di = jax.lax.broadcasted_iota(jnp.int32, (9, 2), 0)
    pj = jax.lax.broadcasted_iota(jnp.int32, (9, 2), 1)
    C9 = jnp.where(pj == 0, di // 3 - 1, di % 3 - 1)
    xg = x_ref[...].reshape(bb, -1, 2)
    tables = jax.lax.dot_general(
        xg, C9.astype(xg.dtype),
        dimension_numbers=(((2,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)             # [bb, bg, 9]

    # ---- Fetch phase: decode words → digits (5 div-mod-9 VPU steps), then
    # a one-hot contraction pulls entry d[o, g] from tables[b, g, :].
    v = w_ref[...].astype(jnp.int32)                    # [bo, bw]
    digs = []
    for _ in range(PAIRS_PER_WORD):
        digs.append(v % 9)
        v = v // 9
    digits = jnp.stack(digs, axis=-1).reshape(w_ref.shape[0], -1)  # [bo, bg]
    iota = jax.lax.broadcasted_iota(jnp.int32, (*digits.shape, 9), 2)
    oh = jnp.where(iota == digits[..., None], 1.0, 0.0)
    partial = jax.lax.dot_general(
        tables, oh, dimension_numbers=(((1, 2), (1, 2)), ((), ())),
        preferred_element_type=jnp.float32)             # [bb, bo]

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += partial


@functools.partial(
    jax.jit, static_argnames=("n", "block_b", "block_o", "block_w", "interpret")
)
def tl2_matmul(
    x: jax.Array,
    words: jax.Array,
    n: int,
    *,
    block_b: int = 8,
    block_o: int = 128,
    block_w: int = 64,
    interpret: bool = True,
) -> jax.Array:
    """Pallas TL2 matmul: ``y[b, o] = Σ_k x[b, k] · trits(words)[o, k]``.

    Args:
      x:     [B, N'] activations (f32/bf16/int8); padded internally to the
             full unpacked width ``words.shape[1] * 10``.
      words: [O, W] uint16 TL2-packed ternary weights (:func:`pack_tl2`).
      n:     logical K (columns beyond n are zero by construction).
      block_*: VMEM tile sizes; ``block_w`` counts packed words (×10 x cols).
      interpret: interpret mode (CPU container); False targets real TPU.

    Returns [B, O] float32.
    """
    B = x.shape[0]
    O, W = words.shape
    full = W * TRITS_PER_WORD
    if x.shape[1] < full:
        x = jnp.pad(x, ((0, 0), (0, full - x.shape[1])))
    x = x.astype(jnp.float32)

    block_b = min(block_b, B)
    block_o = min(block_o, O)
    block_w = min(block_w, W)
    pad_b = (-B) % block_b
    pad_o = (-O) % block_o
    pad_w = (-W) % block_w
    if pad_b or pad_w:
        x = jnp.pad(x, ((0, pad_b), (0, pad_w * TRITS_PER_WORD)))
    if pad_o or pad_w:
        # pad word 0 decodes to digit-0 pairs = (-1, -1) trits, but the
        # matching x columns are zero-padded so the products vanish; padded
        # output rows are sliced off below.
        words = jnp.pad(words, ((0, pad_o), (0, pad_w)))
    Bp, Op, Wp = B + pad_b, O + pad_o, W + pad_w

    out = pl.pallas_call(
        _tl2_kernel,
        grid=(Bp // block_b, Op // block_o, Wp // block_w),
        in_specs=[
            pl.BlockSpec((block_b, block_w * TRITS_PER_WORD),
                         lambda i, j, k: (i, k)),
            pl.BlockSpec((block_o, block_w), lambda i, j, k: (j, k)),
        ],
        out_specs=pl.BlockSpec((block_b, block_o), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Bp, Op), jnp.float32),
        interpret=interpret,
    )(x, words)
    return out[:B, :O]
