"""Pallas TPU kernel: W1.58A8 matmul — the paper's Table-I operating point.

BitNet b1.58 runs ternary weights against **INT8 activations**; the
accelerator's INT8 column in the cost model is exactly this datapath.  On
TPU the analogue is an int8×int8→int32 MXU contraction:

  * activations arrive as int8 with a per-row (per-token) fp scale,
  * weights stream as base-3 packed uint8 (1.6 b/w) and are expanded to int8
    trits in VMEM,
  * accumulation is exact int32 (the ASIC's wide accumulators); the two
    scales are applied as a rank-1 correction on the way out.

Against the bf16 dequant path this halves activation bytes and keeps the
MXU in its highest-throughput int8 mode — the TPU-native version of the
paper's "INT8 activations make the arithmetic cheap" observation.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.encoding import TRITS_PER_BYTE
from repro.kernels.dequant_matmul import _unpack_block


def _w2a8_kernel(x_ref, p_ref, out_ref):
    """x_ref [bb, bn] int8; p_ref [bo, bn//5] uint8; out [bb, bo] int32."""
    k = pl.program_id(2)
    x = x_ref[...]
    w = _unpack_block(p_ref[...], jnp.int8)  # [bo, bn] trits
    partial = jax.lax.dot_general(
        x, w, dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.int32,
    )

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += partial


@functools.partial(
    jax.jit, static_argnames=("n", "block_b", "block_o", "block_n", "interpret")
)
def w2a8_matmul(
    x_q: jax.Array,
    packed: jax.Array,
    n: int,
    *,
    block_b: int = 8,
    block_o: int = 128,
    block_n: int = 640,
    interpret: bool = True,
) -> jax.Array:
    """Exact int32 y[b,o] = Σ_n x_q[b,n] · trits(packed)[o,n].

    x_q: [B, N] int8 (per-token quantized activations).
    packed: [O, ceil(N/5)] base-3 ternary weights.
    """
    B, N = x_q.shape
    O, NB = packed.shape
    full = NB * TRITS_PER_BYTE
    if N < full:
        x_q = jnp.pad(x_q, ((0, 0), (0, full - N)))
    N = full
    block_n = min(block_n, N)
    block_n -= block_n % TRITS_PER_BYTE
    block_b = min(block_b, B)
    block_o = min(block_o, O)
    pad_b, pad_o, pad_n = (-B) % block_b, (-O) % block_o, (-N) % block_n
    if pad_b or pad_n:
        x_q = jnp.pad(x_q, ((0, pad_b), (0, pad_n)))
    if pad_o or pad_n:
        packed = jnp.pad(packed, ((0, pad_o), (0, pad_n // TRITS_PER_BYTE)))
    Bp, Op, Np = B + pad_b, O + pad_o, N + pad_n

    out = pl.pallas_call(
        _w2a8_kernel,
        grid=(Bp // block_b, Op // block_o, Np // block_n),
        in_specs=[
            pl.BlockSpec((block_b, block_n), lambda i, j, k: (i, k)),
            pl.BlockSpec((block_o, block_n // TRITS_PER_BYTE), lambda i, j, k: (j, k)),
        ],
        out_specs=pl.BlockSpec((block_b, block_o), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Bp, Op), jnp.int32),
        interpret=interpret,
    )(x_q, packed)
    return out[:B, :O]


def w2a8_linear(x: jax.Array, packed: jax.Array, w_scale: jax.Array, n: int,
                *, interpret: bool = True) -> jax.Array:
    """Full W1.58A8 linear: quantize acts → int kernel → rank-1 rescale."""
    from repro.core.quantization import quantize_activations_int8

    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    x_q, x_scale = quantize_activations_int8(x2)
    y = w2a8_matmul(x_q, packed, n, interpret=interpret)
    y = y.astype(jnp.float32) * x_scale * jnp.asarray(w_scale, jnp.float32)
    return y.reshape(*lead, -1).astype(x.dtype)
