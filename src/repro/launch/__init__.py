"""repro.launch subsystem."""
