import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""Packing ablation: the paper's bandwidth claim at the framework level.

Lowers the same serve_step twice — once with packed 1.6-bit ternary weights
(deployment artifact) and once with bf16 weights — and compares the roofline
memory term and weight bytes/device.  The bitnet-2b × decode_4k cell is the
paper's own operating point (short context: weights, not KV, dominate).

Usage: python -m repro.launch.ablate [--arch bitnet-b1.58-2b] [--seq 4096]
"""

import argparse
import functools

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.registry import get_config
from repro.configs.shapes import Shape
from repro.launch import roofline as rl
from repro.launch.mesh import make_production_mesh
from repro.models.decode import decode_step, init_cache, quantize_for_serving
from repro.models.model import init_params
from repro.parallel import sharding as sh


def lower_decode(cfg, shape, params_sds, mesh):
    pspecs = sh.param_specs(params_sds, mesh)
    psh = sh.to_shardings(pspecs, mesh)
    cache_sds = jax.eval_shape(
        lambda: init_cache(cfg, shape.global_batch, shape.seq_len))
    csh = sh.to_shardings(sh.cache_specs(cache_sds, mesh), mesh)
    tok_sds = jax.ShapeDtypeStruct((shape.global_batch,), jnp.int32)
    tok_sh = sh.to_shardings(sh.batch_specs(tok_sds, mesh), mesh)
    fn = jax.jit(lambda p, c, t, i: decode_step(p, cfg, c, t, i),
                 in_shardings=(psh, csh, tok_sh, NamedSharding(mesh, P())),
                 out_shardings=(None, csh), donate_argnums=(1,))
    with mesh:
        compiled = fn.lower(params_sds, cache_sds, tok_sds,
                            jax.ShapeDtypeStruct((), jnp.int32)).compile()
    roof, _ = rl.from_compiled(compiled, mesh.devices.size)
    import math
    wbytes = sum(
        math.prod(x.shape) * x.dtype.itemsize
        for x in jax.tree.leaves(params_sds)) / 1e9
    return roof, wbytes


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="bitnet-b1.58-2b")
    ap.add_argument("--seq", type=int, default=4096)
    ap.add_argument("--batch", type=int, default=128)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    shape = Shape("ablate", args.seq, args.batch, "decode")
    mesh = make_production_mesh(multi_pod=False)
    key = jax.random.PRNGKey(0)
    params_sds = jax.eval_shape(functools.partial(init_params, cfg), key)
    packed_sds = jax.eval_shape(
        functools.partial(quantize_for_serving, cfg=cfg), params_sds)

    r_bf16, w_bf16 = lower_decode(cfg, shape, params_sds, mesh)
    r_pack, w_pack = lower_decode(cfg, shape, packed_sds, mesh)
    print(f"{args.arch} × decode seq={args.seq} batch={args.batch} (256 chips)")
    print(f"  weights global: bf16 {w_bf16:.2f} GB vs packed {w_pack:.2f} GB "
          f"({w_bf16 / w_pack:.1f}x)")
    print(f"  memory term: bf16 {r_bf16.memory_s*1e3:.1f} ms vs packed "
          f"{r_pack.memory_s*1e3:.1f} ms ({r_bf16.memory_s/r_pack.memory_s:.2f}x)")
    print(f"  bytes/device: bf16 {r_bf16.bytes_per_device/1e9:.2f} GB vs packed "
          f"{r_pack.bytes_per_device/1e9:.2f} GB")


if __name__ == "__main__":
    main()
