import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

This is the proof that the distribution config is coherent without real
hardware: 512 placeholder host devices stand in for 2 TPU v5e pods.  For each
cell we build ShapeDtypeStruct inputs (no allocation), jit with explicit
in/out shardings, ``.lower().compile()``, and record

  * ``memory_analysis``  (per-device footprint — proves it fits),
  * ``cost_analysis``    (FLOPs / bytes for §Roofline),
  * collective bytes parsed from the optimized HLO,

into ``experiments/dryrun/<arch>__<shape>__<mesh>.json`` (incremental: cells
already recorded are skipped, so an interrupted sweep resumes).

Usage:
  python -m repro.launch.dryrun                      # all cells, both meshes
  python -m repro.launch.dryrun --arch qwen3-0.6b --shape train_4k --mesh single
"""

import argparse
import functools
import json
import math
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.registry import all_cells, input_specs
from repro.configs.shapes import SHAPES
from repro.launch import roofline as rl
from repro.launch.mesh import make_production_mesh
from repro.models.decode import decode_step, init_cache, prefill, quantize_for_serving
from repro.models.model import init_params, train_loss
from repro.optim.optimizers import clip_by_global_norm, make_optimizer
from repro.parallel import sharding as sh

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")


def _sharded_bytes(sds_tree, spec_tree, mesh) -> float:
    """Analytical per-device bytes of a sharded pytree (for reporting)."""
    total = 0.0
    for sds, spec in zip(jax.tree.leaves(sds_tree),
                         jax.tree.leaves(spec_tree,
                                         is_leaf=lambda s: isinstance(s, P))):
        shards = 1
        for axes in spec:
            if axes is None:
                continue
            for a in (axes if isinstance(axes, tuple) else (axes,)):
                shards *= mesh.shape[a]
        total += math.prod(sds.shape) * sds.dtype.itemsize / shards
    return total


def build_train_step(cfg, opt):
    def train_step(params, opt_state, batch, step):
        (loss, metrics), grads = jax.value_and_grad(
            train_loss, has_aux=True)(params, cfg, batch)
        grads, gnorm = clip_by_global_norm(grads, 1.0)
        params, opt_state = opt.update(opt_state, grads, params, step)
        return params, opt_state, {"loss": loss, "gnorm": gnorm, **metrics}
    return train_step


def lower_cell(arch: str, shape_name: str, multi_pod: bool, verbose=True):
    cfg, shape, specs = input_specs(arch, shape_name)
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    rec = {"arch": arch, "shape": shape_name,
           "mesh": "2x16x16" if multi_pod else "16x16", "chips": chips,
           "kind": shape.kind, "ok": False}
    key = jax.random.PRNGKey(0)
    t0 = time.time()

    params_sds = jax.eval_shape(functools.partial(init_params, cfg), key)
    pspecs = sh.param_specs(params_sds, mesh)
    psh = sh.to_shardings(pspecs, mesh)

    if shape.kind == "train":
        opt = make_optimizer(cfg.optimizer)
        state_sds = jax.eval_shape(opt.init, params_sds)
        sspecs = opt.state_specs(pspecs, params_sds)
        ssh = sh.to_shardings(sspecs, mesh)
        bspecs = sh.batch_specs(specs, mesh)
        bsh = sh.to_shardings(bspecs, mesh)
        fn = jax.jit(build_train_step(cfg, opt),
                     in_shardings=(psh, ssh, bsh, NamedSharding(mesh, P())),
                     out_shardings=(psh, ssh, None),
                     donate_argnums=(0, 1))
        with mesh:
            lowered = fn.lower(params_sds, state_sds, specs,
                               jax.ShapeDtypeStruct((), jnp.int32))
        rec["state_bytes_per_device"] = _sharded_bytes(state_sds, sspecs, mesh)
    else:
        packed_sds = jax.eval_shape(
            functools.partial(quantize_for_serving, cfg=cfg), params_sds)
        packed_specs = sh.param_specs(packed_sds, mesh)
        packed_sh = sh.to_shardings(packed_specs, mesh)
        rec["packed_bytes_per_device"] = _sharded_bytes(packed_sds, packed_specs, mesh)
        if shape.kind == "prefill":
            bspecs = sh.batch_specs(specs, mesh)
            bsh = sh.to_shardings(bspecs, mesh)

            def prefill_step(params, batch):
                return prefill(params, cfg, batch, s_max=shape.seq_len)

            cache_sds = jax.eval_shape(
                lambda: init_cache(cfg, shape.global_batch, shape.seq_len))
            csh = sh.to_shardings(sh.cache_specs(cache_sds, mesh), mesh)
            fn = jax.jit(prefill_step, in_shardings=(packed_sh, bsh),
                         out_shardings=((csh, None)))
            with mesh:
                lowered = fn.lower(packed_sds, specs)
        else:  # decode
            cache_sds = specs["cache"]
            cspecs = sh.cache_specs(cache_sds, mesh)
            csh = sh.to_shardings(cspecs, mesh)
            tok_sds = jax.ShapeDtypeStruct((shape.global_batch,), jnp.int32)
            tok_sh = sh.to_shardings(sh.batch_specs(tok_sds, mesh), mesh)
            rec["cache_bytes_per_device"] = _sharded_bytes(cache_sds, cspecs, mesh)

            def serve_step(params, cache, tokens, index):
                return decode_step(params, cfg, cache, tokens, index)

            fn = jax.jit(serve_step,
                         in_shardings=(packed_sh, csh, tok_sh,
                                       NamedSharding(mesh, P())),
                         out_shardings=(None, csh),
                         donate_argnums=(1,))
            with mesh:
                lowered = fn.lower(packed_sds, cache_sds, tok_sds,
                                   jax.ShapeDtypeStruct((), jnp.int32))

    rec["param_bytes_per_device"] = _sharded_bytes(params_sds, pspecs, mesh)
    rec["lower_s"] = time.time() - t0
    t1 = time.time()
    compiled = lowered.compile()
    rec["compile_s"] = time.time() - t1

    try:
        ma = compiled.memory_analysis()
        if ma is not None:
            rec["memory_analysis"] = {
                k: int(getattr(ma, k)) for k in
                ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "generated_code_size_in_bytes")
                if hasattr(ma, k)}
    except Exception as e:  # pragma: no cover
        rec["memory_analysis_error"] = str(e)

    roof, coll = rl.from_compiled(compiled, chips)
    rec["roofline"] = roof.as_dict()
    rec["collectives"] = coll
    rec["model_flops"] = rl.model_flops(cfg, shape, shape.kind)
    hlo_flops_global = roof.flops_per_device * chips
    rec["model_flops_ratio"] = rec["model_flops"] / max(hlo_flops_global, 1.0)
    rec["ok"] = True
    if verbose:
        print(f"  {arch} × {shape_name} × {rec['mesh']}: "
              f"compute={roof.compute_s*1e3:.2f}ms memory={roof.memory_s*1e3:.2f}ms "
              f"collective={roof.collective_s*1e3:.2f}ms → {roof.bottleneck} "
              f"(lower {rec['lower_s']:.0f}s compile {rec['compile_s']:.0f}s)")
    return rec


def cell_path(arch, shape_name, mesh_name, out_dir=OUT_DIR):
    return os.path.join(out_dir, f"{arch}__{shape_name}__{mesh_name}.json")


def run(arch=None, shape=None, meshes=("16x16", "2x16x16"), out_dir=OUT_DIR,
        force=False):
    os.makedirs(out_dir, exist_ok=True)
    cells = all_cells()
    if arch:
        cells = [c for c in cells if c[0] == arch]
    if shape:
        cells = [c for c in cells if c[1] == shape]
    failures = []
    for a, s in cells:
        for mesh_name in meshes:
            path = cell_path(a, s, mesh_name, out_dir)
            if os.path.exists(path) and not force:
                with open(path) as f:
                    if json.load(f).get("ok"):
                        continue
            print(f"[dryrun] {a} × {s} × {mesh_name}")
            try:
                rec = lower_cell(a, s, multi_pod=(mesh_name == "2x16x16"))
            except Exception as e:
                rec = {"arch": a, "shape": s, "mesh": mesh_name, "ok": False,
                       "error": f"{type(e).__name__}: {e}",
                       "traceback": traceback.format_exc()[-4000:]}
                failures.append((a, s, mesh_name, str(e)[:200]))
                print(f"  FAILED: {rec['error'][:300]}")
            with open(path, "w") as f:
                json.dump(rec, f, indent=1)
    print(f"\n{len(failures)} failures")
    for f_ in failures:
        print(" ", f_)
    return failures


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="both")
    ap.add_argument("--out-dir", default=OUT_DIR)
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    meshes = {"single": ("16x16",), "multi": ("2x16x16",),
              "both": ("16x16", "2x16x16")}[args.mesh]
    run(args.arch, args.shape, meshes, args.out_dir, args.force)


if __name__ == "__main__":
    main()
