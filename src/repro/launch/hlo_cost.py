"""Trip-count-aware cost analysis over optimized HLO text.

``compiled.cost_analysis()`` counts every computation **once** — a
``lax.scan`` over 60 layers contributes a single layer's FLOPs (verified in
tests/test_hlo_cost.py).  Since every model here scans over layers (and over
loss/SSD chunks), raw numbers can be ~10-100× off.  This module re-derives
costs from ``compiled.as_text()`` with loop multiplicities:

  1. segment the module into named computations;
  2. per computation, accumulate
       * dot/convolution FLOPs (2 × prod(result) × prod(contracted dims)),
       * collective bytes by kind (result-shape proxy; reduce-scatter scaled
         by replica-group size),
       * materialized bytes (Σ result-shape bytes of top-level ops — a
         first-order HBM-traffic proxy: post-fusion, each tensor is written
         once and read ~once),
       * call edges (fusion `calls=`, `call`, `while` body/condition,
         `conditional` branches);
  3. recover each while loop's trip count from the canonical counted-loop
     form (`compare(iv, constant(N)), direction=LT` in the condition);
  4. propagate multipliers from ENTRY through the call graph and aggregate.

All numbers remain *derived from the compiled dry-run artifact*; only the
loop multiplicity bookkeeping is ours.
"""

from __future__ import annotations

import math
import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s2": 1, "u2": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "s16": 2, "u16": 2, "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e3m4": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
}

COLLECTIVE_KINDS = ("all-gather", "all-reduce", "reduce-scatter",
                    "all-to-all", "collective-permute")

_COMP_HDR = re.compile(r"^(ENTRY\s+)?%([\w\.\-]+)\s*\(.*\)\s*->\s*.*\{\s*$")
_SHAPE = re.compile(r"([a-z]\d*[a-z]*\d*[a-z]*)\[([0-9,]*)\]")
_RESULT = re.compile(r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(.*)$")
_OPNAME = re.compile(r"\s*([a-z][a-z0-9\-]*)\(")
_DOT_ARGS = re.compile(r"dot\(([^)]*)\)")
_ARG_NAME = re.compile(r"%([\w\.\-]+)")
_ARG_INLINE_SHAPE = re.compile(r"([a-z]\d*[a-z]*\d*)\[([0-9,]*)\][^\s]*\s+%([\w\.\-]+)")
_CONTRACT = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_CALLS = re.compile(r"calls=%?([\w\.\-]+)")
_WHILE = re.compile(r"while\(.*?\)\s*,\s*condition=%?([\w\.\-]+)\s*,\s*body=%?([\w\.\-]+)")
_TO_APPLY = re.compile(r"to_apply=%?([\w\.\-]+)")
_BRANCHES = re.compile(r"branch_computations=\{([^}]*)\}")
_CONST_S32 = re.compile(r"s32\[\]\s+constant\((\d+)\)")
_RG_IOTA = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_RG_LIST = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


@dataclass
class Computation:
    name: str
    flops: float = 0.0
    bytes_materialized: float = 0.0
    collectives: dict = field(default_factory=lambda: {k: 0.0 for k in COLLECTIVE_KINDS})
    edges: list = field(default_factory=list)  # (callee, kind)
    while_bodies: list = field(default_factory=list)  # (cond, body)
    const_s32: list = field(default_factory=list)
    is_entry: bool = False
    lines: list = field(default_factory=list)
    shapes: dict = field(default_factory=dict)  # %name -> (dtype, dims)
    # HBM-traffic model inputs (filled by _parse_line):
    op_records: list = field(default_factory=list)
    # each: (name, op, result_bytes, arg_names, callee, dus_update_bytes)
    root_op: str = ""
    root_dus_update: float = 0.0
    param_names: set = field(default_factory=set)
    param_index: dict = field(default_factory=dict)  # name -> position


def _parse_line(comp: Computation, line: str):
    for m in _CONST_S32.finditer(line):
        comp.const_s32.append(int(m.group(1)))
    r = _RESULT.match(line)
    if not r:
        return
    _, rhs = r.groups()
    # result shape(s): first shape token(s) before the op name's paren
    shapes = _SHAPE.findall(rhs.split("(")[0])
    result_bytes = sum(_shape_bytes(dt, dims) for dt, dims in shapes)
    om = _OPNAME.match(rhs) or _OPNAME.search(rhs.split("(")[0] + "(")
    # op name = last identifier before the first '(' in canonical text
    head = rhs.split("(")[0].strip()
    op = head.split()[-1] if head else ""
    if not op.replace("-", "").isalnum():
        op = om.group(1) if om else ""

    if op == "dot":
        dm = _DOT_ARGS.search(rhs)
        cdims = _CONTRACT.search(rhs)
        contract = 1
        if dm:
            args = dm.group(1)
            inline = _ARG_INLINE_SHAPE.findall(args)
            if inline:
                ldims = [int(d) for d in inline[0][1].split(",") if d]
            else:
                names = _ARG_NAME.findall(args)
                ldims = None
                if names and names[0] in comp.shapes:
                    ldims = [int(d) for d in comp.shapes[names[0]][1].split(",") if d]
            if ldims is not None and cdims and cdims.group(1):
                for i in (int(x) for x in cdims.group(1).split(",")):
                    if i < len(ldims):
                        contract *= ldims[i]
        out_elems = 1
        if shapes:
            for d in shapes[0][1].split(","):
                if d:
                    out_elems *= int(d)
        comp.flops += 2.0 * out_elems * contract
    elif op == "convolution":
        dm = re.search(r"convolution\(([^)]*)\)", rhs)
        if dm and shapes:
            names = _ARG_NAME.findall(dm.group(1))
            if len(names) >= 2 and names[1] in comp.shapes:
                kdims = [int(d) for d in comp.shapes[names[1]][1].split(",") if d]
                out_elems = math.prod(int(d) for d in shapes[0][1].split(",") if d)
                if kdims:
                    comp.flops += 2.0 * out_elems * math.prod(kdims[:-1])
    elif op in COLLECTIVE_KINDS:
        b = result_bytes
        if op == "reduce-scatter":
            g = _RG_IOTA.search(rhs)
            if g:
                b *= int(g.group(2))
            else:
                g2 = _RG_LIST.search(rhs)
                if g2:
                    b *= len(g2.group(1).split(","))
        comp.collectives[op] += b

    w = _WHILE.search(rhs)
    if w:
        comp.while_bodies.append((w.group(1), w.group(2)))
    callee = None
    for m in _CALLS.finditer(rhs):
        comp.edges.append((m.group(1), "call"))
        callee = m.group(1)
    for m in _TO_APPLY.finditer(rhs):
        comp.edges.append((m.group(1), "apply"))
    bm = _BRANCHES.search(rhs)
    if bm:
        for b in bm.group(1).replace("%", "").split(","):
            comp.edges.append((b.strip(), "branch"))

    # --- HBM traffic bookkeeping ---
    name = r.group(1)
    if op == "parameter":
        comp.param_names.add(name)
        pm = re.search(r"parameter\((\d+)\)", rhs)
        if pm:
            comp.param_index[name] = int(pm.group(1))
    args_m = re.search(rf"{re.escape(op)}\(([^)]*)\)", rhs) if op else None
    arg_names = _ARG_NAME.findall(args_m.group(1)) if args_m else []
    dus_update = None
    if op == "dynamic-update-slice" and len(arg_names) >= 2:
        upd = comp.shapes.get(arg_names[1])
        if upd:
            dus_update = _shape_bytes(*upd)
    comp.op_records.append((name, op, result_bytes, arg_names, callee, dus_update))
    if line.lstrip().startswith("ROOT") or " ROOT " in line:
        comp.root_op = op
        if dus_update is not None:
            comp.root_dus_update = dus_update


def parse_computations(text: str, keep_lines: bool = False) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    for raw in text.splitlines():
        line = raw.rstrip()
        s = line.strip()
        h = _COMP_HDR.match(s) if (s.endswith("{") and "->" in s) else None
        if h:
            cur = Computation(name=h.group(2), is_entry=bool(h.group(1)))
            comps[cur.name] = cur
            continue
        if cur is None or s == "}" or not s:
            continue
        cur.lines.append(s)
    # pass 1: result-shape map; pass 2: full parse with operand resolution
    for comp in comps.values():
        for s in comp.lines:
            r = _RESULT.match(s)
            if r:
                sh = _SHAPE.findall(r.group(2).split("(")[0])
                if sh:
                    comp.shapes[r.group(1)] = sh[0]
        for s in comp.lines:
            _parse_line(comp, s)
        if not keep_lines:
            comp.lines = []  # free
    return comps


def trip_count(cond: Computation) -> int:
    """Counted loops compare the induction var against constant N (LT)."""
    return max(cond.const_s32) if cond.const_s32 else 1


_NO_WRITE = {"parameter", "constant", "get-tuple-element", "tuple", "bitcast",
             "", "while", "conditional"}

_SLICE_OPS = {"dynamic-slice", "slice", "gather"}


def _param_read_profile(comp: Computation) -> dict:
    """position → bytes actually read per execution, for parameters whose
    every consumer is a slice (read = Σ slice results, not the whole buffer).
    Positions not present read their full size."""
    consumers: dict[str, list] = {}
    for name, op, result_bytes, args, callee, _ in comp.op_records:
        for a in args:
            if a in comp.param_names:
                consumers.setdefault(a, []).append((op, result_bytes))
    out = {}
    for pname, cons in consumers.items():
        if cons and all(op in _SLICE_OPS for op, _ in cons):
            idx = comp.param_index.get(pname)
            if idx is not None:
                out[idx] = float(sum(rb for _, rb in cons))
    return out


def computation_traffic(comp: Computation, comps: dict) -> float:
    """First-order HBM traffic of one execution of a *control-flow*
    computation (ENTRY / while body):

      writes — every top-level op's result bytes, except (a) in-place
        dynamic-update-slice (count the updated slice, not the buffer; XLA
        aliases the rest), including fusions whose root is a DUS, and
        (b) pure metadata ops;
      reads  — external operands (parameters / loop carry / constants)
        consumed by compute ops, each counted once per execution (weights
        and KV caches live here — this is where 1.6-bit packing shows up).

    Intermediate tensors are counted once (at production) — a deliberate
    write≈read merge that keeps the proxy first-order.
    """
    # externally-produced names: parameters and gte chains off them
    external = set(comp.param_names)
    for name, op, _, args, _, _ in comp.op_records:
        if op == "get-tuple-element" and args and args[0] in external:
            external.add(name)

    traffic = 0.0
    reads_counted: set = set()
    for name, op, result_bytes, args, callee, dus_update in comp.op_records:
        if op in _NO_WRITE:
            continue
        # writes
        if op == "dynamic-update-slice" and dus_update is not None:
            traffic += dus_update
        elif op == "fusion" and callee in comps and \
                comps[callee].root_op == "dynamic-update-slice":
            traffic += comps[callee].root_dus_update or 0.0
        else:
            traffic += result_bytes
        # external reads (slice-aware through fusions: a consumer that only
        # dynamic-slices a carried buffer reads the slice, not the buffer)
        slice_prof = (_param_read_profile(comps[callee])
                      if op == "fusion" and callee in comps else {})
        if op in _SLICE_OPS:
            # a bare slice of an external reads its own result size —
            # already counted as the write above; skip the full-buffer read
            args = args[:0]
        for pos, a in enumerate(args):
            if a in external and (a, op) not in reads_counted:
                reads_counted.add((a, op))
                if pos in slice_prof:
                    traffic += slice_prof[pos]
                else:
                    shp = comp.shapes.get(a)
                    if shp:
                        traffic += _shape_bytes(*shp)
    return traffic


def propagate_multipliers(comps: dict) -> dict[str, float]:
    entry = next((c for c in comps.values() if c.is_entry), None)
    if entry is None:
        raise ValueError("no ENTRY computation found")
    mult: dict[str, float] = {c: 0.0 for c in comps}
    mult[entry.name] = 1.0
    for _ in range(len(comps)):
        changed = False
        for c in comps.values():
            m = mult[c.name]
            if m == 0.0:
                continue
            for callee, kind in c.edges:
                if callee in mult and mult[callee] < m:
                    mult[callee] = m
                    changed = True
            for cond, body in c.while_bodies:
                t = trip_count(comps[cond]) if cond in comps else 1
                if body in mult and mult[body] < m * t:
                    mult[body] = m * t
                    changed = True
                if cond in mult and mult[cond] < m * (t + 1):
                    mult[cond] = m * (t + 1)
                    changed = True
        if not changed:
            break
    return mult


def control_flow_comps(comps: dict) -> set:
    """ENTRY + while bodies/conds + conditional branches: the computations
    whose op results are materialized buffers (fusion callees are interior)."""
    ctl = {c.name for c in comps.values() if c.is_entry}
    for c in comps.values():
        for cond, body in c.while_bodies:
            ctl.add(cond)
            ctl.add(body)
        for callee, kind in c.edges:
            if kind == "branch":
                ctl.add(callee)
    return ctl


def analyze(text: str) -> dict:
    comps = parse_computations(text)
    mult = propagate_multipliers(comps)
    ctl = control_flow_comps(comps)

    out = {"flops": 0.0, "bytes": 0.0,
           "collectives": {k: 0.0 for k in COLLECTIVE_KINDS},
           "n_computations": len(comps)}
    for c in comps.values():
        m = mult.get(c.name, 0.0)
        out["flops"] += m * c.flops
        if c.name in ctl:
            c.bytes_materialized = computation_traffic(c, comps)
            out["bytes"] += m * c.bytes_materialized
        for k in COLLECTIVE_KINDS:
            out["collectives"][k] += m * c.collectives[k]
    out["collective_bytes"] = sum(out["collectives"].values())
    return out
