"""Production mesh construction.

Target hardware: TPU v5e pods — 256 chips/pod (16×16), 2 pods = 512 chips for
the multi-pod dry-run.  Defined as functions (never module-level constants)
so importing this module never touches jax device state.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh_for(devices: int, model_parallel: int = 1):
    """Small meshes for tests/examples on real local devices."""
    assert devices % model_parallel == 0
    return jax.make_mesh((devices // model_parallel, model_parallel),
                         ("data", "model"))


# Hardware constants for the roofline (assignment block).
PEAK_FLOPS_BF16 = 197e12     # per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link
CHIPS_SINGLE_POD = 256
CHIPS_MULTI_POD = 512
