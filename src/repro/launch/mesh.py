"""Production mesh construction.

Target hardware: TPU v5e pods — 256 chips/pod (16×16), 2 pods = 512 chips for
the multi-pod dry-run.  Defined as functions (never module-level constants)
so importing this module never touches jax device state.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh_for(devices: int, model_parallel: int = 1):
    """Small meshes for tests/examples on real local devices."""
    assert devices % model_parallel == 0
    return jax.make_mesh((devices // model_parallel, model_parallel),
                         ("data", "model"))


def make_serving_mesh(spec: str):
    """Parse a ``--mesh`` CLI spec into a serving mesh over local devices.

    ``"DxM"`` → ``(data, model)``; ``"PxDxM"`` → ``(pod, data, model)``.
    E.g. ``--mesh 1x8`` is 8-way tensor parallelism, ``--mesh 2x4`` shards
    MoE experts 2-way on data with 4-way TP inside each expert.  The axis
    product must match the available device count (on CPU CI, forced via
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N``).
    """
    try:
        shape = tuple(int(p) for p in spec.lower().split("x"))
    except ValueError:
        raise ValueError(f"bad mesh spec {spec!r}; expected e.g. '1x8'")
    if len(shape) == 2:
        axes = ("data", "model")
    elif len(shape) == 3:
        axes = ("pod", "data", "model")
    else:
        raise ValueError(
            f"bad mesh spec {spec!r}; expected 'DxM' or 'PxDxM'")
    n = 1
    for s in shape:
        n *= s
    avail = len(jax.devices())
    if n != avail:
        raise ValueError(
            f"mesh {spec!r} needs {n} devices but {avail} are available "
            "(on CPU, set XLA_FLAGS=--xla_force_host_platform_device_count)")
    return jax.make_mesh(shape, axes)


# Hardware constants for the roofline (assignment block).
PEAK_FLOPS_BF16 = 197e12     # per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link
CHIPS_SINGLE_POD = 256
CHIPS_MULTI_POD = 512
