"""Dry-run profiler: attribute FLOPs / bytes / collectives to HLO
computations (with loop multipliers) and print the top contributors.

This is the "profile" of the §Perf hypothesis loop on a CPU-only container:
instead of a wall-clock trace we rank computations by their roofline-term
contribution and read the op mix (dots vs transposes vs collectives) off the
optimized HLO.

Usage:
  python -m repro.launch.profile --arch gemma-7b --shape decode_32k [--multi]
"""

import argparse
import os
import re

from repro.launch import hlo_cost


_OP_KINDS = ("dot", "fusion", "transpose", "copy", "dynamic-update-slice",
             "dynamic-slice", "all-gather", "all-reduce", "reduce-scatter",
             "all-to-all", "collective-permute", "scatter", "gather", "sort",
             "reduce", "broadcast", "convert", "concatenate", "reshape",
             "while", "convolution", "iota", "select", "pad", "slice", "rng")


def per_op_bytes(comp: hlo_cost.Computation) -> dict:
    """Op-kind → result bytes inside one computation (needs comp.lines)."""
    out = {}
    for s in comp.lines:
        r = hlo_cost._RESULT.match(s)
        if not r:
            continue
        rhs = r.group(2)
        head = rhs.split("(")[0].strip()
        op = head.split()[-1] if head else "?"
        shapes = hlo_cost._SHAPE.findall(rhs.split("(")[0])
        b = sum(hlo_cost._shape_bytes(dt, dims) for dt, dims in shapes)
        out[op] = out.get(op, 0) + b
    return out


def profile_text(text: str, top: int = 15) -> str:
    comps = hlo_cost.parse_computations(text, keep_lines=True)
    mult = hlo_cost.propagate_multipliers(comps)
    ctl = hlo_cost.control_flow_comps(comps)
    for c in comps.values():
        c.bytes_materialized = (
            hlo_cost.computation_traffic(c, comps) if c.name in ctl else 0.0)

    lines = []
    total_b = sum(mult[c.name] * c.bytes_materialized for c in comps.values())
    total_f = sum(mult[c.name] * c.flops for c in comps.values())
    lines.append(f"total: {total_b/1e9:.2f} GB traffic, {total_f/1e9:.1f} GFLOP (per device)")
    scored = sorted(comps.values(),
                    key=lambda c: -(mult[c.name] * c.bytes_materialized))
    lines.append(f"{'computation':<46}{'mult':>8}{'GB(traffic×mult)':>17}{'GFLOP×mult':>14}  top ops by result bytes")
    for c in scored[:top]:
        m = mult[c.name]
        if m * c.bytes_materialized < 1e6:
            continue
        ops = per_op_bytes(c)
        top_ops = sorted(ops.items(), key=lambda kv: -kv[1])[:4]
        ops_s = " ".join(f"{k}:{v*m/1e9:.1f}G" for k, v in top_ops)
        lines.append(f"{c.name[:45]:<46}{m:>8.0f}{m*c.bytes_materialized/1e9:>17.2f}"
                     f"{m*c.flops/1e9:>14.1f}  {ops_s}")
    return "\n".join(lines)


def main():
    # 512 placeholder host devices for the production-mesh lowering; set here
    # (not at import) so merely importing this module — nothing above main()
    # touches jax — never changes the device count of the embedding process
    os.environ.setdefault("XLA_FLAGS",
                          "--xla_force_host_platform_device_count=512")
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--multi", action="store_true")
    ap.add_argument("--top", type=int, default=15)
    args = ap.parse_args()

    from repro.launch.dryrun import lower_cell  # noqa: deferred heavy import
    import repro.launch.dryrun as dr
    import json

    # reuse lower_cell but capture compiled text: monkeypatch-lite
    from repro.configs.registry import input_specs  # noqa

    rec = dr.lower_cell.__wrapped__ if hasattr(dr.lower_cell, "__wrapped__") else None
    # simplest: call lower_cell's internals by re-lowering here
    import jax

    cfg, shape, specs = input_specs(args.arch, args.shape)
    text = _lower_text(args, cfg, shape, specs)
    print(profile_text(text, args.top))


def _lower_text(args, cfg, shape, specs):
    import functools
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.launch.mesh import make_production_mesh
    from repro.launch.dryrun import build_train_step
    from repro.models.decode import decode_step, init_cache, prefill, quantize_for_serving
    from repro.models.model import init_params
    from repro.optim.optimizers import make_optimizer
    from repro.parallel import sharding as sh

    mesh = make_production_mesh(multi_pod=args.multi)
    key = jax.random.PRNGKey(0)
    params_sds = jax.eval_shape(functools.partial(init_params, cfg), key)
    pspecs = sh.param_specs(params_sds, mesh)
    psh = sh.to_shardings(pspecs, mesh)
    if shape.kind == "train":
        opt = make_optimizer(cfg.optimizer)
        state_sds = jax.eval_shape(opt.init, params_sds)
        ssh = sh.to_shardings(opt.state_specs(pspecs, params_sds), mesh)
        bsh = sh.to_shardings(sh.batch_specs(specs, mesh), mesh)
        fn = jax.jit(build_train_step(cfg, opt),
                     in_shardings=(psh, ssh, bsh, NamedSharding(mesh, P())),
                     out_shardings=(psh, ssh, None), donate_argnums=(0, 1))
        with mesh:
            return fn.lower(params_sds, state_sds, specs,
                            jax.ShapeDtypeStruct((), jnp.int32)).compile().as_text()
    packed_sds = jax.eval_shape(functools.partial(quantize_for_serving, cfg=cfg),
                                params_sds)
    packed_sh = sh.to_shardings(sh.param_specs(packed_sds, mesh), mesh)
    if shape.kind == "prefill":
        bsh = sh.to_shardings(sh.batch_specs(specs, mesh), mesh)
        cache_sds = jax.eval_shape(lambda: init_cache(cfg, shape.global_batch, shape.seq_len))
        csh = sh.to_shardings(sh.cache_specs(cache_sds, mesh), mesh)
        fn = jax.jit(lambda p, b: prefill(p, cfg, b, s_max=shape.seq_len),
                     in_shardings=(packed_sh, bsh), out_shardings=(csh, None))
        with mesh:
            return fn.lower(packed_sds, specs).compile().as_text()
    cache_sds = specs["cache"]
    csh = sh.to_shardings(sh.cache_specs(cache_sds, mesh), mesh)
    tok_sds = jax.ShapeDtypeStruct((shape.global_batch,), jnp.int32)
    tok_sh = sh.to_shardings(sh.batch_specs(tok_sds, mesh), mesh)
    fn = jax.jit(lambda p, c, t, i: decode_step(p, cfg, c, t, i),
                 in_shardings=(packed_sh, csh, tok_sh, NamedSharding(mesh, P())),
                 out_shardings=(None, csh), donate_argnums=(1,))
    with mesh:
        return fn.lower(packed_sds, cache_sds, tok_sds,
                        jax.ShapeDtypeStruct((), jnp.int32)).compile().as_text()


if __name__ == "__main__":
    main()
