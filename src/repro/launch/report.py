"""Render EXPERIMENTS.md tables from the dry-run JSON records.

Usage: python -m repro.launch.report [--dir experiments/dryrun]
Prints the §Dry-run and §Roofline markdown tables.
"""

import argparse
import glob
import json
import os


def load(d):
    recs = []
    for f in sorted(glob.glob(os.path.join(d, "*.json"))):
        with open(f) as fh:
            recs.append(json.load(fh))
    return recs


def fmt_s(x):
    return f"{x*1e3:,.1f}" if x < 100 else f"{x*1e3:,.0f}"


def roofline_table(recs, mesh="16x16"):
    rows = [r for r in recs if r.get("ok") and r["mesh"] == mesh]
    rows.sort(key=lambda r: (r["arch"], r["shape"]))
    out = ["| arch | shape | compute ms | memory ms | collective ms | bottleneck | "
           "MODEL_FLOPS/HLO | step ms |",
           "|---|---|---:|---:|---:|---|---:|---:|"]
    for r in rows:
        ro = r["roofline"]
        out.append(
            f"| {r['arch']} | {r['shape']} | {fmt_s(ro['compute_s'])} | "
            f"{fmt_s(ro['memory_s'])} | {fmt_s(ro['collective_s'])} | "
            f"{ro['bottleneck']} | {r.get('model_flops_ratio', 0):.2f} | "
            f"{fmt_s(ro['step_time_s'])} |")
    return "\n".join(out)


def dryrun_table(recs):
    rows = [r for r in recs if r.get("ok")]
    rows.sort(key=lambda r: (r["arch"], r["shape"], r["mesh"]))
    out = ["| arch | shape | mesh | params GB/dev | state GB/dev | temp GB/dev | "
           "collective GB/dev | compile s |",
           "|---|---|---|---:|---:|---:|---:|---:|"]
    for r in rows:
        ma = r.get("memory_analysis", {})
        extra = r.get("state_bytes_per_device",
                      r.get("cache_bytes_per_device", 0)) / 1e9
        pb = r.get("packed_bytes_per_device", r.get("param_bytes_per_device", 0)) / 1e9
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {pb:.2f} | {extra:.2f} | "
            f"{ma.get('temp_size_in_bytes', 0)/1e9:.2f} | "
            f"{r['roofline']['collective_bytes_per_device']/1e9:.2f} | "
            f"{r.get('compile_s', 0):.0f} |")
    return "\n".join(out)


def summary(recs):
    ok = [r for r in recs if r.get("ok")]
    n_single = len([r for r in ok if r["mesh"] == "16x16"])
    n_multi = len([r for r in ok if r["mesh"] == "2x16x16"])
    bn = {}
    for r in ok:
        if r["mesh"] == "16x16":
            bn[r["roofline"]["bottleneck"]] = bn.get(r["roofline"]["bottleneck"], 0) + 1
    return (f"{len(ok)}/{len(recs)} cells compiled "
            f"({n_single} single-pod + {n_multi} multi-pod); "
            f"single-pod bottlenecks: {bn}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default=os.path.join(
        os.path.dirname(__file__), "..", "..", "..", "experiments", "dryrun"))
    args = ap.parse_args()
    recs = load(args.dir)
    print("## Summary\n")
    print(summary(recs))
    print("\n## Roofline (single-pod 16x16, 256 chips)\n")
    print(roofline_table(recs))
    print("\n## Dry-run memory/collective detail (both meshes)\n")
    print(dryrun_table(recs))


if __name__ == "__main__":
    main()
