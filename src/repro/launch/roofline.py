"""Roofline-term extraction from compiled dry-run artifacts.

Three terms per (arch × shape × mesh), in seconds:

    compute    = HLO_FLOPs   / (chips × 197e12)
    memory     = HLO_bytes   / (chips × 819e9)
    collective = Σ collective-bytes / (chips × 50e9)

FLOPs/bytes come from ``compiled.cost_analysis()``.  Collective bytes are not
in cost_analysis: we parse the *post-SPMD* optimized HLO
(``compiled.as_text()``) and sum the result-shape bytes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute.
For reduce-scatter the data moved per participant is ~result × group_size
(ring), so we scale by the replica-group size; for the others the result
shape is the standard per-device traffic proxy.

Note cost_analysis FLOPs/bytes on the CPU backend are whole-program totals
for one SPMD program instance (= per device); we report them as such and
multiply by chips for the global number.
"""

from __future__ import annotations

import re
from dataclasses import dataclass

from repro.launch import mesh as mesh_mod

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_OP_RE = re.compile(
    r"=\s*(?:\(?)([a-z0-9]+)\[([0-9,]*)\][^\s]*\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
)
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes(hlo_text: str) -> dict:
    """Sum per-device collective traffic by op kind from optimized HLO."""
    out = {k: 0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        dtype, dims, kind = m.groups()
        b = _shape_bytes(dtype, dims)
        if kind == "reduce-scatter":
            g = _GROUPS_RE.search(line)
            if g:
                b *= int(g.group(2))  # iota groups [n, size] → size
            else:
                g2 = _GROUPS_LIST_RE.search(line)
                if g2:
                    b *= len(g2.group(1).split(","))
        out[kind] += b
        counts[kind] += 1
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    out["counts"] = counts
    return out


@dataclass
class Roofline:
    chips: int
    flops_per_device: float
    bytes_per_device: float
    collective_bytes_per_device: float

    @property
    def compute_s(self) -> float:
        return self.flops_per_device / mesh_mod.PEAK_FLOPS_BF16

    @property
    def memory_s(self) -> float:
        return self.bytes_per_device / mesh_mod.HBM_BW

    @property
    def collective_s(self) -> float:
        return self.collective_bytes_per_device / mesh_mod.ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """Roofline step time = max of the three terms (perfect overlap)."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    def as_dict(self) -> dict:
        return {
            "chips": self.chips,
            "flops_per_device": self.flops_per_device,
            "bytes_per_device": self.bytes_per_device,
            "collective_bytes_per_device": self.collective_bytes_per_device,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "bottleneck": self.bottleneck,
            "step_time_s": self.step_time_s,
        }


def model_flops(cfg, shape, kind: str) -> float:
    """MODEL_FLOPS = 6·N·D (train) / 2·N·D (fwd-only), N = active params."""
    n = cfg.active_param_count()
    if kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    # decode: one token per sequence
    return 2.0 * n * shape.global_batch


def from_compiled(compiled, chips: int) -> tuple[Roofline, dict]:
    """Roofline terms from the compiled artifact.

    Primary source: :mod:`repro.launch.hlo_cost` — a trip-count-aware re-walk
    of the optimized HLO (XLA's ``cost_analysis`` counts while-loop bodies
    once; with scan-over-layers that understates FLOPs by ~n_layers, verified
    in tests/test_hlo_cost.py).  Raw ``cost_analysis`` numbers are reported
    alongside for transparency.
    """
    from repro.launch import hlo_cost

    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    text = compiled.as_text()
    h = hlo_cost.analyze(text)
    coll = dict(h["collectives"])
    coll["total"] = h["collective_bytes"]
    coll["raw_xla_flops"] = float(ca.get("flops", 0.0))
    coll["raw_xla_bytes"] = float(ca.get("bytes accessed", 0.0))
    rl = Roofline(chips=chips, flops_per_device=h["flops"],
                  bytes_per_device=h["bytes"],
                  collective_bytes_per_device=float(h["collective_bytes"]))
    return rl, coll
