"""Serving launcher: load (or initialize) a model, quantize to the packed
1.6-bit artifact, and serve batched generation through the
continuous-batching scheduler (default) or the generational baseline.

On a pod this runs one process per host against the production mesh; on this
container it exercises the identical code path on local devices.

Usage:
  python -m repro.launch.serve --arch bitnet-b1.58-2b --smoke \
      [--ckpt-dir DIR] [--batch 4] [--new-tokens 32] [--temperature 0.8] \
      [--discipline continuous|generational] [--stream] \
      [--prefill-chunk 32] [--admission-budget 1] [--mesh 1x8] \
      [--prefix-cache] [--prefix-cache-mb 64] \
      [--draft qwen3-0.6b] [--spec-k 4] [--dynamic-spec-k] \
      [--scenario chat|rag|agentic|code] [--scenario-seed 0]

``--scenario NAME`` replaces the fixed request list with a named
multi-tenant workload (see ``repro.serving.workload``) replayed open-loop
under the wall clock: requests arrive on each tenant's stochastic arrival
process, queue for real, and the launcher prints per-tenant p50/p95/p99
TTFT+TPOT plus SLO attainment.  The deterministic virtual-clock variant
(for CI-diffable numbers and saturation sweeps) lives in
``benchmarks/serving_bench.py --scenario``.

``--draft <arch>`` turns on draft-and-verify speculative decoding on the
continuous path: the (replicated, randomly-initialized here — pass a real
draft checkpoint in deployment) draft model proposes ``--spec-k - 1``
greedy continuations per scheduler step and the target verifies all
candidates in one batched forward, emitting the accepted window.  Greedy
streams are byte-identical to non-speculative serving under the canonical
(bf16-argmax) greedy selection the speculative round is defined over
(``SamplerConfig(canonical_greedy=True)`` on the non-spec engine; on dense
caches the verify forward itself is scatter-first bitwise-exact); the
draft and target must share a tokenizer/vocab (the engine raises
ValueError otherwise) and ``--temperature`` must stay 0.

``--mesh DxM`` (e.g. ``1x8``) serves sharded: packed ternary weights are
tensor-parallel on the ``model`` axis and MoE expert stacks expert-parallel
on ``data`` (rules in ``repro/parallel/sharding.py``), the KV/state cache is
sharded alongside, and kernel dispatch keys its autotune cache on the
per-shard local problems.  The axis product must match the device count —
on CPU, force devices with XLA_FLAGS=--xla_force_host_platform_device_count=8.

Admission is chunked and length-bucketed on supported architectures:
prompts are padded to a multiple of ``--prefill-chunk`` and prefilled one
fixed-shape chunk at a time (one compiled trace for any prompt-length mix);
``--admission-budget`` caps prefill chunks per scheduler step so co-batched
requests keep decoding — bounded time-to-first-token — while a long prompt
is admitted.
"""

from __future__ import annotations

import argparse
import time

import jax

from repro.checkpoint import checkpointing as ckpt
from repro.configs.registry import get_config, get_smoke_config
from repro.models.decode import packed_bits_per_weight, quantize_for_serving
from repro.models.model import init_params
from repro.serving.engine import DecodeEngine, Request, SamplerConfig
from repro.serving.scheduler import ContinuousScheduler


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--ckpt-dir", help="restore trained params (else random init)")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--requests", type=int, default=None,
                    help="number of requests (default: --batch; may exceed "
                    "it — the scheduler queues and refills slots)")
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--prefill-chunk", type=int, default=32,
                    help="admission prefill chunk size / bucket granularity "
                    "(clamped to the ring on windowed configs)")
    ap.add_argument("--admission-budget", type=int, default=0,
                    help="max prefill chunks per scheduler step (0 = "
                    "unbounded); >0 bounds co-batched time-to-first-token "
                    "while long prompts are admitted (continuous only)")
    ap.add_argument("--discipline", choices=["continuous", "generational"],
                    default="continuous")
    ap.add_argument("--stream", action="store_true",
                    help="print tokens as they are emitted (continuous only)")
    ap.add_argument("--mesh", default=None,
                    help="serve sharded over a DxM (data x model) device "
                    "mesh, e.g. 1x8 (TP) or 2x4 (EP x TP); axis product "
                    "must equal the device count")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="hashed shared-prefix KV reuse: admission splices "
                    "cached KV blocks (block = one --prefill-chunk) instead "
                    "of recomputing them, publishes fresh blocks, and the "
                    "scheduler admits cache-hot requests first (continuous "
                    "only; chunked-admission archs)")
    ap.add_argument("--prefix-cache-mb", type=float, default=64.0,
                    help="prefix-cache byte budget in MiB (LRU eviction)")
    ap.add_argument("--draft", default=None,
                    help="draft arch for speculative decoding (continuous "
                    "only, greedy only; must share the target's "
                    "tokenizer/vocab — mismatches raise ValueError)")
    ap.add_argument("--spec-k", type=int, default=4,
                    help="candidates per speculative verify step (1 free "
                    "target token + spec-k - 1 drafted)")
    ap.add_argument("--act-dtype", choices=["none", "int8"], default="none",
                    help="activation dtype for the packed ternary "
                    "projections: int8 quantizes per token (absmax) in "
                    "front of every packed matmul — the W1.58A8 end-to-end "
                    "path (dispatch routes w2a8/grouped_w2a8/tl2)")
    ap.add_argument("--scenario", default=None,
                    help="replay a named multi-tenant workload (chat | rag "
                    "| agentic | code) open-loop under the WALL clock "
                    "instead of the fixed request list, and print "
                    "per-tenant p50/p95/p99 TTFT+TPOT and SLO attainment "
                    "(continuous only; --smoke shrinks the scenario too)")
    ap.add_argument("--scenario-seed", type=int, default=0,
                    help="arrival-trace seed for --scenario")
    ap.add_argument("--dynamic-spec-k", action="store_true",
                    help="with --draft: size each request's next draft "
                    "window from its measured acceptance, clamped to "
                    "[2, --spec-k]")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if args.act_dtype != "none":
        cfg = cfg.with_(act_dtype=args.act_dtype)
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    if args.ckpt_dir:
        step, state = ckpt.restore_latest(
            args.ckpt_dir, jax.eval_shape(lambda: {"params": params}))
        if state is not None:
            params = state["params"]
            print(f"[serve] restored step {step} from {args.ckpt_dir}")

    served = quantize_for_serving(params, cfg)
    print(f"[serve] {cfg.name}: packed {packed_bits_per_weight(served):.3f} b/w")
    mesh = None
    if args.mesh:
        from repro.launch.mesh import make_serving_mesh

        mesh = make_serving_mesh(args.mesh)
        print(f"[serve] mesh {args.mesh}: "
              f"{dict(zip(mesh.axis_names, mesh.devices.shape))}")
    draft = None
    if args.draft:
        if args.discipline != "continuous":
            raise SystemExit("[serve] --draft requires --discipline "
                             "continuous (the generational path ignores "
                             "the draft)")
        dcfg = (get_smoke_config(args.draft) if args.smoke
                else get_config(args.draft))
        draft_params = quantize_for_serving(
            init_params(dcfg, jax.random.PRNGKey(1)), dcfg)
        print(f"[serve] draft {dcfg.name}: spec_k={args.spec_k}, packed "
              f"{packed_bits_per_weight(draft_params):.3f} b/w")
        draft = (draft_params, dcfg)
    scenario = None
    if args.scenario:
        if args.discipline != "continuous":
            raise SystemExit("[serve] --scenario requires --discipline "
                             "continuous (open-loop arrivals need slot "
                             "refills)")
        from repro.serving.workload import get_scenario

        scenario = get_scenario(args.scenario)
        if args.smoke:
            scenario = scenario.smoke()
        need = scenario.max_prompt_len() + scenario.max_new_tokens() + 1
        if args.max_len < need:  # the scenario dictates the geometry
            args.max_len = -(-need // 16) * 16
            print(f"[serve] scenario {scenario.name}: max_len raised to "
                  f"{args.max_len}")
    engine = DecodeEngine(served, cfg, batch_size=args.batch,
                          max_len=args.max_len,
                          sampler=SamplerConfig(temperature=args.temperature,
                                                top_k=args.top_k),
                          prefill_chunk=args.prefill_chunk, mesh=mesh,
                          prefix_cache=args.prefix_cache,
                          prefix_cache_mb=args.prefix_cache_mb,
                          draft=draft, spec_k=args.spec_k)
    if scenario is not None:
        from repro.serving.loadgen import (LoadGenerator, generate_trace,
                                           latency_summary)

        trace = generate_trace(scenario, cfg.vocab_size, args.scenario_seed)
        budget = args.admission_budget if args.admission_budget > 0 else None
        gen = LoadGenerator(engine, trace, clock="wall",
                            admission_budget=budget,
                            dynamic_spec_k=args.dynamic_spec_k)
        res = gen.run()
        print(f"[serve] scenario {scenario.name} (seed "
              f"{args.scenario_seed}): {len(res.records)} requests, "
              f"offered {res.offered_qps:.2f} qps, achieved "
              f"{res.achieved_qps:.2f} qps, makespan {res.makespan_s:.2f}s")
        tenants = {t.name: t for t in scenario.tenants}
        for name, recs in sorted(res.by_tenant().items()):
            ttft = latency_summary(
                [r.ttft_s for r in recs if r.ttft_s is not None], 4)
            tpot = latency_summary(
                [r.tpot_s for r in recs if r.tpot_s is not None], 4)
            ten = tenants[name]
            ok = sum(1 for r in recs
                     if r.ttft_s is not None and r.ttft_s <= ten.slo_ttft_s
                     and (r.tpot_s is None or r.tpot_s <= ten.slo_tpot_s))
            print(f"[serve]   {name}: {len(recs)} reqs | ttft p50/p95/p99 "
                  f"{ttft['p50']}/{ttft['p95']}/{ttft['p99']}s | tpot p50 "
                  f"{tpot['p50']}s | slo attainment {ok / len(recs):.0%}")
        return

    n_req = args.requests if args.requests is not None else args.batch
    reqs = [Request(prompt=[7 + i, 13 + i], max_new_tokens=args.new_tokens)
            for i in range(n_req)]

    t0 = time.time()
    if args.discipline == "generational":
        if n_req > args.batch:
            raise SystemExit("[serve] generational cannot queue: "
                             "--requests must be <= --batch")
        engine.run(reqs)
        steps = max(len(r.out) for r in reqs)
    else:
        ids = {r.rid: i for i, r in enumerate(reqs)}
        on_token = (lambda r, t: print(f"  [stream] req {ids[r.rid]}: {t}")) \
            if args.stream else None
        budget = args.admission_budget if args.admission_budget > 0 else None
        sched = ContinuousScheduler(engine, on_token=on_token,
                                    admission_budget=budget,
                                    dynamic_spec_k=args.dynamic_spec_k)
        for r in reqs:
            sched.submit(r)
        sched.run()
        steps = sched.stats.decode_steps
    dt = time.time() - t0
    n = sum(len(r.out) for r in reqs)
    print(f"[serve] {args.discipline}: {n} tokens / {steps} decode steps "
          f"in {dt:.1f}s ({n / dt:.1f} tok/s)")
    if args.draft and args.discipline == "continuous":
        st = sched.stats
        print(f"[serve] speculative: {st.spec_rounds} rounds, "
              f"{st.accepted_drafted_tokens}/{st.drafted_tokens} drafted "
              f"tokens accepted ({st.acceptance_rate:.0%}), "
              f"{n / max(st.decode_steps, 1):.2f} tok/decode-step")
    if engine.prefix_store is not None:
        st = engine.prefix_store.stats
        print(f"[serve] prefix cache: {st.hit_blocks}/{st.lookups} block "
              f"hits ({st.hit_rate:.0%}), {st.reused_tokens} prompt tokens "
              f"spliced, {len(engine.prefix_store)} blocks resident "
              f"({engine.prefix_store.nbytes >> 10} KiB)")
    for i, r in enumerate(reqs):
        print(f"  [{i}] {r.out}")


if __name__ == "__main__":
    main()
