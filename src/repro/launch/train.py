"""Fault-tolerant distributed training loop.

Production behaviors implemented (and exercised on local devices by tests
and examples — the same code path drives the 512-chip mesh):

  * **checkpoint/restart** — async atomic checkpoints every
    ``--checkpoint-every`` steps; ``--resume auto`` restores the latest
    committed step (crc-validated) and the data stream realigns to it
    deterministically (the pipeline is a pure function of step).
  * **elastic restarts** — checkpoints store logical (unsharded) arrays;
    on restore they are device_put against the *current* mesh's shardings,
    so a restart may change pod/host count.
  * **straggler mitigation** — per-step deadline watchdog: a step exceeding
    ``deadline_factor ×`` the trailing-median step time is logged and
    counted; after ``max_straggler_strikes`` the loop checkpoints and exits
    non-zero so the scheduler can reschedule around the slow host (on real
    pods the signal keys off the cross-host step barrier; here the timing
    harness is identical with the barrier replaced by device sync).
  * **gradient compression** — optional int8 error-feedback all-reduce
    (``--compress-grads``), see optim/compression.py.
  * **NaN containment** — non-finite loss skips the update (grad-skip), a
    standard guard for QAT at scale.

On multi-host TPU this file is launched per host (jax.distributed handles
process groups); the container runs it single-process on CPU devices.
"""

from __future__ import annotations

import argparse
import functools
import statistics
import sys
import time

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.checkpoint import checkpointing as ckpt
from repro.configs.registry import get_config, get_smoke_config
from repro.data.pipeline import DataConfig, SyntheticLMStream
from repro.models.model import init_params, train_loss
from repro.optim import compression
from repro.optim.optimizers import clip_by_global_norm, make_optimizer
from repro.parallel import sharding as sh


def make_train_step(cfg, opt, compress: bool = False):
    def train_step(params, opt_state, err_state, batch, step):
        (loss, metrics), grads = jax.value_and_grad(
            train_loss, has_aux=True)(params, cfg, batch)
        if compress:
            grads, err_state = compression.roundtrip(grads, err_state)
        grads, gnorm = clip_by_global_norm(grads, 1.0)
        new_params, new_state = opt.update(opt_state, grads, params, step)
        # NaN containment: skip the update when loss/grads blow up.
        ok = jnp.isfinite(loss) & jnp.isfinite(gnorm)
        new_params = jax.tree.map(lambda n, o: jnp.where(ok, n, o), new_params, params)
        new_state = jax.tree.map(lambda n, o: jnp.where(ok, n, o), new_state, opt_state)
        return new_params, new_state, err_state, {
            "loss": loss, "gnorm": gnorm, "skipped": (~ok).astype(jnp.float32),
            **metrics}
    return train_step


def train(cfg, *, steps: int, global_batch: int, seq_len: int, mesh,
          ckpt_dir: str | None = None, checkpoint_every: int = 50,
          resume: str = "auto", compress_grads: bool = False,
          deadline_factor: float = 3.0, max_straggler_strikes: int = 5,
          log_every: int = 10, lr: float = 3e-4):
    key = jax.random.PRNGKey(0)
    opt = make_optimizer(cfg.optimizer, base_lr=lr, total=steps)

    params_sds = jax.eval_shape(functools.partial(init_params, cfg), key)
    pspecs = sh.param_specs(params_sds, mesh)
    psh = sh.to_shardings(pspecs, mesh)
    sspecs = opt.state_specs(pspecs, params_sds)
    ssh = sh.to_shardings(sspecs, mesh)

    with mesh:
        params = jax.jit(functools.partial(init_params, cfg),
                         out_shardings=psh)(key)
        opt_state = jax.jit(opt.init, out_shardings=ssh)(params)
    err_state = compression.init_error_state(params) if compress_grads else {}

    start_step = 0
    if ckpt_dir and resume == "auto":
        latest = ckpt.latest_step(ckpt_dir)
        if latest is not None:
            state = ckpt.restore(ckpt_dir, latest,
                                 {"params": params_sds,
                                  "opt": jax.eval_shape(opt.init, params_sds)})
            # elastic: re-place against the *current* mesh
            params = jax.device_put(state["params"], psh)
            opt_state = jax.device_put(state["opt"], ssh)
            start_step = latest + 1
            print(f"[train] resumed from step {latest}")

    data = SyntheticLMStream(DataConfig(cfg.vocab_size, seq_len, global_batch))
    sample = data.batch(0)
    bsh = sh.to_shardings(sh.batch_specs(sample, mesh), mesh)

    step_fn = jax.jit(make_train_step(cfg, opt, compress_grads),
                      in_shardings=(psh, ssh, None, bsh, NamedSharding(mesh, P())),
                      out_shardings=(psh, ssh, None, None),
                      donate_argnums=(0, 1, 2))

    times, strikes = [], 0
    history = []
    for step in range(start_step, steps):
        batch = jax.device_put(data.batch(step), bsh)
        t0 = time.time()
        with mesh:
            params, opt_state, err_state, metrics = step_fn(
                params, opt_state, err_state, batch, jnp.asarray(step))
        jax.block_until_ready(metrics["loss"])
        dt = time.time() - t0
        # --- straggler watchdog ---
        if len(times) >= 5:
            med = statistics.median(times[-20:])
            if dt > deadline_factor * med:
                strikes += 1
                print(f"[train] straggler: step {step} took {dt:.2f}s "
                      f"(median {med:.2f}s) — strike {strikes}")
                if strikes >= max_straggler_strikes:
                    if ckpt_dir:
                        ckpt.save(ckpt_dir, step,
                                  {"params": params, "opt": opt_state})
                    print("[train] too many stragglers; checkpointed, "
                          "exiting for reschedule")
                    return {"exit": "straggler", "step": step,
                            "history": history}
        times.append(dt)
        history.append(float(metrics["loss"]))
        if step % log_every == 0 or step == steps - 1:
            print(f"[train] step {step:5d} loss {float(metrics['loss']):.4f} "
                  f"gnorm {float(metrics['gnorm']):.3f} {dt*1e3:.0f}ms"
                  + (" SKIPPED" if float(metrics['skipped']) else ""))
        if ckpt_dir and (step + 1) % checkpoint_every == 0:
            ckpt.save_async(ckpt_dir, step, {"params": params, "opt": opt_state})
    ckpt.wait()
    if ckpt_dir:
        ckpt.save(ckpt_dir, steps - 1, {"params": params, "opt": opt_state})
    return {"exit": "done", "step": steps - 1, "history": history,
            "params": params}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-friendly)")
    ap.add_argument("--ckpt-dir")
    ap.add_argument("--checkpoint-every", type=int, default=50)
    ap.add_argument("--resume", default="auto", choices=["auto", "none"])
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--lr", type=float, default=3e-4)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    n = jax.device_count()
    mesh = jax.make_mesh((n, 1), ("data", "model")) if n > 1 else \
        jax.make_mesh((1, 1), ("data", "model"))
    out = train(cfg, steps=args.steps, global_batch=args.global_batch,
                seq_len=args.seq_len, mesh=mesh, ckpt_dir=args.ckpt_dir,
                checkpoint_every=args.checkpoint_every, resume=args.resume,
                compress_grads=args.compress_grads, lr=args.lr)
    sys.exit(0 if out["exit"] == "done" else 17)


if __name__ == "__main__":
    main()
