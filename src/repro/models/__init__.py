"""Model zoo: config schema, shared layers, family trunks, serving paths."""
