"""Model configuration schema shared by all assigned architectures."""

from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Literal

Family = Literal["dense", "moe", "hybrid", "ssm", "audio", "vlm"]


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Family
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 → d_model // n_heads

    # attention flavor
    qkv_bias: bool = False
    qk_norm: bool = False
    window: int = 0            # sliding-window size for decode/long ctx (0 = full)
    rope_theta: float = 10_000.0

    # block flavor
    act_fn: str = "silu"       # "silu" (SwiGLU) | "gelu" (GeGLU)
    ffn_gated: bool = True     # False → plain 2-layer MLP (whisper)
    rmsnorm_offset: bool = False   # gemma: weight stored as (1 + w)
    embed_scale: bool = False      # gemma: scale embeddings by sqrt(d_model)
    tie_embeddings: bool = False

    # MoE
    n_experts: int = 0
    experts_per_token: int = 0
    moe_shared_expert: bool = False
    moe_every: int = 1         # MoE on every k-th layer (others dense)
    dense_ff: int = 0          # d_ff of interleaved dense layers (0 → d_ff)
    capacity_factor: float = 1.25

    # SSM / hybrid / recurrent
    block_pattern: str = "attn"    # attn | mamba2 | zamba2 | xlstm
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    attn_every: int = 0            # zamba2: shared attn block every k mamba blocks

    # encoder-decoder (whisper) / modality frontends
    enc_layers: int = 0
    enc_seq: int = 0               # encoder positions (whisper: 1500 frames)
    frontend: str = "none"         # none | audio_stub | vit_stub
    vision_tokens: int = 0         # vlm: prefix positions fed from the stub

    # quantization (the paper's technique)
    quant: str = "qat"             # "fp" | "qat" (training); serving packs ternary
    quantize_acts: bool = False    # optional INT8 activation fake-quant in QAT
    mu: int = 3                    # LUT group size for the lut serving path
    act_dtype: str = "none"        # serving activation dtype for the packed
                                   # ternary projections: "none" keeps the
                                   # compute dtype (bf16 dequant paths);
                                   # "int8" quantizes per token (absmax) in
                                   # front of every packed matmul so dispatch
                                   # routes the W1.58A8 kernels
                                   # (w2a8/grouped_w2a8/tl2)
    matmul_policy: str | None = None   # ternary-matmul dispatch: "auto" |
                                       # "prior" | "fixed:<kernel>"; None
                                       # defers to $REPRO_TERNARY_POLICY,
                                       # then "auto" (repro.kernels.dispatch)

    # numerics / training
    dtype: str = "bfloat16"
    remat: bool = True
    loss_chunk: int = 512          # vocab-projection chunking for CE loss
    optimizer: str = "adamw"       # "adamw" | "adafactor" (for >=30B archs)

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        assert self.n_heads % max(self.n_kv_heads, 1) == 0

    @property
    def padded_vocab(self) -> int:
        """Vocab padded to a multiple of 256 for clean TP sharding of the
        embedding/LM head (standard practice, e.g. MaxText).  Logits beyond
        ``vocab_size`` are masked to -inf in the loss and at decode."""
        return ((self.vocab_size + 255) // 256) * 256

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    @property
    def is_encdec(self) -> bool:
        return self.enc_layers > 0

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + blocks), for roofline
        MODEL_FLOPS and reporting."""
        d, f, v = self.d_model, self.d_ff, self.vocab_size
        emb = v * d * (1 if self.tie_embeddings else 2)
        if self.block_pattern in ("attn",):
            attn = d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
            if self.act_fn in ("silu", "gelu"):
                ffn = 3 * d * f
            else:
                ffn = 2 * d * f
            if self.n_experts:
                moe_layers = self.n_layers // self.moe_every
                dense_layers = self.n_layers - moe_layers
                dff = self.dense_ff or f
                ffn_dense = 3 * d * dff
                blocks = self.n_layers * attn + dense_layers * ffn_dense \
                    + moe_layers * (self.n_experts * ffn + d * self.n_experts
                                    + (ffn if self.moe_shared_expert else 0))
            else:
                blocks = self.n_layers * (attn + ffn)
        elif self.block_pattern == "zamba2":
            d_in = self.ssm_expand * d
            mamba = d * 2 * d_in + d_in * d + d_in * (2 * self.ssm_state)
            attn = d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d + 3 * d * f
            blocks = self.n_layers * mamba + attn  # shared attn counted once
        elif self.block_pattern == "mamba2":
            d_in = self.ssm_expand * d
            blocks = self.n_layers * (d * 2 * d_in + d_in * d + d_in * 2 * self.ssm_state)
        elif self.block_pattern == "xlstm":
            d_in = 2 * d
            mlstm = d * 2 * d_in + d_in * d + 3 * d_in * d_in // 4
            slstm = 4 * d * d + 4 * (d // self.n_heads) * d
            blocks = (self.n_layers // 2) * (mlstm + slstm)
        else:
            blocks = 0
        if self.is_encdec:
            attn = d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
            ffn = 2 * d * f
            blocks = self.enc_layers * (attn + ffn) + self.n_layers * (2 * attn + ffn)
        return emb + blocks

    def active_param_count(self) -> int:
        """Active parameters per token (MoE: only routed experts)."""
        if not self.n_experts:
            return self.param_count()
        full = self.param_count()
        f, d = self.d_ff, self.d_model
        ffn = 3 * d * f
        moe_layers = self.n_layers // self.moe_every
        inactive = moe_layers * (self.n_experts - self.experts_per_token) * ffn
        return full - inactive

    def with_(self, **kw) -> "ModelConfig":
        return replace(self, **kw)


def reduced(cfg: ModelConfig, **overrides) -> ModelConfig:
    """Shrink a config to smoke-test scale, preserving the family's structure
    (GQA ratio, MoE routing, SSM blocks, enc-dec split, shared-attn cadence)."""
    ratio = max(cfg.n_heads // max(cfg.n_kv_heads, 1), 1)
    kw = dict(
        n_layers=min(cfg.n_layers, 4 if cfg.attn_every == 0 else 2 * cfg.attn_every),
        d_model=128,
        n_heads=4,
        n_kv_heads=max(4 // ratio, 1),
        head_dim=32,
        d_ff=0 if cfg.d_ff == 0 else 256,
        vocab_size=512,
        loss_chunk=64,
    )
    if cfg.n_experts:
        kw.update(n_experts=min(cfg.n_experts, 8),
                  experts_per_token=cfg.experts_per_token)
    if cfg.enc_layers:
        kw.update(enc_layers=2, enc_seq=16)
    if cfg.ssm_state:
        kw.update(ssm_state=16, ssm_head_dim=16)
    if cfg.vision_tokens:
        kw.update(vision_tokens=8)
    kw.update(overrides)
    return cfg.with_(**kw)
