"""Serving-side model paths: ternary weight packing, KV/state caches,
prefill, and single-token decode for every architecture family.

``quantize_for_serving`` converts a trained parameter tree into the
deployment artifact the paper targets: every ternary-eligible projection is
replaced by ``{"packed": uint8 base-3 (1.6 b/w), "scale": absmean}``; decode
then streams ~10× fewer weight bytes from HBM than bf16 — the memory-bound
decode win that motivates the whole accelerator line (§I).

Caches use a ring buffer when the config has a sliding ``window`` (zamba2's
shared attention at 500k context), with absolute-position slots so RoPE'd
keys stay valid after wraparound.  Every writer honours one canonical ring
invariant — **position ``p`` lives at slot ``p % CL``** (:func:`_ring_slot`)
— so whole-prompt prefill, chunked prefill, and decode writes all agree on
where a key belongs and wraparound never evicts an in-window key early.

Decode is continuous-batching ready: ``decode_step`` takes a per-slot
position vector ``index: [B]`` (each row masks/advances independently;
``-1`` marks a dead row whose KV write must drop), ``prefill_into_slot``
splices a single freshly-prefilled request into one batch row of a live
cache, and :func:`prefill_chunk` advances a prefill by one fixed-size
chunk — the length-bucketed admission path (prompts padded to chunk
multiples compile one trace total) — see :mod:`repro.serving.scheduler`.
"""

from __future__ import annotations

import logging
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import encoding
from repro.core.quantization import ternarize
from repro.models import ssm, xlstm
from repro.models.config import ModelConfig
from repro.models.layers import (
    attention,
    ffn,
    linear,
    moe_capacity,
    moe_ffn,
    rms_norm,
)
from repro.models.model import (
    Params,
    _whisper_encode,
    embed_tokens,
    lm_head_w,
    sinusoidal_position_at,
    sinusoidal_positions,
)

logger = logging.getLogger(__name__)

#: leaf-dict keys (within their parent block) that carry ternary weights
TERNARY_KEYS = {"wq", "wk", "wv", "wo", "wi", "wg", "up", "down", "wz", "wx",
                "ffn_up", "ffn_down"}
#: parent keys whose children must stay fp regardless
FP_PARENTS = {"router"}
#: top-level entries that stay fp
FP_TOP = {"embed", "lm_head"}


def _pack_leaf(leaf: dict, per_expert: bool) -> dict:
    w = leaf["w"]  # [..., din, dout]
    if per_expert:
        # [L, E, din, dout] → per-expert scales
        w_t, scale = ternarize(w, axis=(-2, -1))
        scale = scale[..., 0, 0]
    else:
        if w.ndim == 2:
            w_t, scale = ternarize(w)
        else:  # stacked [L, din, dout] → per-layer scale
            w_t, scale = ternarize(w, axis=(-2, -1))
            scale = scale[..., 0, 0]
    packed = encoding.pack_base3(jnp.swapaxes(w_t, -1, -2))  # [..., dout, ceil(din/5)]
    # Pad the packed dim to a multiple of 128 bytes: keeps TP shardings
    # divisible on any mesh axis ≤128 (zero bytes decode to trits past the
    # logical width, which unpack_base3(·, n) slices off).
    pad = (-packed.shape[-1]) % 128
    if pad:
        packed = jnp.pad(packed, [(0, 0)] * (packed.ndim - 1) + [(0, pad)])
    out = {"packed": packed, "scale": scale.astype(jnp.bfloat16)}
    if "b" in leaf:
        out["b"] = leaf["b"]
    return out


def quantize_for_serving(p: Params, cfg: ModelConfig) -> Params:
    """Training params → packed-ternary serving params (offline, like the
    paper's offline weight encoding)."""

    def walk(node, key_path):
        if isinstance(node, dict):
            if "w" in node and key_path and key_path[-1] in TERNARY_KEYS \
                    and not (set(key_path) & (FP_PARENTS | FP_TOP)):
                per_expert = node["w"].ndim == 4 and "moe" in key_path
                return _pack_leaf(node, per_expert)
            return {k: walk(v, key_path + (k,)) for k, v in node.items()}
        return node

    return walk(p, ())


def layer_matmul_problems(cfg: ModelConfig, batch_size: int,
                          seq_len: int = 1
                          ) -> list[tuple[str, int, int, int]]:
    """Role-tagged dense matmul problems ``(role, M, K, N)`` one forward
    step issues — ``role`` is the projection's parameter-leaf name, which is
    what the name-based TP rules (``repro.parallel.sharding``) key on, so a
    mesh-mode engine can map each problem to its per-device shard.  Roles
    that dispatch identically (``wk``/``wv``; ``wi``/``wg``) are listed once
    under a representative name."""
    M = batch_size * seq_len
    d = cfg.d_model
    probs: set[tuple[str, int, int, int]] = set()

    def proj(role, k, n):
        if k and n:
            probs.add((role, M, int(k), int(n)))

    has_attn = cfg.block_pattern in ("attn", "zamba2") or cfg.is_encdec
    if has_attn:
        proj("wq", d, cfg.q_dim)
        proj("wk", d, cfg.kv_dim)
        proj("wo", cfg.q_dim, d)
    if cfg.d_ff:
        proj("wi", d, cfg.d_ff)          # wi / wg
        proj("wo", cfg.d_ff, d)          # wo
    if cfg.dense_ff:
        proj("wi", d, cfg.dense_ff)
        proj("wo", cfg.dense_ff, d)
    if cfg.block_pattern in ("zamba2", "mamba2"):
        d_in, _, _ = ssm.ssm_dims(cfg)
        proj("wz", d, d_in)              # wz / wx
        proj("wo", d_in, d)              # wo
    if cfg.block_pattern == "xlstm":
        d_in, _, _ = xlstm.mlstm_dims(cfg)
        proj("up", d, 2 * d_in)          # mLSTM up
        proj("wq", d_in, d_in)           # mLSTM wq/wk/wv
        proj("down", d_in, d)            # mLSTM down
        up = int(d * 4 / 3)
        proj("ffn_up", d, 2 * up)        # sLSTM ffn_up
        proj("ffn_down", up, d)          # sLSTM ffn_down
    return sorted(probs)


def layer_matmul_shapes(cfg: ModelConfig, batch_size: int,
                        seq_len: int = 1) -> list[tuple[int, int, int]]:
    """The distinct ternary-matmul problems ``(M, K, N)`` one forward step
    issues through :func:`repro.kernels.dispatch.ternary_matmul`.

    ``M = batch_size · seq_len`` (decode: ``seq_len=1``); ``K``/``N`` are the
    in/out features of every ternary-eligible dense projection of the
    architecture.  This is the shape universe the autotune sweep
    (``benchmarks/autotune_sweep.py``) populates the dispatch cache with, so
    serving dispatch hits measured entries instead of the analytical prior.
    """
    return sorted({(m, k, n)
                   for _, m, k, n in layer_matmul_problems(cfg, batch_size,
                                                           seq_len)})


def layer_grouped_matmul_problems(cfg: ModelConfig, batch_size: int,
                                  seq_len: int = 1
                                  ) -> list[tuple[str, int, int, int, int]]:
    """Role-tagged grouped (MoE expert) problems ``(role, E, C, K, N)`` —
    the grouped analogue of :func:`layer_matmul_problems`.  Empty for
    non-MoE configs."""
    if not cfg.n_experts:
        return []
    E = cfg.n_experts
    cap = moe_capacity(cfg, batch_size * seq_len)
    d, f = cfg.d_model, cfg.d_ff
    return sorted({("wi", E, cap, d, f), ("wo", E, cap, f, d)})


def layer_grouped_matmul_shapes(cfg: ModelConfig, batch_size: int,
                                seq_len: int = 1
                                ) -> list[tuple[int, int, int, int]]:
    """The distinct grouped ternary-matmul problems ``(E, C, K, N)`` one
    forward step issues through
    :func:`repro.kernels.dispatch.grouped_ternary_matmul` — the MoE expert
    stacks (``wi``/``wg``: ``K = d_model``, ``N = d_ff``; ``wo`` reversed)
    at the step's per-expert capacity.  Decode capacity is tiny (often 1),
    which is exactly the weight-bandwidth-bound operating point the grouped
    packed kernels exist for.  Empty for non-MoE configs.
    """
    return sorted({(e, c, k, n)
                   for _, e, c, k, n in layer_grouped_matmul_problems(
                       cfg, batch_size, seq_len)})


def packed_bits_per_weight(p: Params) -> float:
    """Measured storage density of the serving artifact (paper: ≈1.6 b/w)."""
    packed_bits = ternary_weights = 0

    def walk(node):
        nonlocal packed_bits, ternary_weights
        if isinstance(node, dict):
            if "packed" in node:
                packed_bits += node["packed"].size * 8
                ternary_weights += node["packed"].size * encoding.TRITS_PER_BYTE
            else:
                for v in node.values():
                    walk(v)

    walk(p)
    return packed_bits / max(ternary_weights, 1)


# ---------------------------------------------------------------------------
# caches
# ---------------------------------------------------------------------------


def cache_len(cfg: ModelConfig, s_max: int) -> int:
    return min(cfg.window, s_max) if cfg.window else s_max


def init_cache(cfg: ModelConfig, B: int, s_max: int, dtype=jnp.bfloat16) -> dict:
    CL = cache_len(cfg, s_max)
    kv = lambda n: {
        "k": jnp.zeros((n, B, CL, cfg.n_kv_heads, cfg.head_dim), dtype),
        "v": jnp.zeros((n, B, CL, cfg.n_kv_heads, cfg.head_dim), dtype),
        # per-row slot positions: continuous batching gives every batch row
        # its own position trajectory (-1 = empty slot)
        "pos": jnp.full((n, B, CL), -1, jnp.int32),
    }
    if cfg.is_encdec:
        c = kv(cfg.n_layers)
        c["cross_k"] = jnp.zeros((cfg.n_layers, B, cfg.enc_seq, cfg.n_kv_heads,
                                  cfg.head_dim), dtype)
        c["cross_v"] = jnp.zeros_like(c["cross_k"])
        return c
    if cfg.block_pattern == "attn":
        return kv(cfg.n_layers)
    if cfg.block_pattern == "zamba2":
        d_in, H, N = ssm.ssm_dims(cfg)
        P = cfg.ssm_head_dim
        conv_ch = d_in + 2 * N
        c = kv(cfg.n_layers // cfg.attn_every)
        c["ssm"] = jnp.zeros((cfg.n_layers, B, H, N, P), jnp.float32)
        c["conv"] = jnp.zeros((cfg.n_layers, B, cfg.ssm_conv - 1, conv_ch), dtype)
        return c
    if cfg.block_pattern == "xlstm":
        d_in, H, dk = xlstm.mlstm_dims(cfg)
        half = cfg.n_layers // 2
        return {
            "mC": jnp.zeros((half, B, H, dk, dk), jnp.float32),
            "mn": jnp.zeros((half, B, H, dk), jnp.float32),
            "mm": jnp.full((half, B, H), -1e30, jnp.float32),
            "sc": jnp.zeros((half, B, cfg.d_model), jnp.float32),
            "sn": jnp.zeros((half, B, cfg.d_model), jnp.float32) + 1e-6,
            "sh": jnp.zeros((half, B, cfg.d_model), jnp.float32),
            "sm": jnp.full((half, B, cfg.d_model), -1e30, jnp.float32),
        }
    raise ValueError(cfg.block_pattern)


def _ring_slot(cfg: ModelConfig, CL: int, index: jax.Array) -> jax.Array:
    """Canonical ring-slot invariant: position ``p`` lives at slot ``p % CL``
    when a sliding window makes the cache a ring; full caches store at the
    position itself.  Negative positions (dead scheduler rows, padded chunk
    tails) map one past the cache end so the scatter write drops."""
    index = jnp.asarray(index, jnp.int32)
    slot = index % CL if (cfg.window and CL) else index
    return jnp.where(index >= 0, slot, CL)


# ---------------------------------------------------------------------------
# prefill
# ---------------------------------------------------------------------------


def _pad_kv_to(k: jax.Array, CL: int):
    """[L?, B, S, H, hd] → CL slots honouring the ring invariant.

    ``S < CL`` pads (position p sits at slot p); ``S >= CL`` keeps the last
    CL keys and rolls them so position ``p`` lands at slot ``p % CL`` — the
    slot ``decode_step`` will overwrite when it writes position ``p + CL``.
    Without the roll the window's oldest key would sit at slot 0 instead of
    ``(S - CL) % CL`` and the first post-prefill decode steps would evict
    *in-window* keys (one attended key silently lost per step until the ring
    is fully rewritten)."""
    S = k.shape[-3]
    if S >= CL:
        k = k[..., S - CL:, :, :]
        shift = S % CL
        return jnp.roll(k, shift, axis=-3) if shift else k
    pad = [(0, 0)] * k.ndim
    pad[-3] = (0, CL - S)
    return jnp.pad(k, pad)


def _prefill_positions(S: int, CL: int):
    """Per-slot absolute positions matching :func:`_pad_kv_to`'s layout:
    slot ``s`` holds position ``p`` ⇒ ``p % CL == s`` (-1 = empty)."""
    pos = jnp.arange(S, dtype=jnp.int32)
    if S >= CL:
        return jnp.roll(pos[S - CL:], S % CL)
    return jnp.concatenate([pos, jnp.full((CL - S,), -1, jnp.int32)])


def prefill(p: Params, cfg: ModelConfig, batch: dict, s_max: int):
    """Run the full prompt once; return (cache, last-position logits).

    A single kv/state-collecting pass over the trunk (``lax.scan`` ys carry
    the per-layer KV/states) — prefill costs exactly one forward.
    """
    tokens = batch["tokens"]
    B, S = tokens.shape
    CL = cache_len(cfg, s_max)
    if not cfg.window and S > CL:
        # a full-attention cache cannot hold the prompt; truncating to the
        # last s_max keys would silently change what decode attends to
        raise ValueError(
            f"prompt length {S} exceeds cache length {CL} (s_max) for a "
            f"non-windowed config; raise s_max/max_len instead of relying on "
            f"silent truncation")
    cache = init_cache(cfg, B, CL if cfg.window else s_max, dtype=jnp.bfloat16)
    positions = jnp.arange(S)
    from repro.models.layers import mask_padded_vocab

    def final_logits(x):
        x = rms_norm(p["final_norm"], x, offset=cfg.rmsnorm_offset)
        return mask_padded_vocab(
            (x[:, -1] @ lm_head_w(p, cfg)).astype(jnp.float32), cfg.vocab_size)

    if cfg.block_pattern == "attn" and not cfg.is_encdec:
        hs = embed_tokens(p, cfg, tokens, batch.get("vision_embeds"))

        def block_kv(x, blk, is_moe):
            hn = rms_norm(blk["ln1"], x, offset=cfg.rmsnorm_offset)
            a, (k, v) = attention(blk["attn"], hn, cfg, positions=positions,
                                  window=cfg.window, return_kv=True)
            x = x + a
            hn2 = rms_norm(blk["ln2"], x, offset=cfg.rmsnorm_offset)
            if is_moe:
                f, _ = moe_ffn(blk["moe"], hn2, cfg)
            else:
                f = ffn(blk["ffn"], hn2, cfg)
            return x + f, (k, v)

        if "dense_blocks" in p:  # interleaved MoE
            kk = cfg.moe_every
            groups = cfg.n_layers // kk
            dense = jax.tree.map(lambda t: t.reshape(groups, kk - 1, *t.shape[1:]),
                                 p["dense_blocks"])

            def group_body(x, blks):
                dblk, mblk = blks
                x, (kd, vd) = jax.lax.scan(
                    lambda xx, b: block_kv(xx, b, False), x, dblk)
                x, (km, vm) = block_kv(x, mblk, True)
                k = jnp.concatenate([kd, km[None]], axis=0)  # [kk, B, S, H, hd]
                v = jnp.concatenate([vd, vm[None]], axis=0)
                return x, (k, v)

            hs, (ks, vs) = jax.lax.scan(group_body, hs, (dense, p["moe_blocks"]))
            ks = ks.reshape(cfg.n_layers, *ks.shape[2:])
            vs = vs.reshape(cfg.n_layers, *vs.shape[2:])
        else:
            hs, (ks, vs) = jax.lax.scan(
                lambda x, b: block_kv(x, b, bool(cfg.n_experts)), hs, p["blocks"])
        logits = final_logits(hs)
        cache["k"] = _pad_kv_to(ks, CL).astype(cache["k"].dtype)
        cache["v"] = _pad_kv_to(vs, CL).astype(cache["v"].dtype)
        cache["pos"] = jnp.broadcast_to(_prefill_positions(S, CL),
                                        cache["pos"].shape)
        return cache, logits

    if cfg.is_encdec:
        enc_out = _whisper_encode(p, cfg, batch["frames"])
        hs = embed_tokens(p, cfg, tokens) + \
            sinusoidal_positions(S, cfg.d_model)[None]
        enc_pos = jnp.arange(cfg.enc_seq)

        def body(x, blk):
            a, (k, v) = attention(blk["self_attn"], rms_norm(blk["ln1"], x), cfg,
                                  positions=positions,
                                  use_rope=False, return_kv=True)
            x = x + a
            ck = linear(blk["cross_attn"]["wk"], enc_out, cfg, role="wk")
            cv = linear(blk["cross_attn"]["wv"], enc_out, cfg, role="wv")
            Se = enc_out.shape[1]
            ck = ck.reshape(B, Se, cfg.n_kv_heads, cfg.head_dim)
            cv = cv.reshape(B, Se, cfg.n_kv_heads, cfg.head_dim)
            x = x + attention(blk["cross_attn"], rms_norm(blk["ln2"], x), cfg,
                              positions=positions, k_positions=enc_pos,
                              kind="full", kv=(ck, cv), use_rope=False)
            x = x + ffn(blk["ffn"], rms_norm(blk["ln3"], x), cfg)
            return x, (k, v, ck, cv)

        hs, (ks, vs, cks, cvs) = jax.lax.scan(body, hs, p["dec_blocks"])
        logits = final_logits(hs)
        cache["k"] = _pad_kv_to(ks, CL).astype(cache["k"].dtype)
        cache["v"] = _pad_kv_to(vs, CL).astype(cache["v"].dtype)
        cache["cross_k"] = cks.astype(cache["cross_k"].dtype)
        cache["cross_v"] = cvs.astype(cache["cross_v"].dtype)
        cache["pos"] = jnp.broadcast_to(_prefill_positions(S, CL), cache["pos"].shape)
        return cache, logits

    if cfg.block_pattern == "zamba2":
        g = cfg.attn_every
        groups = cfg.n_layers // g
        stacked = jax.tree.map(lambda x: x.reshape(groups, g, *x.shape[1:]),
                               p["mamba_blocks"])
        shared = p["shared_attn"]
        hs = embed_tokens(p, cfg, tokens)

        def mamba_body(x, blk):
            hn = rms_norm(blk["ln"], x)
            y, (state, conv) = ssm.mamba2_block(blk["mixer"], hn, cfg)
            return x + y, (state, conv)

        def group_body(x, blks):
            x, (states, convs) = jax.lax.scan(mamba_body, x, blks)
            hn = rms_norm(shared["ln1"], x)
            a, (k, v) = attention(shared["attn"], hn, cfg, positions=positions,
                                  window=cfg.window, return_kv=True)
            x = x + a
            x = x + ffn(shared["ffn"], rms_norm(shared["ln2"], x), cfg)
            return x, (states, convs, k, v)

        hs, (states, convs, ks, vs) = jax.lax.scan(group_body, hs, stacked)
        logits = final_logits(hs)
        cache["ssm"] = states.reshape(cfg.n_layers, *states.shape[2:])
        cache["conv"] = convs.reshape(cfg.n_layers, *convs.shape[2:]).astype(cache["conv"].dtype)
        cache["k"] = _pad_kv_to(ks, CL).astype(cache["k"].dtype)
        cache["v"] = _pad_kv_to(vs, CL).astype(cache["v"].dtype)
        cache["pos"] = jnp.broadcast_to(_prefill_positions(S, CL), cache["pos"].shape)
        return cache, logits

    if cfg.block_pattern == "xlstm":
        hs = embed_tokens(p, cfg, tokens)

        def body(x, blks):
            mblk, sblk = blks
            y, (C, n, m) = xlstm.mlstm_block(mblk["cell"], rms_norm(mblk["ln"], x), cfg)
            x = x + y
            y, (sc, sn, sh, sm) = xlstm.slstm_block(sblk["cell"],
                                                    rms_norm(sblk["ln"], x), cfg)
            return x + y, (C, n, m, sc, sn, sh, sm)

        hs, (C, n, m, sc, sn, sh, sm) = jax.lax.scan(
            body, hs, (p["mlstm_blocks"], p["slstm_blocks"]))
        logits = final_logits(hs)
        cache.update(mC=C, mn=n, mm=m, sc=sc, sn=sn, sh=sh, sm=sm)
        return cache, logits

    raise ValueError(cfg.block_pattern)


def prefill_into_slot(p: Params, cfg: ModelConfig, cache: dict, batch: dict,
                      slot: jax.Array, s_max: int):
    """Prefill ONE request and splice its KV/state rows into batch row
    ``slot`` of a live multi-slot ``cache`` — the atomic reference form of
    the continuous-batching refill: a finished slot is re-armed mid-flight
    without touching (or re-prefilling) any other row.  (The serving engine
    performs the same prefill+splice through its jitted admission commit so
    chunked and whole-prompt admission share one splice; this function is
    the standalone API.)

    ``batch["tokens"]`` must have leading batch dim 1; ``slot`` is a (possibly
    traced) int32 row index.  Every cache leaf carries the batch on axis 1
    (``[layers, B, ...]``), so the splice is one dynamic_update_slice per
    leaf — rows other than ``slot`` are bit-identical afterwards, a live
    neighbour can never be clobbered.  Returns ``(cache, logits [V])`` with
    the last-prompt-position logits, ready to sample the slot's first token.
    """
    cache1, logits = prefill(p, cfg, batch, s_max=s_max)

    def splice(big, one):
        idx = (0, slot) + (0,) * (big.ndim - 2)
        return jax.lax.dynamic_update_slice(big, one.astype(big.dtype), idx)

    return jax.tree.map(splice, cache, cache1), logits[0]


# ---------------------------------------------------------------------------
# chunked prefill (length-bucketed admission)
# ---------------------------------------------------------------------------


def supports_chunked_prefill(p: Params, cfg: ModelConfig) -> bool:
    """Whether :func:`prefill_chunk` covers this (params, config).

    The chunk-scan path needs a uniform stack of attention blocks whose only
    cross-chunk state is the KV ring: plain ``attn`` stacks (incl. uniform
    MoE) qualify; encoder-decoder, modality frontends, interleaved-MoE
    (``dense_blocks``, e.g. llama4), and recurrent-state families
    (zamba2/xlstm, whose conv/SSM states would absorb chunk padding) fall
    back to whole-prompt admission via :func:`prefill_into_slot` — which
    retraces per prompt length (see ROADMAP "Continuous-batching
    follow-ups").  Each fallback logs its reason at DEBUG on
    ``repro.models.decode`` so the per-length-retrace tax is attributable.
    """
    reason = None
    if cfg.block_pattern != "attn":
        reason = (f"block_pattern={cfg.block_pattern!r} carries recurrent "
                  "conv/SSM chunk state")
    elif cfg.is_encdec:
        reason = "encoder-decoder stacks prefill the encoder whole"
    elif cfg.frontend != "none":
        reason = f"modality frontend {cfg.frontend!r} feeds prefix embeds"
    elif "dense_blocks" in p:
        reason = "interleaved-MoE (dense_blocks) stack is not a uniform scan"
    if reason is not None:
        logger.debug(
            "chunked prefill unsupported for %s: %s; admission falls back "
            "to whole-prompt prefill_into_slot (one jit trace per prompt "
            "length)", cfg.name, reason)
        return False
    return True


def _chunk_forward(p: Params, cfg: ModelConfig, cache: dict,
                   tokens: jax.Array, positions: jax.Array,
                   exact: bool = False):
    """Shared body of :func:`prefill_chunk` and :func:`verify_step`: run a
    ``[B, C]`` token chunk at per-row absolute ``positions`` through a
    uniform attention stack, attending the already-written ring (read-only)
    plus the chunk itself via
    :func:`repro.models.layers.append_attention`, then scatter the chunk's
    KV at the canonical ring slots (``p % CL``).  Positions of ``-1`` (dead
    rows, padded tails) neither write KV nor match any query.  Returns
    ``(cache, h [B, C, d_model])`` with ``h`` already final-norm'd.

    ``exact`` (dense caches only) switches attention to the scatter-first
    form: each layer writes the chunk's KV into its ring slots *before*
    attending, the ring scan is masked strictly below each query, and the
    chunk merges self-only as the extra online-softmax partition.  Every
    chunk position then reproduces the attended set, partition boundaries,
    and reduction order of a sequential :func:`decode_step` at that position
    — so the returned hidden states (and the KV left in the cache) are
    *bitwise* what C sequential decode steps would have produced.  This is
    what lets speculative verify guarantee byte-identical greedy streams.
    Windowed caches cannot use it (the pre-scatter would evict in-window
    keys that earlier chunk positions still attend) and keep the standard
    read-only form, which is positionally exact but may differ from
    sequential decode in the last bits of the softmax reduction.
    """
    if not supports_chunked_prefill(p, cfg):
        raise NotImplementedError(
            f"chunked prefill not supported for {cfg.name} "
            f"(block_pattern={cfg.block_pattern}); use prefill()")
    from repro.models.layers import append_attention

    B, C = tokens.shape
    CL = cache["pos"].shape[-1]
    if cfg.window and C > CL:
        raise ValueError(
            f"chunk size {C} exceeds ring length {CL}: a single chunk would "
            f"collide with itself in the ring; use chunks <= the window")
    if exact and cfg.window:
        raise ValueError(
            "exact (scatter-first) chunk forward requires a dense cache: a "
            "wrapped ring would pre-evict in-window keys that earlier chunk "
            "positions still attend")
    positions = jnp.asarray(positions, jnp.int32)
    slot = _ring_slot(cfg, CL, positions)  # [B, C]; padded tail drops
    rows = jnp.arange(B)
    h = embed_tokens(p, cfg, tokens)
    old_pos = cache["pos"][0]  # [B, CL] pre-chunk positions (-1 = empty)
    # exact mode scatters positions up front: queries see chunk-mates' slots
    k_pos = (old_pos.at[rows[:, None], slot].set(positions) if exact
             else old_pos)

    def body(x, xs):
        blk, ck, cv = xs
        hn = rms_norm(blk["ln1"], x, offset=cfg.rmsnorm_offset)
        a, (k, v) = append_attention(blk["attn"], hn, cfg, positions=positions,
                                     cache_k=ck, cache_v=cv,
                                     k_positions=k_pos, window=cfg.window,
                                     scatter_slots=slot if exact else None)
        x = x + a
        hn = rms_norm(blk["ln2"], x, offset=cfg.rmsnorm_offset)
        if cfg.n_experts:
            f, _ = moe_ffn(blk["moe"], hn, cfg)
        else:
            f = ffn(blk["ffn"], hn, cfg)
        return x + f, (k, v)

    h, (k_new, v_new) = jax.lax.scan(body, h, (p["blocks"], cache["k"],
                                               cache["v"]))
    if exact:
        # each layer already scattered its chunk KV pre-attention; the scan
        # ys stack IS the new cache
        new_pos = cache["pos"].at[:, rows[:, None], slot].set(positions)
        cache = dict(cache, k=k_new, v=v_new, pos=new_pos)
    else:
        # one batched scatter per leaf: all layers' chunk tokens at their
        # canonical slots (padded positions target slot CL and drop)
        ks = cache["k"].at[:, rows[:, None], slot].set(k_new.astype(cache["k"].dtype))
        vs = cache["v"].at[:, rows[:, None], slot].set(v_new.astype(cache["v"].dtype))
        new_pos = cache["pos"].at[:, rows[:, None], slot].set(positions)
        cache = dict(cache, k=ks, v=vs, pos=new_pos)
    h = rms_norm(p["final_norm"], h, offset=cfg.rmsnorm_offset)
    return cache, h


def prefill_chunk(p: Params, cfg: ModelConfig, cache: dict,
                  tokens: jax.Array, positions: jax.Array,
                  take: jax.Array | int | None = None):
    """Advance a prefill by ONE fixed-size chunk of the prompt.

    The admission path of continuous batching: instead of tracing one whole-
    prompt prefill per prompt length, the engine pads prompts to a multiple
    of the chunk size and scans them through this function — every chunk has
    the same shape, so a mixed-length request stream compiles exactly one
    trace.  Each chunk attends the already-written ring (read-only) plus
    itself via :func:`repro.models.layers.append_attention` and then writes
    its KV at the canonical ring slots (``p % CL`` — the same invariant
    whole-prompt prefill and decode honour), so chunked and whole-prompt
    prefill produce the same cache.

    tokens: [B, C]; positions: int32 [B, C] absolute prompt positions, -1 on
    the padded tail (padded tokens neither write KV nor match any query);
    ``take``: index into the chunk of the token whose logits to return
    (default C-1; pass the last *valid* index for a padded final chunk).
    Returns (cache, logits [B, V]).
    """
    from repro.models.layers import mask_padded_vocab

    C = tokens.shape[1]
    take = C - 1 if take is None else take
    cache, h = _chunk_forward(p, cfg, cache, tokens, positions)
    logits = (h[:, take] @ lm_head_w(p, cfg)).astype(jnp.float32)
    return cache, mask_padded_vocab(logits, cfg.vocab_size)


def verify_step(p: Params, cfg: ModelConfig, cache: dict, tokens: jax.Array,
                start: jax.Array):
    """Score K candidate tokens per row in ONE batched forward — the verify
    half of draft-and-verify speculative decoding.

    ``tokens``: int32 [B, K] candidate continuations per slot; ``start``:
    int32 [B], the absolute position of each row's FIRST candidate (the
    scheduler passes ``index + 1``; ``-1`` marks a dead row — the whole row
    is masked, so no position of a dead row can write KV or match a query).
    Row ``b``'s candidates sit at positions ``start[b] .. start[b]+K-1`` and
    their KV is written at the canonical ring slots (``p % CL``), exactly as
    K sequential :func:`decode_step` calls would have.

    Returns ``(logits [B, K, V], cache)``: ``logits[b, j]`` is the target's
    next-token distribution after consuming candidates ``0..j`` — comparing
    ``argmax(logits[:, :-1])`` against ``tokens[:, 1:]`` yields the accepted
    prefix, and :func:`rollback_kv_window` rewinds the rejected suffix.
    Only architectures with a uniform attention stack are supported (same
    gate as :func:`supports_chunked_prefill`).

    On dense caches the forward runs in scatter-first *exact* mode: logits
    and written KV are bitwise what K sequential :func:`decode_step` calls
    produce, so speculative greedy streams are byte-identical to
    non-speculative serving by construction.  Windowed caches use the
    read-only chunk form — positionally exact, but the online-softmax
    partitioning differs from sequential decode, so bf16 logit *ties* may
    resolve differently (greedy streams can diverge at near-tie tokens).
    """
    from repro.models.layers import mask_padded_vocab

    B, K = tokens.shape
    start = jnp.asarray(start, jnp.int32)
    # guard the WHOLE row on start < 0: -1 + j is >= 0 for j >= 1, so a
    # per-position mask would let dead rows write real ring slots
    positions = jnp.where(start[:, None] >= 0,
                          start[:, None] + jnp.arange(K, dtype=jnp.int32), -1)
    cache, h = _chunk_forward(p, cfg, cache, tokens, positions,
                              exact=not cfg.window)
    logits = (h @ lm_head_w(p, cfg)).astype(jnp.float32)  # [B, K, V]
    return mask_padded_vocab(logits, cfg.vocab_size), cache


def snapshot_kv_window(cfg: ModelConfig, cache: dict, start: jax.Array,
                       K: int) -> dict:
    """Capture the KV/pos entries the next K-token speculative write will
    touch, BEFORE writing — the undo slab for :func:`rollback_kv_window`.

    Gathers, per row, the K ring slots for positions ``start[b] ..
    start[b]+K-1`` (``start[b] = -1`` = dead row; its slots resolve to CL so
    the paired restore drops).  Within a row, K ≤ CL consecutive positions
    map to K distinct slots, so the snapshot/restore pair is exact even when
    the window wraps and the speculative write evicts in-window keys.
    Returns ``{"slot": [B, K], "pos": [B, K], "k"/"v": [L, B, K, Hkv, hd]}``.
    """
    CL = cache["pos"].shape[-1]
    start = jnp.asarray(start, jnp.int32)
    positions = jnp.where(start[:, None] >= 0,
                          start[:, None] + jnp.arange(K, dtype=jnp.int32), -1)
    slot = _ring_slot(cfg, CL, positions)  # [B, K]; dead/OOB -> CL (clamped read)
    rows = jnp.arange(slot.shape[0])
    return {
        "slot": slot,
        "pos": cache["pos"][0][rows[:, None], slot],
        "k": cache["k"][:, rows[:, None], slot],
        "v": cache["v"][:, rows[:, None], slot],
    }


def rollback_kv_window(cfg: ModelConfig, cache: dict, undo: dict,
                       keep: jax.Array) -> dict:
    """Rewind a K-token speculative write: restore entries ``j >= keep[b]``
    of each row from the ``undo`` slab (:func:`snapshot_kv_window`), leaving
    the accepted prefix ``j < keep[b]`` in place.  Restored slots get their
    pre-write KV *and* position values back — including ``-1`` (empty) and
    evicted in-window positions on a wrapped ring — so the cache is exactly
    what K_accepted sequential :func:`decode_step` writes would have left.
    Kept (and dead-row) entries target slot CL, which scatter-drops.
    """
    K = undo["slot"].shape[1]
    CL = cache["pos"].shape[-1]
    rows = jnp.arange(undo["slot"].shape[0])
    restore = jnp.arange(K)[None, :] >= jnp.asarray(keep, jnp.int32)[:, None]
    slot = jnp.where(restore, undo["slot"], CL)  # kept entries drop
    return dict(
        cache,
        k=cache["k"].at[:, rows[:, None], slot].set(
            undo["k"].astype(cache["k"].dtype)),
        v=cache["v"].at[:, rows[:, None], slot].set(
            undo["v"].astype(cache["v"].dtype)),
        pos=cache["pos"].at[:, rows[:, None], slot].set(undo["pos"]),
    )


def extract_kv_blocks(cfg: ModelConfig, cache: dict, start: jax.Array | int,
                      length: int) -> dict:
    """Pull one prefix block's KV out of a single-row cache: the slab
    ``{"k": [L, length, Hkv, hd], "v": [L, length, Hkv, hd]}`` holding
    positions ``[start, start + length)`` — read from their canonical ring
    slots (``p % CL``), so the extraction is valid whenever those positions
    are still live in the ring (the engine extracts each chunk right after
    prefilling it, before any wraparound can overwrite it).

    ``start`` may be traced (one jit trace serves every block index);
    ``length`` is static (the slab shape).  Inverse of
    :func:`splice_kv_blocks`.
    """
    CL = cache["pos"].shape[-1]
    pos = jnp.asarray(start, jnp.int32) + jnp.arange(length, dtype=jnp.int32)
    slots = _ring_slot(cfg, CL, pos)  # in-range by construction
    return {"k": jnp.take(cache["k"][:, 0], slots, axis=1),
            "v": jnp.take(cache["v"][:, 0], slots, axis=1)}


def splice_kv_blocks(cfg: ModelConfig, cache: dict, block: dict,
                     start: jax.Array | int) -> dict:
    """Write a cached prefix block back into a single-row cache at the
    canonical ring slots for positions ``[start, start + length)`` —
    KV *and* the per-slot position row, so a subsequent chunked-prefill or
    decode step sees exactly the state the original compute left behind
    (byte-identical: the slab is spliced in its stored dtype, untouched).

    Blocks must be spliced in prefix order: with a sliding window a later
    block's slots may wrap onto an earlier block's (the engine caps reuse
    depth at ``CL`` so this never happens, but the primitive stays correct
    either way — later writes win, matching recompute).  Inverse of
    :func:`extract_kv_blocks`.  Returns the updated cache.
    """
    CL = cache["pos"].shape[-1]
    length = block["k"].shape[1]
    pos = jnp.asarray(start, jnp.int32) + jnp.arange(length, dtype=jnp.int32)
    slots = _ring_slot(cfg, CL, pos)
    return dict(
        cache,
        k=cache["k"].at[:, 0, slots].set(block["k"].astype(cache["k"].dtype)),
        v=cache["v"].at[:, 0, slots].set(block["v"].astype(cache["v"].dtype)),
        pos=cache["pos"].at[:, 0, slots].set(pos),
    )


def prefill_chunks_of(plen: int, chunk: int) -> list[tuple[int, int]]:
    """Split a prompt of length ``plen`` into ``(start, valid)`` chunk specs
    (every chunk spans ``chunk`` tokens; the last may have ``valid < chunk``
    padded tail positions)."""
    if plen < 1:
        raise ValueError("empty prompt")
    return [(s, min(chunk, plen - s)) for s in range(0, plen, chunk)]


def prefill_chunked(p: Params, cfg: ModelConfig, batch: dict, s_max: int,
                    chunk: int):
    """Whole-prompt prefill built from :func:`prefill_chunk` scans — the
    differential-oracle form: must produce the same cache and last-position
    logits as :func:`prefill` (windowed or not, including prompts that wrap
    the ring), while compiling one trace per chunk size instead of one per
    prompt length.  Returns (cache, logits [B, V])."""
    tokens = batch["tokens"]
    B, S = tokens.shape
    CL = cache_len(cfg, s_max)
    if not cfg.window and S > CL:
        raise ValueError(
            f"prompt length {S} exceeds cache length {CL} (s_max) for a "
            f"non-windowed config")
    cache = init_cache(cfg, B, CL if cfg.window else s_max, dtype=jnp.bfloat16)
    logits = None
    for start, valid in prefill_chunks_of(S, chunk):
        ctoks = jnp.pad(tokens[:, start:start + valid],
                        ((0, 0), (0, chunk - valid)), constant_values=1)
        cpos = jnp.where(jnp.arange(chunk) < valid,
                         start + jnp.arange(chunk), -1)
        cpos = jnp.broadcast_to(cpos, (B, chunk)).astype(jnp.int32)
        cache, logits = prefill_chunk(p, cfg, cache, ctoks, cpos,
                                      take=valid - 1)
    return cache, logits


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------


def decode_step(p: Params, cfg: ModelConfig, cache: dict, tokens: jax.Array,
                index: jax.Array):
    """One decode step.  tokens: [B]; index: int32 [B] per-slot positions (a
    scalar broadcasts — every row at the same position, the generational
    case).  With per-slot positions each batch row advances independently:
    its attention mask, RoPE angles, ring slot, and cache writes all derive
    from its own ``index[b]``, so a continuous-batching scheduler can refill
    finished rows mid-flight (see :func:`prefill_into_slot`).

    Rows whose position is out of cache range — or negative (``index[b] =
    -1``, the scheduler's dead/prefilling-row sentinel) — scatter-drop their
    KV and position writes, so idle slots can never pollute the ring.
    Returns (logits [B, V], new_cache).
    """
    B = tokens.shape[0]
    index = jnp.asarray(index, jnp.int32)
    if index.ndim == 0:
        index = jnp.broadcast_to(index, (B,))
    CL = cache["pos"].shape[-1] if "pos" in cache else 0
    slot = _ring_slot(cfg, CL, index)  # [B]; canonical p % CL ring slots
    positions = index[:, None]  # [B, 1] per-row query positions
    rows = jnp.arange(B)
    h = embed_tokens(p, cfg, tokens[:, None])

    if cfg.is_encdec:
        h = h + sinusoidal_position_at(index, cfg.d_model, h.dtype)[:, None]
        new_pos = cache["pos"].at[:, rows, slot].set(index)
        kpos = new_pos[0]  # [B, CL]
        enc_pos = jnp.arange(cfg.enc_seq)

        def body(x, xs):
            blk, ck, cv, crk, crv = xs
            a, (ck, cv) = attention(blk["self_attn"], rms_norm(blk["ln1"], x), cfg,
                                    positions=positions, k_positions=kpos,
                                    window=cfg.window,
                                    cache=(ck, cv), cache_index=slot, use_rope=False)
            x = x + a
            x = x + attention(blk["cross_attn"], rms_norm(blk["ln2"], x), cfg,
                              positions=positions, k_positions=enc_pos, kind="full",
                              kv=(crk, crv), use_rope=False)
            x = x + ffn(blk["ffn"], rms_norm(blk["ln3"], x), cfg)
            return x, (ck, cv)

        h, (ks, vs) = jax.lax.scan(
            body, h, (p["dec_blocks"], cache["k"], cache["v"],
                      cache["cross_k"], cache["cross_v"]))
        cache = dict(cache, k=ks, v=vs, pos=new_pos)

    elif cfg.block_pattern == "attn":
        new_pos = cache["pos"].at[:, rows, slot].set(index)
        kpos = new_pos[0]  # [B, CL]

        def block_step(x, blk, ck, cv, is_moe):
            hn = rms_norm(blk["ln1"], x, offset=cfg.rmsnorm_offset)
            a, (ck, cv) = attention(blk["attn"], hn, cfg, positions=positions,
                                    k_positions=kpos, window=cfg.window,
                                    cache=(ck, cv), cache_index=slot)
            x = x + a
            hn = rms_norm(blk["ln2"], x, offset=cfg.rmsnorm_offset)
            if is_moe:
                f, _ = moe_ffn(blk["moe"], hn, cfg)
            else:
                f = ffn(blk["ffn"], hn, cfg)
            return x + f, ck, cv

        if "dense_blocks" in p:  # interleaved MoE
            kk = cfg.moe_every
            groups = cfg.n_layers // kk
            dense = jax.tree.map(lambda t: t.reshape(groups, kk - 1, *t.shape[1:]),
                                 p["dense_blocks"])
            ckg = cache["k"].reshape(groups, kk, *cache["k"].shape[1:])
            cvg = cache["v"].reshape(groups, kk, *cache["v"].shape[1:])

            def group_body(x, xs):
                dblk, mblk, ck, cv = xs

                def dbody(xx, ys):
                    b, k1, v1 = ys
                    xx, k1, v1 = block_step(xx, b, k1, v1, False)
                    return xx, (k1, v1)

                x, (kd, vd) = jax.lax.scan(dbody, x, (dblk, ck[:kk - 1], cv[:kk - 1]))
                x, km, vm = block_step(x, mblk, ck[kk - 1], cv[kk - 1], True)
                return x, (jnp.concatenate([kd, km[None]], 0),
                           jnp.concatenate([vd, vm[None]], 0))

            h, (ks, vs) = jax.lax.scan(group_body, h,
                                       (dense, p["moe_blocks"], ckg, cvg))
            ks = ks.reshape(cache["k"].shape)
            vs = vs.reshape(cache["v"].shape)
        else:
            # Read-only cache in the layer loop: attend over the OLD cache
            # and merge the just-computed token as one extra online-softmax
            # chunk (layers.append_attention — shared with chunked prefill);
            # new k/v come out as tiny scan ys and are written with a
            # single batched DUS after the loop.  Mutating the carried cache
            # inside the loop makes XLA insert full-cache copies (+f32
            # mirrors on backends that upcast bf16 dots) — measured 17
            # GB/layer on gemma-7b decode_32k (EXPERIMENTS.md §Perf it.3).
            from repro.models.layers import append_attention
            old_pos = cache["pos"][0]  # [B, CL] pre-update positions (-1 = empty)

            def body(x, xs):
                blk, ck, cv = xs
                hn = rms_norm(blk["ln1"], x, offset=cfg.rmsnorm_offset)
                a, (k, v) = append_attention(blk["attn"], hn, cfg,
                                             positions=positions, cache_k=ck,
                                             cache_v=cv, k_positions=old_pos,
                                             window=cfg.window)
                x = x + a
                hn = rms_norm(blk["ln2"], x, offset=cfg.rmsnorm_offset)
                if cfg.n_experts:
                    f, _ = moe_ffn(blk["moe"], hn, cfg)
                else:
                    f = ffn(blk["ffn"], hn, cfg)
                return x + f, (k, v)

            h, (k_new, v_new) = jax.lax.scan(
                body, h, (p["blocks"], cache["k"], cache["v"]))
            # one batched in-place write: all layers' new tokens, each batch
            # row at its own `slot[b]` (scatter; out-of-range rows drop)
            ks = cache["k"].at[:, rows, slot].set(
                k_new[:, :, 0].astype(cache["k"].dtype))
            vs = cache["v"].at[:, rows, slot].set(
                v_new[:, :, 0].astype(cache["v"].dtype))
        cache = dict(cache, k=ks, v=vs, pos=new_pos)

    elif cfg.block_pattern == "zamba2":
        g = cfg.attn_every
        groups = cfg.n_layers // g
        new_pos = cache["pos"].at[:, rows, slot].set(index)
        kpos = new_pos[0]  # [B, CL]
        stacked = jax.tree.map(lambda x: x.reshape(groups, g, *x.shape[1:]),
                               p["mamba_blocks"])
        sst = cache["ssm"].reshape(groups, g, *cache["ssm"].shape[1:])
        cst = cache["conv"].reshape(groups, g, *cache["conv"].shape[1:])
        shared = p["shared_attn"]

        def mamba_body(x, xs):
            blk, st, cv = xs
            hn = rms_norm(blk["ln"], x)
            y, (st, cv) = ssm.mamba2_block(blk["mixer"], hn, cfg,
                                           state=st, conv_state=cv)
            return x + y, (st, cv)

        def group_body(x, xs):
            blks, st, cv, ck, cvv = xs
            x, (st, cv) = jax.lax.scan(mamba_body, x, (blks, st, cv))
            hn = rms_norm(shared["ln1"], x)
            a, (ck, cvv) = attention(shared["attn"], hn, cfg, positions=positions,
                                     k_positions=kpos, window=cfg.window,
                                     cache=(ck, cvv), cache_index=slot)
            x = x + a
            x = x + ffn(shared["ffn"], rms_norm(shared["ln2"], x), cfg)
            return x, (st, cv, ck, cvv)

        h, (st, cv, ks, vs) = jax.lax.scan(
            group_body, h, (stacked, sst, cst, cache["k"], cache["v"]))
        cache = dict(cache, ssm=st.reshape(cache["ssm"].shape),
                     conv=cv.reshape(cache["conv"].shape), k=ks, v=vs, pos=new_pos)

    elif cfg.block_pattern == "xlstm":
        def body(x, xs):
            mblk, sblk, C, n, m, sc, sn, sh, sm = xs
            y, (C, n, m) = xlstm.mlstm_block(mblk["cell"], rms_norm(mblk["ln"], x),
                                             cfg, state=(C, n, m), decode=True)
            x = x + y
            y, (sc, sn, sh, sm) = xlstm.slstm_block(
                sblk["cell"], rms_norm(sblk["ln"], x), cfg, state=(sc, sn, sh, sm))
            return x + y, (C, n, m, sc, sn, sh, sm)

        h, (C, n, m, sc, sn, sh, sm) = jax.lax.scan(
            body, h, (p["mlstm_blocks"], p["slstm_blocks"], cache["mC"],
                      cache["mn"], cache["mm"], cache["sc"], cache["sn"],
                      cache["sh"], cache["sm"]))
        cache = dict(cache, mC=C, mn=n, mm=m, sc=sc, sn=sn, sh=sh, sm=sm)
    else:
        raise ValueError(cfg.block_pattern)

    h = rms_norm(p["final_norm"], h, offset=cfg.rmsnorm_offset)
    logits = (h[:, 0] @ lm_head_w(p, cfg)).astype(jnp.float32)
    from repro.models.layers import mask_padded_vocab
    return mask_padded_vocab(logits, cfg.vocab_size), cache
