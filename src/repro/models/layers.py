"""Shared neural blocks: norms, RoPE, ternary-aware linears, GQA attention,
(Ge/Swi)GLU FFNs, MoE, and the chunked cross-entropy loss.

Every projection goes through :func:`linear`, which dispatches on the
parameter leaf structure:

  * ``{"w": [in, out]}``               — fp or QAT (BitNet STE) training path
  * ``{"packed": [out, in/5], "scale"}`` — 1.6-bit base-3 deployment path

so the same model code serves training (fake-quant master weights) and
serving (streamed packed ternary weights).
"""

from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.core.quantization import (fake_quant_acts, fake_quant_ternary,
                                     quantize_activations_int8)
from repro.models.config import ModelConfig

Params = dict[str, Any]


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------


def init_linear(key, d_in: int, d_out: int, *, bias: bool = False,
                dtype=jnp.bfloat16, scale: float | None = None,
                stack: tuple[int, ...] = ()) -> Params:
    scale = 1.0 / math.sqrt(d_in) if scale is None else scale
    p = {"w": jax.random.normal(key, (*stack, d_in, d_out), dtype) * scale}
    if bias:
        p["b"] = jnp.zeros((*stack, d_out), dtype)
    return p


def init_norm(d: int, *, dtype=jnp.bfloat16, stack: tuple[int, ...] = ()) -> Params:
    return {"g": jnp.ones((*stack, d), dtype)}


# ---------------------------------------------------------------------------
# primitive ops
# ---------------------------------------------------------------------------


def rms_norm(p: Params, x: jax.Array, *, offset: bool = False, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    g = p["g"].astype(jnp.float32)
    if offset:
        g = 1.0 + g
    return (x * g).astype(dt)


def linear(p: Params, x: jax.Array, cfg: ModelConfig, *, ternary: bool = True,
           role: str | None = None):
    """Apply a (possibly ternary) linear layer.  See module docstring.

    ``role`` is the projection's parameter-leaf name (``"wq"``, ``"wo"``,
    ...).  It only matters under a mesh (``dispatch.shard_scope``): the
    TP rules in :mod:`repro.parallel.sharding` are name-based, so the name
    is what tells dispatch which matmul dim is sharded on this device —
    global ``(K, N)`` alone is ambiguous (``wq`` and ``wo`` share a shape
    whenever ``q_dim == d_model`` but shard opposite dims)."""
    if "packed" in p:
        k = x.shape[-1]
        if p["packed"].ndim != 2:
            # stacked serving params are sliced per layer by lax.scan before
            # they reach linear(); per-expert stacks go via _expert_matmul
            raise NotImplementedError(
                f"linear() needs a per-layer [out, in/5] packed matrix, got "
                f"shape {p['packed'].shape}; slice the stacked dim first")
        # unified dispatch: the serving policy (cfg.matmul_policy, or
        # $REPRO_TERNARY_POLICY) picks the kernel per (shape, dtype,
        # backend) — autotune-cache best, cost-model prior, or a pin.
        from repro.kernels.dispatch import TernaryWeight, ternary_matmul

        tw = TernaryWeight.from_packed(p["packed"], p["scale"], k, mu=cfg.mu)
        if cfg.act_dtype == "int8" and jnp.issubdtype(x.dtype, jnp.floating):
            # W1.58A8: per-token absmax int8 quant in front of the packed
            # matmul; dispatch sees int8 and routes the w2a8/tl2 kernels.
            # The activation scale is the second rank-1 correction (the
            # weight scale is applied inside ternary_matmul).
            x_q, x_scale = quantize_activations_int8(x)
            y = ternary_matmul(x_q, tw, policy=cfg.matmul_policy, role=role)
            y = (y * x_scale).astype(x.dtype)
        else:
            y = ternary_matmul(x, tw, policy=cfg.matmul_policy, role=role)
    else:
        w = p["w"]
        if ternary and cfg.quant == "qat":
            w = fake_quant_ternary(w)
            if cfg.quantize_acts:
                x = fake_quant_acts(x)
        y = x @ w
    if "b" in p:
        y = y + p["b"].astype(y.dtype)
    return y


def rope(x: jax.Array, positions: jax.Array, theta: float):
    """Rotary embedding.  x: [B, S, H, hd]; positions: [B, S] or [S]."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = jnp.exp(-math.log(theta) * jnp.arange(0, half, dtype=jnp.float32) / half)
    if positions.ndim == 1:
        positions = positions[None, :]
    ang = positions[..., None].astype(jnp.float32) * freqs  # [B, S, half]
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def _act(name: str):
    return {"silu": jax.nn.silu, "gelu": lambda v: jax.nn.gelu(v, approximate=True)}[name]


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------


def init_attention(key, cfg: ModelConfig, *, stack=()) -> Params:
    ks = jax.random.split(key, 4)
    dt = jnp.bfloat16
    p = {
        "wq": init_linear(ks[0], cfg.d_model, cfg.q_dim, bias=cfg.qkv_bias, dtype=dt, stack=stack),
        "wk": init_linear(ks[1], cfg.d_model, cfg.kv_dim, bias=cfg.qkv_bias, dtype=dt, stack=stack),
        "wv": init_linear(ks[2], cfg.d_model, cfg.kv_dim, bias=cfg.qkv_bias, dtype=dt, stack=stack),
        "wo": init_linear(ks[3], cfg.q_dim, cfg.d_model, dtype=dt, stack=stack),
    }
    if cfg.qk_norm:
        p["q_norm"] = init_norm(cfg.head_dim, stack=stack)
        p["k_norm"] = init_norm(cfg.head_dim, stack=stack)
    return p


def _chunk_mask(qp: jax.Array, kp: jax.Array, kind: str, window: int):
    """[B?, qc, kc] bool validity from absolute positions (kp = -1 ⇒ empty
    slot).  qp/kp are [qc]/[kc] shared over the batch, or [B, qc]/[B, kc]
    per-slot (continuous batching: every batch row at its own position).

    ``kind``: "causal" (kp <= qp, optional sliding window), "causal_strict"
    (kp < qp — the cache half of a scatter-first exact verify, where the
    query's own key already sits in the cache and must come from the extra
    chunk instead), "self" (kp == qp — the matching extra chunk, each query
    attending only its own appended key), or "full" (cross-attention)."""
    if qp.ndim == 1:
        qp = qp[None]
    if kp.ndim == 1:
        kp = kp[None]
    valid = kp[:, None, :] >= 0
    if kind == "self":
        valid &= kp[:, None, :] == qp[:, :, None]
    elif kind == "causal_strict":
        valid &= kp[:, None, :] < qp[:, :, None]
    elif kind == "causal":
        valid &= kp[:, None, :] <= qp[:, :, None]
        if window:
            valid &= kp[:, None, :] > qp[:, :, None] - window
    return valid


def _sdpa(q, k, v, cfg: ModelConfig, *, q_pos, k_pos, kind: str = "causal",
          window: int = 0, chunk_q: int = 512, chunk_k: int = 1024,
          extra_kv=None, extra_kind: str | None = None):
    """Flash-style chunked attention with online softmax.

    q: [B,Sq,H,hd]; k/v: [B,Sk,Hkv,hd]; q_pos [Sq] or [B,Sq], k_pos [Sk] or
    [B,Sk] absolute positions (k_pos = -1 marks empty cache slots; batched
    forms give each row its own positions — per-slot continuous decode).
    Memory is
    O(B·H·chunk_q·chunk_k) instead of O(B·H·Sq·Sk) — required for the 32k/500k
    shapes to fit HBM; on real TPU this is where a fused flash kernel slots
    in.  ``kind``: "causal" (+optional sliding window) or "full" (cross-attn).
    """
    B, Sq, H, hd = q.shape
    Sk = k.shape[1]
    Hkv = k.shape[2]
    rep = H // Hkv
    q_pos = jnp.atleast_2d(jnp.asarray(q_pos))  # [1 or B, Sq]
    k_pos = jnp.atleast_2d(jnp.asarray(k_pos))  # [1 or B, Sk]
    cq, ck = min(chunk_q, Sq), min(chunk_k, Sk)
    pad_q, pad_k = (-Sq) % cq, (-Sk) % ck
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
        q_pos = jnp.pad(q_pos, ((0, 0), (0, pad_q)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        k_pos = jnp.pad(k_pos, ((0, 0), (0, pad_k)), constant_values=-1)
    nq, nk = (Sq + pad_q) // cq, (Sk + pad_k) // ck
    scale = 1.0 / math.sqrt(hd)

    # Chunks are taken with dynamic_slice on the *native* [B, S, H, hd]
    # layout.  A reshape(B, nk, ck, ...).transpose(...) formulation makes XLA
    # materialize a transposed copy of the whole K/V buffer (and on backends
    # without native bf16 dots, hoist a second full-size f32 upcast of it out
    # of the loop — measured +15 GB/step on the gemma-7b decode_32k cell, see
    # EXPERIMENTS.md §Perf).  Slicing keeps per-step traffic at one chunk.
    def q_chunk(_, qi):
        qb = jax.lax.dynamic_slice_in_dim(q, qi * cq, cq, axis=1)
        qp = jax.lax.dynamic_slice_in_dim(q_pos, qi * cq, cq, axis=1)
        qb = qb.reshape(B, cq, Hkv, rep, hd)

        def merge_chunk(carry, kb, vb, kp, mk=kind):
            m, l, acc = carry
            s = jnp.einsum("bqkrd,bskd->bkrqs", qb, kb).astype(jnp.float32) * scale
            valid = _chunk_mask(qp, kp, mk, window)  # [1 or B, cq, kc]
            s = jnp.where(valid[:, None, None], s, -1e30)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l = l * corr + p.sum(axis=-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bkrqs,bskd->bkrqd", p.astype(vb.dtype), vb).astype(jnp.float32)
            return (m_new, l, acc)

        def kv_step(carry, ki):
            kb = jax.lax.dynamic_slice_in_dim(k, ki * ck, ck, axis=1)
            vb = jax.lax.dynamic_slice_in_dim(v, ki * ck, ck, axis=1)
            kp = jax.lax.dynamic_slice_in_dim(k_pos, ki * ck, ck, axis=1)
            return merge_chunk(carry, kb, vb, kp), None

        init = (jnp.full((B, Hkv, rep, cq), -1e30, jnp.float32),
                jnp.zeros((B, Hkv, rep, cq), jnp.float32),
                jnp.zeros((B, Hkv, rep, cq, hd), jnp.float32))
        (m, l, acc), _ = jax.lax.scan(kv_step, init, jnp.arange(nk))
        if extra_kv is not None:
            # one more online-softmax chunk (decode: the token being written
            # this step, so the cache stays read-only inside the layer loop)
            k1, v1, p1 = extra_kv
            m, l, acc = merge_chunk((m, l, acc), k1.astype(qb.dtype),
                                    v1.astype(qb.dtype), p1,
                                    extra_kind or kind)
        out = acc / jnp.maximum(l, 1e-30)[..., None]  # [B, Hkv, rep, cq, hd]
        return None, out.transpose(0, 3, 1, 2, 4)      # [B, cq, Hkv, rep, hd]

    _, outs = jax.lax.scan(q_chunk, None, jnp.arange(nq))  # [nq, B, cq, ...]
    out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(B, Sq + pad_q, H, hd)
    return out[:, :Sq].reshape(B, Sq, H * hd).astype(v.dtype)


def append_attention(p: Params, x: jax.Array, cfg: ModelConfig, *,
                     positions: jax.Array, cache_k: jax.Array,
                     cache_v: jax.Array, k_positions: jax.Array,
                     window: int = 0, scatter_slots: jax.Array | None = None):
    """Attention over a READ-ONLY kv cache plus the tokens being appended.

    The decode/chunked-prefill form: q/k/v come from ``x`` (``Sq`` = 1 for
    single-token decode, = chunk size for chunked prefill), the cache is
    attended as-is with the new tokens merged as one extra online-softmax
    chunk, and the fresh ``(k, v)`` are returned for the caller to write at
    their ring slots *after* the layer loop.  Keeping the cache read-only
    inside the layer scan stops XLA inserting full-cache copies per layer
    (see the note in ``decode_step``).  Causality inside the appended chunk
    falls out of the absolute-position mask (``k_pos <= q_pos``), so one code
    path serves both uses.

    x: [B, Sq, D]; positions: [B, Sq] absolute; cache k/v: [B, CL, Hkv, hd];
    k_positions: [B, CL] slot positions (-1 = empty).  A ``-1`` query
    position matches no key, but its *output row is garbage* (a fully-masked
    online softmax degenerates to a uniform average over the scanned values)
    — callers must discard those rows (padded chunk tails are skipped by the
    logits ``take`` index; dead decode rows are masked by the scheduler) and
    its k/v must not be written back (its ring slot maps out of range).
    Returns (out [B, Sq, D], (k, v) [B, Sq, Hkv, hd]).

    ``scatter_slots`` ([B, Sq] ring slots, out-of-range drops) switches to
    the *scatter-first exact* form used by dense speculative verify: the
    chunk's fresh (k, v) are written into the cache BEFORE attending, the
    cache scan is masked strictly below each query (``kp < qp`` — so a
    query's earlier chunk-mates are attended from their ring slots, in ring
    order), and the extra chunk is masked to self-only (``kp == qp``).  Per
    query, the attended set, partition boundaries, and reduction order are
    then *identical* to ``Sq`` sequential single-token decode steps, making
    verify bitwise equal to sequential decode — the property the speculative
    scheduler's byte-identity guarantee rests on.  Only valid for dense
    (``window == 0``) caches whose slot is the position itself: on a wrapped
    ring the scatter would evict in-window keys that sequential decode at the
    earlier window positions still legitimately attends.  Returns
    (out [B, Sq, D], (cache_k, cache_v) post-scatter [B, CL, Hkv, hd]).
    """
    B, Sq, _ = x.shape
    q = linear(p["wq"], x, cfg, role="wq").reshape(B, Sq, cfg.n_heads, cfg.head_dim)
    k = linear(p["wk"], x, cfg, role="wk").reshape(B, Sq, cfg.n_kv_heads, cfg.head_dim)
    v = linear(p["wv"], x, cfg, role="wv").reshape(B, Sq, cfg.n_kv_heads, cfg.head_dim)
    if cfg.qk_norm:
        q = rms_norm(p["q_norm"], q)
        k = rms_norm(p["k_norm"], k)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    if scatter_slots is not None:
        if window:
            raise ValueError("scatter-first exact attention requires a "
                             "dense (window=0) cache")
        rows = jnp.arange(B)[:, None]
        cache_k = cache_k.at[rows, scatter_slots].set(k.astype(cache_k.dtype))
        cache_v = cache_v.at[rows, scatter_slots].set(v.astype(cache_v.dtype))
        o = _sdpa(q, cache_k.astype(q.dtype), cache_v.astype(q.dtype), cfg,
                  q_pos=positions, k_pos=k_positions, kind="causal_strict",
                  extra_kv=(k, v, positions), extra_kind="self")
        return linear(p["wo"], o, cfg, role="wo"), (cache_k, cache_v)
    o = _sdpa(q, cache_k.astype(q.dtype), cache_v.astype(q.dtype), cfg,
              q_pos=positions, k_pos=k_positions, window=window,
              extra_kv=(k, v, positions))
    return linear(p["wo"], o, cfg, role="wo"), (k, v)


def attention(p: Params, x: jax.Array, cfg: ModelConfig, *,
              positions: jax.Array, k_positions: jax.Array | None = None,
              kind: str = "causal", window: int = 0,
              kv: tuple[jax.Array, jax.Array] | None = None,
              cache: tuple[jax.Array, jax.Array] | None = None,
              cache_index: jax.Array | None = None,
              use_rope: bool = True, return_kv: bool = False):
    """GQA attention (chunked-softmax core).

    Training/prefill: ``kv=None, cache=None`` — keys/values from ``x``;
                      ``k_positions`` defaults to ``positions``.
    Cross-attention:  ``kv=(k, v)`` precomputed (whisper), ``kind="full"``.
    Decode:           ``cache=(k_cache, v_cache)`` updated at ``cache_index``
                      (a scalar writes all rows at one slot; an int32 [B]
                      vector writes each batch row at its own slot — the
                      continuous-batching per-slot form, Sq must be 1);
                      ``k_positions`` = cache slot positions (-1 = empty);
                      returns (out, new_cache).

    ``positions``: [Sq] absolute query positions shared over the batch, or
    [B, Sq] per-row (continuous decode).
    """
    B, Sq, _ = x.shape
    q = linear(p["wq"], x, cfg, role="wq").reshape(B, Sq, cfg.n_heads, cfg.head_dim)
    if cfg.qk_norm:
        q = rms_norm(p["q_norm"], q)
    if use_rope:
        q = rope(q, positions, cfg.rope_theta)

    new_cache = None
    if kv is not None:
        k, v = kv
    else:
        k = linear(p["wk"], x, cfg, role="wk").reshape(B, Sq, cfg.n_kv_heads, cfg.head_dim)
        v = linear(p["wv"], x, cfg, role="wv").reshape(B, Sq, cfg.n_kv_heads, cfg.head_dim)
        if cfg.qk_norm:
            k = rms_norm(p["k_norm"], k)
        if use_rope:
            k = rope(k, positions, cfg.rope_theta)
        if cache is not None:
            ck, cv = cache
            ci = jnp.asarray(cache_index)
            if ci.ndim:  # per-slot [B] write positions (continuous decode)
                rows = jnp.arange(B)
                ck = ck.at[rows, ci].set(k[:, 0].astype(ck.dtype))
                cv = cv.at[rows, ci].set(v[:, 0].astype(cv.dtype))
            else:
                ck = jax.lax.dynamic_update_slice(ck, k.astype(ck.dtype), (0, ci, 0, 0))
                cv = jax.lax.dynamic_update_slice(cv, v.astype(cv.dtype), (0, ci, 0, 0))
            k, v, new_cache = ck, cv, (ck, cv)

    if k_positions is None:
        k_positions = positions if cache is None else None
        assert k_positions is not None, "decode requires explicit k_positions"
    out = _sdpa(q, k.astype(q.dtype), v.astype(q.dtype), cfg,
                q_pos=positions, k_pos=k_positions, kind=kind, window=window)
    out = linear(p["wo"], out, cfg, role="wo")
    if return_kv:
        return out, (k, v)
    return (out, new_cache) if cache is not None else out


# ---------------------------------------------------------------------------
# FFN / MoE
# ---------------------------------------------------------------------------


def init_ffn(key, cfg: ModelConfig, *, stack=(), d_ff: int | None = None) -> Params:
    ks = jax.random.split(key, 3)
    f = d_ff or cfg.d_ff
    dt = jnp.bfloat16
    p = {
        "wi": init_linear(ks[0], cfg.d_model, f, dtype=dt, stack=stack),
        "wo": init_linear(ks[2], f, cfg.d_model, dtype=dt, stack=stack),
    }
    if cfg.ffn_gated:
        p["wg"] = init_linear(ks[1], cfg.d_model, f, dtype=dt, stack=stack)
    return p


def ffn(p: Params, x: jax.Array, cfg: ModelConfig):
    """Gated FFN (SwiGLU/GeGLU) or plain 2-layer MLP (whisper)."""
    if "wg" in p:
        h = _act(cfg.act_fn)(linear(p["wg"], x, cfg, role="wg")) \
            * linear(p["wi"], x, cfg, role="wi")
    else:
        h = _act(cfg.act_fn)(linear(p["wi"], x, cfg, role="wi"))
    return linear(p["wo"], h, cfg, role="wo")


def moe_capacity(cfg: ModelConfig, tokens: int) -> int:
    """Per-expert capacity ``C`` for a forward over ``tokens`` tokens — the
    static expert-buffer row count :func:`moe_ffn` allocates.  The single
    source of truth: the autotune shape universe
    (:func:`repro.models.decode.layer_grouped_matmul_shapes`) must enumerate
    exactly the capacities the forward dispatches."""
    return max(int(cfg.capacity_factor * tokens * cfg.experts_per_token
                   / cfg.n_experts), 1)


def init_moe(key, cfg: ModelConfig, *, stack=()) -> Params:
    ks = jax.random.split(key, 5)
    E, d, f = cfg.n_experts, cfg.d_model, cfg.d_ff
    dt = jnp.bfloat16
    s = 1.0 / math.sqrt(d)
    p = {
        "router": {"w": jax.random.normal(ks[0], (*stack, d, E), jnp.float32) * s},
        "wi": {"w": jax.random.normal(ks[1], (*stack, E, d, f), dt) * s},
        "wg": {"w": jax.random.normal(ks[2], (*stack, E, d, f), dt) * s},
        "wo": {"w": jax.random.normal(ks[3], (*stack, E, f, d), dt) * (1.0 / math.sqrt(f))},
    }
    if cfg.moe_shared_expert:
        p["shared"] = init_ffn(ks[4], cfg, stack=stack)
    return p


def _maybe_quant_expert(w, cfg: ModelConfig):
    """Per-expert fake-quant on stacked [E, din, dout] expert weights."""
    if cfg.quant == "qat":
        return fake_quant_ternary(w, axis=(-2, -1))
    return w


def _expert_matmul(leaf: Params, cfg: ModelConfig, d_in: int,
                   role: str | None = None):
    """Returns f: [E, C, d_in] → [E, C, d_out] for train ({"w"}) or packed
    ({"packed" [E, d_out, d_in/5], "scale" [E]}) expert weights.
    ``role`` names the expert leaf (``"wi"``/``"wg"``/``"wo"``) so mesh-mode
    dispatch (``dispatch.shard_scope``) can localize the EP/TP-sharded dims.

    The packed (serving) path goes through the unified dispatch layer's
    grouped entry point, so the expert stack streams as base-3 packed bytes
    end-to-end — never a dense ``[E, d_out, d_in]`` HBM temporary — and the
    serving policy (``cfg.matmul_policy`` / ``$REPRO_TERNARY_POLICY``)
    governs MoE matmuls exactly like the dense projections (``fixed:<dense>``
    pins resolve to the kernel's grouped variant).  The QAT/train path keeps
    the straight-through einsum over fake-quant master weights.
    """
    if "packed" in leaf:
        from repro.kernels.dispatch import (GroupedTernaryWeight,
                                            grouped_ternary_matmul)

        gw = GroupedTernaryWeight.from_packed(leaf["packed"], leaf["scale"],
                                              d_in, mu=cfg.mu)
        if cfg.act_dtype == "int8":
            # W1.58A8 expert path: quantize the post-dispatch expert inputs
            # per token (row) — the all-zero padding/sentinel rows of the
            # dispatch buffer quantize to zero codes with a finite scale, so
            # they stay inert.  Per-expert weight scale applies inside
            # grouped_ternary_matmul; the activation scale is rank-1 here.
            def run(t):
                t_q, t_scale = quantize_activations_int8(t)
                y = grouped_ternary_matmul(t_q, gw,
                                           policy=cfg.matmul_policy,
                                           role=role)
                return (y * t_scale).astype(t.dtype)

            return run
        return lambda t: grouped_ternary_matmul(t, gw,
                                                policy=cfg.matmul_policy,
                                                role=role)
    w = _maybe_quant_expert(leaf["w"], cfg)
    return lambda t: jnp.einsum("ecd,edf->ecf", t, w.astype(t.dtype))


def moe_ffn(p: Params, x: jax.Array, cfg: ModelConfig):
    """Top-k token-choice MoE with **sort-based dispatch** (scalable form).

    The textbook GShard one-hot dispatch costs O(T·E·cap) and detonates at
    T ≈ 1M tokens (the llama4 train_4k cell measured 12.9 TB/device of XLA
    temps).  This implementation sorts token-expert assignments and uses
    linear gather/scatter instead:

      sort (T·K ids) → per-expert slot via counts/offsets → scatter tokens
      into [E, cap, D] → batched expert matmuls → gather back with gates.

    All dispatch traffic is O(T·D); the EP all-to-all emerges from the
    scatter/gather when experts are sharded on the data axis.  Returns
    (out, aux_loss); router stays fp, experts ternary (QAT or packed).
    """
    B, S, D = x.shape
    T = B * S
    E, K = cfg.n_experts, cfg.experts_per_token
    xf = x.reshape(T, D)

    logits = (xf.astype(jnp.float32) @ p["router"]["w"]).astype(jnp.float32)  # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, K)  # [T, K]
    gate_vals = gate_vals / jnp.clip(gate_vals.sum(-1, keepdims=True), 1e-9)

    # load-balance aux loss (Switch): E * Σ_e f_e · p_e
    me = jnp.mean(probs, axis=0)
    ce = jnp.zeros((E,), jnp.float32).at[gate_idx.reshape(-1)].add(1.0) / (T * K)
    aux = E * jnp.sum(me * ce)

    cap = moe_capacity(cfg, T)
    flat_e = gate_idx.reshape(T * K)                                # [TK]
    order = jnp.argsort(flat_e, stable=True)                        # [TK]
    sorted_e = flat_e[order]
    tok_of = order // K
    counts = jnp.zeros((E,), jnp.int32).at[flat_e].add(1)
    start = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                             jnp.cumsum(counts)[:-1]])
    slot = jnp.arange(T * K, dtype=jnp.int32) - start[sorted_e]     # pos in expert
    keep = slot < cap
    # scatter into expert buffers; dropped tokens target the sentinel row
    flat_idx = jnp.where(keep, sorted_e * cap + slot, E * cap)
    buf = jnp.zeros((E * cap + 1, D), xf.dtype).at[flat_idx].set(
        xf[tok_of], mode="drop")
    disp = buf[:-1].reshape(E, cap, D)

    up_i = _expert_matmul(p["wi"], cfg, D, role="wi")
    up_g = _expert_matmul(p["wg"], cfg, D, role="wg")
    down = _expert_matmul(p["wo"], cfg, cfg.d_ff, role="wo")
    h = _act(cfg.act_fn)(up_g(disp)) * up_i(disp)
    eout = down(h).reshape(E * cap, D)                              # [E·cap, D]

    gathered = jnp.where(keep[:, None], eout[jnp.minimum(flat_idx, E * cap - 1)], 0)
    gates_sorted = gate_vals.reshape(T * K)[order].astype(xf.dtype)
    out = jnp.zeros((T, D), xf.dtype).at[tok_of].add(gathered * gates_sorted[:, None])
    out = out.reshape(B, S, D)
    if "shared" in p:
        out = out + ffn(p["shared"], x, cfg)
    return out, aux


# ---------------------------------------------------------------------------
# loss
# ---------------------------------------------------------------------------


def mask_padded_vocab(logits: jax.Array, vocab: int) -> jax.Array:
    """-inf out the vocab-padding tail (see ModelConfig.padded_vocab)."""
    if logits.shape[-1] == vocab:
        return logits
    iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
    return jnp.where(iota < vocab, logits, -1e30)


def chunked_ce_loss(x: jax.Array, head_w: jax.Array, labels: jax.Array,
                    mask: jax.Array, chunk: int, vocab: int | None = None):
    """Next-token CE without materializing [B, S, V] logits.

    Scans over sequence chunks; each chunk projects to the vocab, computes
    log-softmax CE, and is rematerialized in backward (jax.checkpoint), so
    peak memory is one [B, chunk, V] slab.
    """
    B, S, D = x.shape
    vocab = vocab or head_w.shape[-1]
    chunk = min(chunk, S)
    pad = (-S) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)))
        mask = jnp.pad(mask, ((0, 0), (0, pad)))
    nc = (S + pad) // chunk
    xc = x.reshape(B, nc, chunk, D).transpose(1, 0, 2, 3)
    yc = labels.reshape(B, nc, chunk).transpose(1, 0, 2)
    mc = mask.reshape(B, nc, chunk).transpose(1, 0, 2)

    @jax.checkpoint
    def body(carry, inp):
        xcb, ycb, mcb = inp
        logits = (xcb @ head_w).astype(jnp.float32)  # [B, chunk, Vpad]
        logits = mask_padded_vocab(logits, vocab)
        lse = jax.nn.logsumexp(logits, axis=-1)
        tgt = jnp.take_along_axis(logits, ycb[..., None], axis=-1)[..., 0]
        nll = (lse - tgt) * mcb
        return (carry[0] + nll.sum(), carry[1] + mcb.sum()), None

    (tot, cnt), _ = jax.lax.scan(body, (jnp.zeros((), jnp.float32),
                                        jnp.zeros((), jnp.float32)), (xc, yc, mc))
    return tot / jnp.clip(cnt, 1.0)
