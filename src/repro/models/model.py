"""Top-level model API: init / train forward / prefill / decode for every
assigned architecture family.

All families share one parameter layout convention — per-layer leaves stacked
on a leading layer axis and consumed by ``lax.scan`` (keeps HLO size constant
in depth; essential for compiling 60-layer × 512-device meshes). The paper's
ternary technique enters through ``layers.linear`` (QAT fake-quant in
training, packed 1.6-bit streaming at serving — see quantize_for_serving).

Families:
  * ``attn``   — dense / GQA / MoE decoder-only LMs (+ VLM prefix injection)
  * ``zamba2`` — Mamba2 backbone with a shared attention block every k layers
  * ``xlstm``  — alternating mLSTM / sLSTM blocks
  * enc-dec    — whisper (audio stub frontend + text decoder w/ cross-attn)
"""

from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import encoding
from repro.core.quantization import ternarize
from repro.models import ssm, xlstm
from repro.models.config import ModelConfig
from repro.models.layers import (
    attention,
    chunked_ce_loss,
    ffn,
    init_attention,
    init_ffn,
    init_moe,
    init_norm,
    linear,
    moe_ffn,
    rms_norm,
)

Params = dict[str, Any]


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def init_params(cfg: ModelConfig, key: jax.Array) -> Params:
    ks = iter(jax.random.split(key, 16))
    dt = jnp.bfloat16
    V = cfg.padded_vocab
    p: Params = {
        "embed": {"w": jax.random.normal(next(ks), (V, cfg.d_model), dt) * 0.02},
        "final_norm": init_norm(cfg.d_model),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = {"w": jax.random.normal(next(ks), (cfg.d_model, V), dt)
                        * (1.0 / math.sqrt(cfg.d_model))}

    if cfg.is_encdec:
        L, Le = cfg.n_layers, cfg.enc_layers
        p["enc_blocks"] = {
            "ln1": init_norm(cfg.d_model, stack=(Le,)),
            "attn": init_attention(next(ks), cfg, stack=(Le,)),
            "ln2": init_norm(cfg.d_model, stack=(Le,)),
            "ffn": init_ffn(next(ks), cfg, stack=(Le,)),
        }
        p["enc_norm"] = init_norm(cfg.d_model)
        p["dec_blocks"] = {
            "ln1": init_norm(cfg.d_model, stack=(L,)),
            "self_attn": init_attention(next(ks), cfg, stack=(L,)),
            "ln2": init_norm(cfg.d_model, stack=(L,)),
            "cross_attn": init_attention(next(ks), cfg, stack=(L,)),
            "ln3": init_norm(cfg.d_model, stack=(L,)),
            "ffn": init_ffn(next(ks), cfg, stack=(L,)),
        }
        return p

    if cfg.block_pattern == "attn":
        L = cfg.n_layers
        if cfg.n_experts and cfg.moe_every > 1:
            # interleaved: each group = (moe_every - 1) dense layers + 1 MoE
            Lm = L // cfg.moe_every
            Ld = L - Lm
            p["dense_blocks"] = {
                "ln1": init_norm(cfg.d_model, stack=(Ld,)),
                "attn": init_attention(next(ks), cfg, stack=(Ld,)),
                "ln2": init_norm(cfg.d_model, stack=(Ld,)),
                "ffn": init_ffn(next(ks), cfg, stack=(Ld,),
                                d_ff=cfg.dense_ff or cfg.d_ff),
            }
            p["moe_blocks"] = {
                "ln1": init_norm(cfg.d_model, stack=(Lm,)),
                "attn": init_attention(next(ks), cfg, stack=(Lm,)),
                "ln2": init_norm(cfg.d_model, stack=(Lm,)),
                "moe": init_moe(next(ks), cfg, stack=(Lm,)),
            }
            return p
        blocks = {
            "ln1": init_norm(cfg.d_model, stack=(L,)),
            "attn": init_attention(next(ks), cfg, stack=(L,)),
            "ln2": init_norm(cfg.d_model, stack=(L,)),
        }
        if cfg.n_experts:
            blocks["moe"] = init_moe(next(ks), cfg, stack=(L,))
        else:
            blocks["ffn"] = init_ffn(next(ks), cfg, stack=(L,))
        p["blocks"] = blocks
    elif cfg.block_pattern == "zamba2":
        L = cfg.n_layers
        p["mamba_blocks"] = {
            "ln": init_norm(cfg.d_model, stack=(L,)),
            "mixer": ssm.init_mamba2(next(ks), cfg, stack=(L,)),
        }
        p["shared_attn"] = {
            "ln1": init_norm(cfg.d_model),
            "attn": init_attention(next(ks), cfg),
            "ln2": init_norm(cfg.d_model),
            "ffn": init_ffn(next(ks), cfg),
        }
    elif cfg.block_pattern == "xlstm":
        half = cfg.n_layers // 2
        p["mlstm_blocks"] = {
            "ln": init_norm(cfg.d_model, stack=(half,)),
            "cell": xlstm.init_mlstm(next(ks), cfg, stack=(half,)),
        }
        p["slstm_blocks"] = {
            "ln": init_norm(cfg.d_model, stack=(half,)),
            "cell": xlstm.init_slstm(next(ks), cfg, stack=(half,)),
        }
    else:
        raise ValueError(cfg.block_pattern)
    return p


def lm_head_w(p: Params, cfg: ModelConfig):
    return p["embed"]["w"].T if cfg.tie_embeddings else p["lm_head"]["w"]


# ---------------------------------------------------------------------------
# embedding / frontends
# ---------------------------------------------------------------------------


def embed_tokens(p: Params, cfg: ModelConfig, tokens: jax.Array,
                 vision_embeds: jax.Array | None = None) -> jax.Array:
    h = p["embed"]["w"][tokens]  # [B, S, D]
    if cfg.embed_scale:
        h = h * jnp.asarray(math.sqrt(cfg.d_model), h.dtype)
    if vision_embeds is not None and cfg.vision_tokens:
        h = jax.lax.dynamic_update_slice(
            h, vision_embeds.astype(h.dtype), (0, 0, 0))
    return h


def sinusoidal_position_at(index: jax.Array, D: int, dtype=jnp.bfloat16) -> jax.Array:
    """Sinusoidal embedding at traced position(s): scalar → [D], [B] → [B, D]
    (decode path; the batched form carries per-slot positions)."""
    idx = jnp.asarray(index, jnp.float32)
    div = jnp.exp(-math.log(10_000.0) * jnp.arange(0, D, 2, jnp.float32) / D)
    ang = idx[..., None] * div
    pe = jnp.zeros((*idx.shape, D), jnp.float32)
    pe = pe.at[..., 0::2].set(jnp.sin(ang))
    pe = pe.at[..., 1::2].set(jnp.cos(ang))
    return pe.astype(dtype)


def sinusoidal_positions(S: int, D: int, dtype=jnp.bfloat16) -> jax.Array:
    pos = jnp.arange(S, dtype=jnp.float32)[:, None]
    div = jnp.exp(-math.log(10_000.0) * jnp.arange(0, D, 2, jnp.float32) / D)
    pe = jnp.zeros((S, D), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(pos * div))
    pe = pe.at[:, 1::2].set(jnp.cos(pos * div))
    return pe.astype(dtype)


# ---------------------------------------------------------------------------
# forward (training / prefill trunk)
# ---------------------------------------------------------------------------


def _maybe_remat(fn, cfg: ModelConfig):
    return jax.checkpoint(fn) if cfg.remat else fn


def _attn_block(blk, x, cfg: ModelConfig, positions, window, *, is_moe: bool):
    hn = rms_norm(blk["ln1"], x, offset=cfg.rmsnorm_offset)
    x = x + attention(blk["attn"], hn, cfg, positions=positions, window=window)
    hn = rms_norm(blk["ln2"], x, offset=cfg.rmsnorm_offset)
    if is_moe:
        f, aux = moe_ffn(blk["moe"], hn, cfg)
    else:
        f, aux = ffn(blk["ffn"], hn, cfg), jnp.zeros((), jnp.float32)
    return x + f, aux


def _attn_trunk(p, cfg: ModelConfig, h, positions, window):
    zero = jnp.zeros((), jnp.float32)

    if "dense_blocks" in p:  # interleaved MoE (llama4)
        k = cfg.moe_every
        groups = cfg.n_layers // k
        dense = jax.tree.map(lambda t: t.reshape(groups, k - 1, *t.shape[1:]),
                             p["dense_blocks"])

        def dense_body(carry, blk):
            x, aux = carry
            x, a = _attn_block(blk, x, cfg, positions, window, is_moe=False)
            return (x, aux + a), None

        def group_body(carry, blks):
            dblk, mblk = blks
            carry, _ = jax.lax.scan(_maybe_remat(dense_body, cfg), carry, dblk)
            x, aux = carry
            x, a = _attn_block(mblk, x, cfg, positions, window, is_moe=True)
            return (x, aux + a), None

        # remat at the group level too: without it every group's MoE
        # dispatch buffers stay live for backward (measured 586 GB/device on
        # llama4 train_4k — see EXPERIMENTS.md §Perf iteration 2).
        (h, aux), _ = jax.lax.scan(_maybe_remat(group_body, cfg), (h, zero),
                                   (dense, p["moe_blocks"]))
        return h, aux

    def body(carry, blk):
        x, aux = carry
        x, a = _attn_block(blk, x, cfg, positions, window, is_moe=bool(cfg.n_experts))
        return (x, aux + a), None

    (h, aux), _ = jax.lax.scan(_maybe_remat(body, cfg), (h, zero), p["blocks"])
    return h, aux


def _zamba2_trunk(p, cfg: ModelConfig, h, positions, window):
    g = cfg.attn_every
    groups = cfg.n_layers // g
    stacked = jax.tree.map(
        lambda x: x.reshape(groups, g, *x.shape[1:]), p["mamba_blocks"])
    shared = p["shared_attn"]

    def mamba_body(x, blk):
        hn = rms_norm(blk["ln"], x)
        y, _ = ssm.mamba2_block(blk["mixer"], hn, cfg)
        return x + y, None

    def group_body(x, blks):
        x, _ = jax.lax.scan(_maybe_remat(mamba_body, cfg), x, blks)
        hn = rms_norm(shared["ln1"], x)
        x = x + attention(shared["attn"], hn, cfg, positions=positions, window=window)
        x = x + ffn(shared["ffn"], rms_norm(shared["ln2"], x), cfg)
        return x, None

    h, _ = jax.lax.scan(_maybe_remat(group_body, cfg), h, stacked)
    return h, jnp.zeros((), jnp.float32)


def _xlstm_trunk(p, cfg: ModelConfig, h):
    def body(x, blks):
        mblk, sblk = blks
        y, _ = xlstm.mlstm_block(mblk["cell"], rms_norm(mblk["ln"], x), cfg)
        x = x + y
        y, _ = xlstm.slstm_block(sblk["cell"], rms_norm(sblk["ln"], x), cfg)
        return x + y, None

    h, _ = jax.lax.scan(_maybe_remat(body, cfg), h,
                        (p["mlstm_blocks"], p["slstm_blocks"]))
    return h, jnp.zeros((), jnp.float32)


def _whisper_encode(p, cfg: ModelConfig, frames: jax.Array):
    """frames: [B, enc_seq, D] precomputed stub embeddings (conv frontend is
    a stub per the assignment)."""
    S = frames.shape[1]
    h = frames + sinusoidal_positions(S, cfg.d_model, frames.dtype)[None]
    positions = jnp.arange(S)

    def body(x, blk):
        hn = rms_norm(blk["ln1"], x)
        x = x + attention(blk["attn"], hn, cfg, positions=positions, kind="full",
                          use_rope=False)
        x = x + ffn(blk["ffn"], rms_norm(blk["ln2"], x), cfg)
        return x, None

    h, _ = jax.lax.scan(_maybe_remat(body, cfg), h, p["enc_blocks"])
    return rms_norm(p["enc_norm"], h)


def _whisper_dec_trunk(p, cfg: ModelConfig, h, enc_out, positions):
    S = h.shape[1]
    enc_pos = jnp.arange(enc_out.shape[1])

    def body(x, blk):
        x = x + attention(blk["self_attn"], rms_norm(blk["ln1"], x), cfg,
                          positions=positions, use_rope=False)
        k = linear(blk["cross_attn"]["wk"], enc_out, cfg, role="wk")
        v = linear(blk["cross_attn"]["wv"], enc_out, cfg, role="wv")
        B, Se = enc_out.shape[:2]
        kv = (k.reshape(B, Se, cfg.n_kv_heads, cfg.head_dim),
              v.reshape(B, Se, cfg.n_kv_heads, cfg.head_dim))
        x = x + attention(blk["cross_attn"], rms_norm(blk["ln2"], x), cfg,
                          positions=positions, k_positions=enc_pos, kind="full",
                          kv=kv, use_rope=False)
        x = x + ffn(blk["ffn"], rms_norm(blk["ln3"], x), cfg)
        return x, None

    h, _ = jax.lax.scan(_maybe_remat(body, cfg), h, p["dec_blocks"])
    return h


def forward(p: Params, cfg: ModelConfig, batch: dict, *, window: int | None = None):
    """Training/prefill trunk → (hidden [B,S,D], aux_loss)."""
    tokens = batch["tokens"]
    S = tokens.shape[1]
    positions = jnp.arange(S)
    win = cfg.window if window is None else window

    if cfg.is_encdec:
        enc_out = _whisper_encode(p, cfg, batch["frames"])
        h = embed_tokens(p, cfg, tokens)
        h = h + sinusoidal_positions(S, cfg.d_model, h.dtype)[None]
        h = _whisper_dec_trunk(p, cfg, h, enc_out, positions)
        aux = jnp.zeros((), jnp.float32)
    else:
        h = embed_tokens(p, cfg, tokens, batch.get("vision_embeds"))
        if cfg.block_pattern == "attn":
            h, aux = _attn_trunk(p, cfg, h, positions, win)
        elif cfg.block_pattern == "zamba2":
            h, aux = _zamba2_trunk(p, cfg, h, positions, win)
        elif cfg.block_pattern == "xlstm":
            h, aux = _xlstm_trunk(p, cfg, h)
        else:
            raise ValueError(cfg.block_pattern)
    return rms_norm(p["final_norm"], h, offset=cfg.rmsnorm_offset), aux


def train_loss(p: Params, cfg: ModelConfig, batch: dict):
    """Next-token CE (+ MoE aux).  batch: tokens, labels, loss_mask [+frontends]."""
    h, aux = forward(p, cfg, batch)
    loss = chunked_ce_loss(h, lm_head_w(p, cfg), batch["labels"],
                           batch["loss_mask"].astype(jnp.float32),
                           cfg.loss_chunk, vocab=cfg.vocab_size)
    total = loss + 0.01 * aux
    return total, {"ce": loss, "aux": aux}
