"""Mamba2 (SSD) blocks for the zamba2 hybrid architecture.

Training uses the chunked SSD formulation (intra-chunk attention-like matmuls
+ a ``lax.scan`` over chunk states) so the recurrence is O(S) with
MXU-friendly inner contractions; decode is the O(1) single-step state update.

Simplifications vs the reference CUDA implementation (documented in
DESIGN.md): separate z/x/B/C/dt projections instead of one fused in_proj
(numerically equivalent modulo init), n_groups = 1.  The ternary technique
applies to the large in/out projections; the small B/C/dt projections, conv,
and gates stay fp — mirroring BitNet practice of keeping sub-1% parameter
tensors in high precision.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import Params, init_linear, init_norm, linear, rms_norm


def ssm_dims(cfg: ModelConfig):
    d_in = cfg.ssm_expand * cfg.d_model
    n_heads = d_in // cfg.ssm_head_dim
    return d_in, n_heads, cfg.ssm_state


def init_mamba2(key, cfg: ModelConfig, *, stack=()) -> Params:
    d_in, H, S = ssm_dims(cfg)
    ks = jax.random.split(key, 8)
    dt = jnp.bfloat16
    conv_ch = d_in + 2 * S
    return {
        "wz": init_linear(ks[0], cfg.d_model, d_in, dtype=dt, stack=stack),
        "wx": init_linear(ks[1], cfg.d_model, d_in, dtype=dt, stack=stack),
        "wB": init_linear(ks[2], cfg.d_model, S, dtype=dt, stack=stack),
        "wC": init_linear(ks[3], cfg.d_model, S, dtype=dt, stack=stack),
        "wdt": init_linear(ks[4], cfg.d_model, H, dtype=dt, stack=stack),
        "conv": jax.random.normal(ks[5], (*stack, cfg.ssm_conv, conv_ch), dt) * 0.1,
        "A_log": jnp.zeros((*stack, H), jnp.float32),
        "D": jnp.ones((*stack, H), jnp.float32),
        "dt_bias": jnp.full((*stack, H), -2.0, jnp.float32),
        "norm": init_norm(d_in, stack=stack),
        "wo": init_linear(ks[6], d_in, cfg.d_model, dtype=dt, stack=stack),
    }


def _causal_conv(x: jax.Array, kernel: jax.Array, state: jax.Array | None = None):
    """Depthwise causal conv.  x: [B, S, C]; kernel: [K, C].

    With ``state`` [B, K-1, C] (decode), returns (y, new_state)."""
    K = kernel.shape[0]
    if state is not None:
        xin = jnp.concatenate([state.astype(x.dtype), x], axis=1)  # [B, K-1+S, C]
        new_state = xin[:, -(K - 1):]
    else:
        xin = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
        new_state = None
    y = sum(xin[:, i:i + x.shape[1]] * kernel[i] for i in range(K))
    y = jax.nn.silu(y)
    return (y, new_state) if state is not None else y


def _ssd_chunked(u, B_in, C_in, log_a, chunk: int, h0=None):
    """Chunked scalar-decay SSD scan.

    u:     [B, S, H, P]  (dt-scaled inputs)
    B_in:  [B, S, N]     input projections (shared across heads, n_groups=1)
    C_in:  [B, S, N]     output projections
    log_a: [B, S, H]     per-step log decays (<= 0)
    h0:    optional [B, H, N, P] initial state.

    Returns (y [B, S, H, P], h_final [B, H, N, P]).
    """
    Bb, S, H, P = u.shape
    N = B_in.shape[-1]
    chunk = min(chunk, S)
    pad = (-S) % chunk
    if pad:  # pad with zero input and zero decay (a=1 keeps state unchanged)
        u = jnp.pad(u, ((0, 0), (0, pad), (0, 0), (0, 0)))
        B_in = jnp.pad(B_in, ((0, 0), (0, pad), (0, 0)))
        C_in = jnp.pad(C_in, ((0, 0), (0, pad), (0, 0)))
        log_a = jnp.pad(log_a, ((0, 0), (0, pad), (0, 0)))
    nc = (S + pad) // chunk

    def resh(t):
        return t.reshape(Bb, nc, chunk, *t.shape[2:]).swapaxes(0, 1)

    uc, Bc, Cc, lac = map(resh, (u, B_in, C_in, log_a))  # leading nc

    h_init = jnp.zeros((Bb, H, N, P), jnp.float32) if h0 is None else h0.astype(jnp.float32)

    def body(h, inp):
        ucb, Bcb, Ccb, lacb = inp  # [B, Q, ...]
        cs = jnp.cumsum(lacb, axis=1)                      # [B, Q, H] Σ_{j<=i}
        total = cs[:, -1]                                  # [B, H]
        # intra-chunk: scores[i, j] = (C_i·B_j)·exp(cs_i - cs_j), j <= i
        scores = jnp.einsum("bin,bjn->bij", Ccb.astype(jnp.float32),
                            Bcb.astype(jnp.float32))
        decay = cs[:, :, None, :] - cs[:, None, :, :]       # [B, i, j, H]
        iota = jnp.arange(ucb.shape[1])
        causal = (iota[:, None] >= iota[None, :])[None, :, :, None]
        gate = jnp.where(causal, jnp.exp(decay), 0.0)
        y_intra = jnp.einsum("bij,bijh,bjhp->bihp", scores, gate,
                             ucb.astype(jnp.float32))
        # inter-chunk: y_i += C_i · h_prev · exp(cs_i)
        y_inter = jnp.einsum("bin,bhnp,bih->bihp", Ccb.astype(jnp.float32), h,
                             jnp.exp(cs))
        # state update: h = exp(total)·h + Σ_j exp(total - cs_j) B_j u_j
        carry_in = jnp.einsum("bjn,bjhp,bjh->bhnp", Bcb.astype(jnp.float32),
                              ucb.astype(jnp.float32),
                              jnp.exp(total[:, None] - cs))
        h_new = jnp.exp(total)[:, :, None, None] * h + carry_in
        return h_new, y_intra + y_inter

    h_fin, yc = jax.lax.scan(body, h_init, (uc, Bc, Cc, lac))
    y = yc.swapaxes(0, 1).reshape(Bb, S + pad, H, P)[:, :S]
    return y, h_fin


def mamba2_block(p: Params, x: jax.Array, cfg: ModelConfig, *,
                 state=None, conv_state=None, chunk: int = 128):
    """Mamba2 mixer.  x: [B, S, D].

    Training/prefill: state=None → full chunked SSD, returns (y, (h, conv)).
    Decode: pass (state [B,H,N,P], conv_state [B,K-1,C]) with S == 1.
    """
    Bb, S, _ = x.shape
    d_in, H, N = ssm_dims(cfg)
    P = cfg.ssm_head_dim

    z = linear(p["wz"], x, cfg, role="wz")
    xs = linear(p["wx"], x, cfg, role="wx")
    Bi = linear(p["wB"], x, cfg, ternary=False)
    Ci = linear(p["wC"], x, cfg, ternary=False)
    dt = linear(p["wdt"], x, cfg, ternary=False).astype(jnp.float32)

    conv_in = jnp.concatenate([xs, Bi, Ci], axis=-1)
    if conv_state is not None:
        conv_out, new_conv = _causal_conv(conv_in, p["conv"], conv_state)
    else:
        conv_out = _causal_conv(conv_in, p["conv"])
        new_conv = conv_in[:, -(cfg.ssm_conv - 1):] if S >= cfg.ssm_conv - 1 else None
    xs, Bi, Ci = (conv_out[..., :d_in], conv_out[..., d_in:d_in + N],
                  conv_out[..., d_in + N:])

    dt = jax.nn.softplus(dt + p["dt_bias"])                  # [B, S, H]
    a = -jnp.exp(p["A_log"])                                 # [H]
    log_a = dt * a                                           # [B, S, H]
    u = (xs.reshape(Bb, S, H, P).astype(jnp.float32)) * dt[..., None]

    if state is None:
        y, h_fin = _ssd_chunked(u, Bi, Ci, log_a, chunk)
    else:
        # single-step recurrence (S == 1)
        a_t = jnp.exp(log_a[:, 0])                           # [B, H]
        h_fin = a_t[:, :, None, None] * state.astype(jnp.float32) + \
            jnp.einsum("bn,bhp->bhnp", Bi[:, 0].astype(jnp.float32), u[:, 0])
        y = jnp.einsum("bn,bhnp->bhp", Ci[:, 0].astype(jnp.float32), h_fin)[:, None]

    # D skip connection on the (conv'd, un-scaled) inputs, per head
    y = y + xs.reshape(Bb, S, H, P).astype(jnp.float32) * p["D"][None, None, :, None]
    y = y.reshape(Bb, S, d_in).astype(x.dtype)
    y = rms_norm(p["norm"], y * jax.nn.silu(z))
    y = linear(p["wo"], y, cfg, role="wo")
    return y, (h_fin, new_conv)
