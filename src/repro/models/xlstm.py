"""xLSTM blocks (sLSTM + mLSTM) for the xlstm-125m architecture.

* **mLSTM** — matrix-memory cell, linear-attention-like, O(1) decode state
  ``(C [B,H,dk,dv], n [B,H,dk], m [B,H])``.  Training uses a chunked scan
  (like SSD) with exponential-gate stabilization carried across chunks:
  states are rescaled by ``exp(m_old - m_new)`` whenever the running
  stabilizer advances — the standard log-space trick from the paper's
  appendix, applied per chunk instead of per step.
* **sLSTM** — scalar-memory cell with recurrent gate weights; inherently
  sequential, implemented as a ``lax.scan`` over time (cheap: elementwise +
  one [B,D]×[D,4D] matmul per step).

Simplifications vs the reference implementation (DESIGN.md): no causal conv
front on q/k, block-diagonal recurrent matrices realized as a single dense
[D, 4D] (an over-parameterization, structurally equivalent for cost
purposes).  Ternary quantization applies to the up/down projections and
q/k/v maps; gates/recurrent weights stay fp.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import Params, init_linear, init_norm, linear, rms_norm


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------


def mlstm_dims(cfg: ModelConfig):
    d_in = 2 * cfg.d_model
    H = cfg.n_heads
    dk = d_in // H
    return d_in, H, dk


def init_mlstm(key, cfg: ModelConfig, *, stack=()) -> Params:
    d_in, H, dk = mlstm_dims(cfg)
    ks = jax.random.split(key, 8)
    dt = jnp.bfloat16
    return {
        "up": init_linear(ks[0], cfg.d_model, 2 * d_in, dtype=dt, stack=stack),
        "wq": init_linear(ks[1], d_in, d_in, dtype=dt, stack=stack),
        "wk": init_linear(ks[2], d_in, d_in, dtype=dt, stack=stack),
        "wv": init_linear(ks[3], d_in, d_in, dtype=dt, stack=stack),
        "wif": init_linear(ks[4], d_in, 2 * H, dtype=jnp.float32, stack=stack),
        "norm": init_norm(d_in, stack=stack),
        "down": init_linear(ks[5], d_in, cfg.d_model, dtype=dt, stack=stack),
    }


def _mlstm_chunked(q, k, v, log_f, log_i, chunk: int, state=None):
    """Chunk-parallel mLSTM.  q/k/v: [B,S,H,dk|dv]; log_f/log_i: [B,S,H]."""
    B, S, H, dk = q.shape
    dv = v.shape[-1]
    chunk = min(chunk, S)
    pad = (-S) % chunk
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        log_f = jnp.pad(log_f, ((0, 0), (0, pad), (0, 0)))          # log f = 0 ⇒ keep
        log_i = jnp.pad(log_i, ((0, 0), (0, pad), (0, 0)), constant_values=-1e30)
    nc = (S + pad) // chunk

    def resh(t):
        return t.reshape(B, nc, chunk, *t.shape[2:]).swapaxes(0, 1)

    qc, kc, vc, lfc, lic = map(resh, (q, k, v, log_f, log_i))

    if state is None:
        C0 = jnp.zeros((B, H, dk, dv), jnp.float32)
        n0 = jnp.zeros((B, H, dk), jnp.float32)
        m0 = jnp.full((B, H), -1e30, jnp.float32)
    else:
        C0, n0, m0 = state

    def body(carry, inp):
        C, n, m = carry
        qb, kb, vb, lf, li = inp                            # [B,Q,...]
        qf = qb.astype(jnp.float32)
        kf = kb.astype(jnp.float32)
        vf = vb.astype(jnp.float32)
        csf = jnp.cumsum(lf, axis=1)                        # [B,Q,H] Σ_{j<=i} log f
        total_f = csf[:, -1]                                # [B,H]

        # pairwise log-weights within the chunk: w[i,j] = li_j + csf_i - csf_j
        w_ij = li[:, None, :, :] + csf[:, :, None, :] - csf[:, None, :, :]
        iota = jnp.arange(qb.shape[1])
        causal = (iota[:, None] >= iota[None, :])[None, :, :, None]
        w_ij = jnp.where(causal, w_ij, -1e30)
        # per-position stabilizer: carries the previous running max m
        m_pos = jnp.maximum(m[:, None] + csf, jnp.max(w_ij, axis=2))  # [B,i,H]
        Dm = jnp.exp(w_ij - m_pos[:, :, None, :])           # stabilized gate matrix

        scores = jnp.einsum("bihd,bjhd->bijh", qf, kf) * Dm
        y_num = jnp.einsum("bijh,bjhv->bihv", scores, vf)
        n_i = jnp.einsum("bijh,bjhd->bihd", Dm, kf)         # key normalizer (intra)

        carry_scale = jnp.exp(m[:, None] + csf - m_pos)     # [B,i,H]
        y_num = y_num + jnp.einsum("bihd,bhdv->bihv", qf, C) * carry_scale[..., None]
        n_i = n_i + n[:, None] * carry_scale[..., None]

        den = jnp.abs(jnp.einsum("bihd,bihd->bih", qf, n_i))
        y = y_num / jnp.maximum(den, jnp.exp(-m_pos))[..., None]

        # ---- state update to end of chunk ----
        intra_w = li + (total_f[:, None] - csf)             # [B,Q,H]
        m_new = jnp.maximum(m + total_f, jnp.max(intra_w, axis=1))
        scale_old = jnp.exp(m + total_f - m_new)            # [B,H]
        wj = jnp.exp(intra_w - m_new[:, None])              # [B,Q,H]
        C_new = C * scale_old[:, :, None, None] + \
            jnp.einsum("bjh,bjhd,bjhv->bhdv", wj, kf, vf)
        n_new = n * scale_old[:, :, None] + jnp.einsum("bjh,bjhd->bhd", wj, kf)
        return (C_new, n_new, m_new), y

    (Cf, nf, mf), yc = jax.lax.scan(body, (C0, n0, m0), (qc, kc, vc, lfc, lic))
    y = yc.swapaxes(0, 1).reshape(B, S + pad, H, dv)[:, :S]
    return y, (Cf, nf, mf)


def mlstm_block(p: Params, x: jax.Array, cfg: ModelConfig, *, state=None,
                chunk: int = 128, decode: bool = False):
    """x: [B, S, D] → (y, new_state)."""
    B, S, _ = x.shape
    d_in, H, dk = mlstm_dims(cfg)
    up = linear(p["up"], x, cfg, role="up")
    xi, z = up[..., :d_in], up[..., d_in:]
    q = linear(p["wq"], xi, cfg, role="wq").reshape(B, S, H, dk) / (dk ** 0.5)
    k = linear(p["wk"], xi, cfg, role="wk").reshape(B, S, H, dk)
    v = linear(p["wv"], xi, cfg, role="wv").reshape(B, S, H, dk)
    gates = linear(p["wif"], xi, cfg, ternary=False).astype(jnp.float32)
    log_i = gates[..., :H]                                   # exp input gate (log-dom)
    log_f = jax.nn.log_sigmoid(gates[..., H:])               # sigmoid forget gate

    if decode:
        C, n, m = state
        m_new = jnp.maximum(log_f[:, 0] + m, log_i[:, 0])
        i_s = jnp.exp(log_i[:, 0] - m_new)
        f_s = jnp.exp(log_f[:, 0] + m - m_new)
        q0, k0, v0 = q[:, 0].astype(jnp.float32), k[:, 0].astype(jnp.float32), v[:, 0].astype(jnp.float32)
        C_new = f_s[:, :, None, None] * C + i_s[:, :, None, None] * \
            jnp.einsum("bhd,bhv->bhdv", k0, v0)
        n_new = f_s[:, :, None] * n + i_s[:, :, None] * k0
        num = jnp.einsum("bhd,bhdv->bhv", q0, C_new)
        den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", q0, n_new)),
                          jnp.exp(-m_new))
        y = (num / den[..., None])[:, None]                  # [B,1,H,dv]
        new_state = (C_new, n_new, m_new)
    else:
        y, new_state = _mlstm_chunked(q, k, v, log_f, log_i, chunk, state)

    y = y.reshape(B, S, d_in).astype(x.dtype) * jax.nn.silu(z)
    y = rms_norm(p["norm"], y)
    return linear(p["down"], y, cfg, role="down"), new_state


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------


def init_slstm(key, cfg: ModelConfig, *, stack=()) -> Params:
    d = cfg.d_model
    ks = jax.random.split(key, 4)
    up = int(d * 4 / 3)
    return {
        "gates_x": init_linear(ks[0], d, 4 * d, dtype=jnp.float32, stack=stack),
        "gates_h": init_linear(ks[1], d, 4 * d, dtype=jnp.float32, scale=0.02, stack=stack),
        "ffn_up": init_linear(ks[2], d, 2 * up, dtype=jnp.bfloat16, stack=stack),
        "ffn_down": init_linear(ks[3], up, d, dtype=jnp.bfloat16, stack=stack),
        "norm": init_norm(d, stack=stack),
    }


def slstm_scan(p: Params, x: jax.Array, cfg: ModelConfig, state=None,
               time_chunk: int = 64):
    """Sequential sLSTM cell.  x: [B, S, D] → (h_seq, state).

    state = (c, n, h, m), each [B, D] (heads share the layout; the recurrent
    matrix realizes the per-head block structure densely).

    Training memory: a naive scan saves every per-step carry for backward
    (4096 steps × [B, D] f32 × layers ≈ tens of GB/device at train_4k).  We
    checkpoint over *time chunks*: only every ``time_chunk``-th carry is
    stored; backward recomputes inside each chunk — the classic O(√S)
    gradient-checkpointing trade, applied along time.
    """
    B, S, D = x.shape
    if state is None:
        z = jnp.zeros((B, D), jnp.float32)
        state = (z, z + 1e-6, z, z - 1e30)
    gx = (x.astype(jnp.float32) @ p["gates_x"]["w"])         # [B, S, 4D]

    def step(carry, gxt):
        c, n, h, m = carry
        g = gxt + h @ p["gates_h"]["w"]
        zt, it, ft, ot = jnp.split(g, 4, axis=-1)
        zt = jnp.tanh(zt)
        ot = jax.nn.sigmoid(ot)
        log_f = jax.nn.log_sigmoid(ft)
        m_new = jnp.maximum(log_f + m, it)
        i_s = jnp.exp(it - m_new)
        f_s = jnp.exp(log_f + m - m_new)
        c_new = f_s * c + i_s * zt
        n_new = f_s * n + i_s
        h_new = ot * c_new / jnp.maximum(n_new, jnp.exp(-m_new))
        return (c_new, n_new, h_new, m_new), h_new

    cs = min(time_chunk, S)
    pad = (-S) % cs
    if pad:
        gx = jnp.pad(gx, ((0, 0), (0, pad), (0, 0)))
    nc = (S + pad) // cs
    gxc = gx.reshape(B, nc, cs, 4 * D).transpose(1, 2, 0, 3)  # [nc, cs, B, 4D]

    @jax.checkpoint
    def chunk(carry, gxb):
        return jax.lax.scan(step, carry, gxb)

    state, hs = jax.lax.scan(chunk, state, gxc)               # hs [nc, cs, B, D]
    hs = hs.transpose(2, 0, 1, 3).reshape(B, S + pad, D)[:, :S]
    return hs.astype(x.dtype), state


def slstm_block(p: Params, x: jax.Array, cfg: ModelConfig, *, state=None):
    h, new_state = slstm_scan(p, x, cfg, state)
    h = rms_norm(p["norm"], h)
    up = linear(p["ffn_up"], h, cfg, role="ffn_up")
    a, b = jnp.split(up, 2, axis=-1)
    y = linear(p["ffn_down"], jax.nn.gelu(a, approximate=True) * b, cfg,
               role="ffn_down")
    return y, new_state
