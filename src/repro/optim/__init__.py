"""repro.optim subsystem."""
