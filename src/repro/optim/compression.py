"""INT8 gradient compression with error feedback (distributed-optimization
trick for bandwidth-bound all-reduce at 1000+ nodes).

The quantize→all-reduce→dequantize cycle runs *inside* the jitted train step:
gradients are quantized per-leaf to int8 with a per-leaf fp32 scale before
the data-parallel mean, and the quantization residual is carried to the next
step (error feedback keeps the scheme unbiased over time).  At 512 chips the
gradient all-reduce bytes drop 4× vs fp32 / 2× vs bf16.

This mirrors the paper's bandwidth thesis on the *training* side: when links,
not FLOPs, bound the step time, narrower numbers win.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp


def compress_leaf(g: jax.Array, err: jax.Array):
    """(grad + carried error) → (int8 payload, scale, new error)."""
    g32 = g.astype(jnp.float32) + err
    scale = jnp.clip(jnp.max(jnp.abs(g32)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale
    return q, scale, g32 - deq


def decompress_leaf(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def init_error_state(params: Any):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress_grads(grads: Any, err_state: Any):
    """Pytree version.  Returns (payload tree of (q, scale), new error)."""
    out = jax.tree.map(compress_leaf, grads, err_state)
    q = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    s = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    e = jax.tree.map(lambda t: t[2], out, is_leaf=lambda t: isinstance(t, tuple))
    return (q, s), e


def decompress_grads(payload) -> Any:
    q, s = payload
    return jax.tree.map(decompress_leaf, q, s)


def roundtrip(grads: Any, err_state: Any):
    """One compress→decompress cycle (what the all-reduce carries)."""
    payload, err = compress_grads(grads, err_state)
    return decompress_grads(payload), err
