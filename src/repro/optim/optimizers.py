"""Optimizers for ternary QAT at scale: AdamW and Adafactor, plus schedules.

Functional (init/update) API with pytree states — no external deps.  Large
archs (yi-34b, phi3.5-moe, llama4-maverick) default to **Adafactor** so the
optimizer state fits the per-device HBM budget at 512 chips (DESIGN.md §4):
factored second moments store O(rows + cols) instead of O(rows × cols), and
no first moment is kept.  This is one of the framework's
distributed-optimization levers; the other is gradient compression
(optim/compression.py).
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp

Params = Any


@dataclass(frozen=True)
class Optimizer:
    init: Callable[[Params], Any]
    update: Callable[[Any, Params, Params, jax.Array], tuple[Params, Any]]
    #: (param_specs, param_shapedtypes) → opt-state PartitionSpec tree, used
    #: by the dry-run/train launchers to place state without compiling init.
    state_specs: Callable[[Any, Any], Any] = None
    name: str = ""


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm: float):
    n = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (n + 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype),
                        grads), n


def cosine_schedule(base_lr: float, warmup: int, total: int):
    def lr(step):
        step = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
        warm = base_lr * jnp.minimum(1.0, (step + 1) / max(warmup, 1))
        t = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        return jnp.where(step < warmup, warm,
                         base_lr * 0.5 * (1 + jnp.cos(math.pi * t)))
    return lr


def adamw(lr_fn, b1=0.9, b2=0.95, eps=1e-8, weight_decay=0.1) -> Optimizer:
    """AdamW with fp32 master weights kept implicitly in the m/v moments'
    precision (params stay bf16; update is computed in fp32)."""

    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {"m": jax.tree.map(zeros, params),
                "v": jax.tree.map(zeros, params),
                "count": jnp.zeros((), jnp.int32)}

    def update(state, grads, params, step):
        c = state["count"] + 1
        lr = lr_fn(step)

        def upd(m, v, g, p):
            g = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * g * g
            mh = m / (1 - b1 ** c.astype(jnp.float32))
            vh = v / (1 - b2 ** c.astype(jnp.float32))
            delta = mh / (jnp.sqrt(vh) + eps) + weight_decay * p.astype(jnp.float32)
            return m, v, (p.astype(jnp.float32) - lr * delta).astype(p.dtype)

        out = jax.tree.map(upd, state["m"], state["v"], grads, params)
        m = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
        v = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
        new_p = jax.tree.map(lambda t: t[2], out, is_leaf=lambda t: isinstance(t, tuple))
        return new_p, {"m": m, "v": v, "count": c}

    def state_specs(param_specs, param_sds):
        from jax.sharding import PartitionSpec as P
        return {"m": param_specs, "v": param_specs, "count": P()}

    return Optimizer(init=init, update=update, state_specs=state_specs, name="adamw")


def adafactor(lr_fn, eps=1e-30, clip_threshold=1.0, decay=0.8,
              weight_decay=0.0) -> Optimizer:
    """Adafactor (factored second moments, no first moment) — O(rows+cols)
    state for matrices, exact RMS for vectors/scalars."""

    def _factored(p):
        return p.ndim >= 2

    def init(params):
        def st(p):
            if _factored(p):
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros((*p.shape[:-2], p.shape[-1]), jnp.float32)}
            return {"v": jnp.zeros(p.shape, jnp.float32)}
        return {"s": jax.tree.map(st, params,
                                  is_leaf=lambda x: not isinstance(x, dict)),
                "count": jnp.zeros((), jnp.int32)}

    def update(state, grads, params, step):
        c = state["count"] + 1
        beta = 1.0 - (c.astype(jnp.float32)) ** (-decay)
        lr = lr_fn(step)

        def upd(s, g, p):
            g = g.astype(jnp.float32)
            g2 = g * g + eps
            if _factored(p):
                vr = beta * s["vr"] + (1 - beta) * g2.mean(axis=-1)
                vc = beta * s["vc"] + (1 - beta) * g2.mean(axis=-2)
                denom = (vr[..., None] / jnp.clip(vr.mean(-1)[..., None, None], eps)) \
                    * vc[..., None, :]
                u = g * jax.lax.rsqrt(jnp.clip(denom, eps))
                ns = {"vr": vr, "vc": vc}
            else:
                v = beta * s["v"] + (1 - beta) * g2
                u = g * jax.lax.rsqrt(jnp.clip(v, eps))
                ns = {"v": v}
            rms = jnp.sqrt(jnp.mean(u * u) + eps)
            u = u / jnp.maximum(1.0, rms / clip_threshold)
            newp = p.astype(jnp.float32) - lr * (u + weight_decay * p.astype(jnp.float32))
            return ns, newp.astype(p.dtype)

        out = jax.tree.map(upd, state["s"], grads, params,
                           is_leaf=lambda x: isinstance(x, dict) and
                           ("vr" in x or "v" in x))
        s = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
        new_p = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
        return new_p, {"s": s, "count": c}

    def state_specs(param_specs, param_sds):
        from jax.sharding import PartitionSpec as P

        def st(spec, sds):
            dims = list(spec) + [None] * (sds.ndim - len(spec))
            if sds.ndim >= 2:
                return {"vr": P(*dims[:-1]), "vc": P(*(dims[:-2] + [dims[-1]]))}
            return {"v": P(*dims)}

        return {"s": jax.tree.map(st, param_specs, param_sds,
                                  is_leaf=lambda x: isinstance(x, P)),
                "count": P()}

    return Optimizer(init=init, update=update, state_specs=state_specs,
                     name="adafactor")


def make_optimizer(name: str, base_lr: float = 3e-4, warmup: int = 100,
                   total: int = 10_000) -> Optimizer:
    lr_fn = cosine_schedule(base_lr, warmup, total)
    if name == "adamw":
        return adamw(lr_fn)
    if name == "adafactor":
        return adafactor(lr_fn)
    raise ValueError(name)
