"""repro.parallel subsystem."""
