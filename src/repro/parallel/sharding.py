"""Logical sharding rules: parameter/optimizer/activation PartitionSpecs.

Meshes (launch/mesh.py):
  single-pod  (16, 16)    axes ("data", "model")
  multi-pod   (2, 16, 16) axes ("pod", "data", "model")

Policy (DESIGN.md §4):
  * batch  → ("pod", "data")          (DP spans pods)
  * TP     → "model" on head/FFN/vocab dims
  * EP     → MoE expert dim on "data" (replicated across pods), TP inside
  * layer-stack leading axes unsharded (consumed by lax.scan)
  * non-divisible dims (yi-34b 56 heads / 16) rely on GSPMD padding

Rules are name-based over the parameter tree paths, so any new module gets
sane defaults (replicated) until a rule says otherwise.
"""

from __future__ import annotations

from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

#: leaf keys whose last ("out") dim is tensor-parallel
_OUT_MODEL = {"wq", "wk", "wv", "wi", "wg", "up", "wz", "wx", "ffn_up"}
#: leaf keys for the d_model-output ("in") projections.  Their *dense* "w"
#: leaves shard the contraction (din) dim — classic row-parallel TP with an
#: f32 partial-sum all-reduce.  Their *packed* leaves shard the dout dim
#: (column-parallel) instead: the packed byte axis is decoded by
#: ``unpack_base3(·, k)``, whose slice-at-logical-K over a byte-sharded
#: array computes wrong values at some shard widths under GSPMD (observed:
#: 0.5+ absolute logit error on the dense oracle at model=8), and dout
#: sharding is also *exact* — every device computes complete output columns,
#: so there is no partial-sum reduce to reorder at all.
_IN_MODEL = {"wo", "down", "ffn_down"}

#: out-projections that are numerically unsafe to TP at all under partial
#: replication (a combined data×model mesh): mamba2's gate projection
#: ``wz`` feeds a plain elementwise ``y * silu(z)`` — nothing slices it, so
#: the head/segment gates don't fire — yet its model-sharded output
#: miscompiles on CPU SPMD exactly when *both* a batch axis and the model
#: axis are >1 (observed: 0.4–1.0 absolute prefill-logit error on zamba2 at
#: 2x4/4x2, bit-exact at 1x8).  Same partial-replication miscompile class
#: as the rope slice bug the head gate works around, so: replicate these
#: whenever batch axes coexist with model parallelism.
_NO_TP_ROLES = {"wz"}

#: public aliases — the dispatch layer (repro.kernels.dispatch.ShardInfo)
#: resolves which matmul dim a projection role shards from these, so the
#: per-shard autotune keys stay in lock-step with the parameter rules above
TP_OUT_ROLES = frozenset(_OUT_MODEL)
TP_IN_ROLES = frozenset(_IN_MODEL)


def batch_axes(mesh: Mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def batch_spec(mesh: Mesh, extra_dims: int = 1) -> P:
    """[B, ...] activations: batch over pod+data, rest replicated."""
    return P(batch_axes(mesh), *([None] * extra_dims))


#: projection leaves whose out dim is reshaped to ``(heads, head_dim)`` and
#: then *sliced within a head* downstream (rope's rotate-half) — model-
#: sharding them is only safe at whole-head granularity.  Maps the leaf
#: name to the ``heads=`` key the caller supplies (wk/wv share kv heads).
_HEAD_ROLES = {"wq": "wq", "wk": "wk", "wv": "wk"}

#: projection leaves whose out dim is *sliced at fixed boundaries*
#: downstream — the same hazard as mid-head attention slices, but with
#: architecture-constant geometry, so the gate needs no ``heads=`` plumbing.
#: Values are the segment count the slice assumes: xlstm's GLU-style
#: two-way splits (slstm ``ffn_up``, mlstm ``up``) slice in half, and
#: mamba2's ``wx`` output is one indivisible segment of the causal-conv
#: concat (``[xs | B | C]``, B/C replicated) sliced back apart after the
#: conv — TP-splitting it shears the concat/slice boundaries across shards
#: (observed: diverging greedy streams on zamba2 at model=4).  Sharding is
#: allowed only when whole segments land on shards (count % model == 0),
#: mirroring the attention head gate.
_SPLIT_ROLES = {"ffn_up": 2, "up": 2, "wx": 1}


def _param_spec(path: tuple[str, ...], ndim: int, mesh: Mesh,
                tied_embed: bool = False, heads=None) -> P:
    names = set(path)
    leaf = path[-1]
    parent = path[-2] if len(path) >= 2 else ""
    has_data = "data" in mesh.axis_names

    def pad(spec_tail: list):
        """Right-align the spec against ndim (stack axes lead, unsharded)."""
        lead = ndim - len(spec_tail)
        return P(*([None] * lead + spec_tail))

    def head_safe(role: str) -> bool:
        """True when model-sharding ``role``'s out dim lands on whole
        heads/segments.

        Splitting *inside* a head or slice segment is both wrong-by-design
        for TP (rope / per-head ops then need intra-head collectives) and,
        on this jax version, numerically broken under partial replication
        (a combined data×model mesh) — the reshape-to-heads + rotate-half
        slice of a mid-head-sharded tensor miscompiles on CPU SPMD, and the
        split/concat sites in ``_SPLIT_ROLES`` diverge the same way.  The
        attention gate needs caller-supplied ``heads`` geometry (legacy
        flat-dim sharding stands without it); the split gate is always on.
        """
        if role in _NO_TP_ROLES:
            # partial-replication gate: TP only on a pure-model mesh
            batch = 1
            for a in ("pod", "data"):
                batch *= mesh.shape.get(a, 1)
            return batch == 1
        seg = _SPLIT_ROLES.get(role)
        if seg is not None:
            # split gate: always on (the segment count is an architectural
            # constant, not caller-supplied geometry)
            return seg % mesh.shape["model"] == 0
        key = _HEAD_ROLES.get(role)
        if heads is None or key is None or key not in heads:
            return True
        return heads[key] % mesh.shape["model"] == 0

    # Embedding table: d_model-sharded normally; **vocab-sharded when tied**.
    # A tied head (logits = x @ embed.T) with a d_model-sharded table puts the
    # TP axis on the contraction dim → XLA all-reduces full f32 logits per
    # loss chunk (measured 131 GB/step on gemma-7b train_4k — EXPERIMENTS.md
    # §Perf cell 4).  Vocab sharding keeps logits vocab-sharded (tiny
    # logsumexp all-reduce) at the cost of one [B,S,D] all-reduce in the
    # token-embedding gather.
    if "embed" in names:
        if ndim != 2:
            return P()
        return P("model", None) if tied_embed else P(None, "model")
    if "lm_head" in names:
        return P(None, "model") if ndim == 2 else P()

    # Router before the expert rule: its weight is [L?, d_model, E] — NOT an
    # expert stack — and must stay replicated (matching "moe"+"w" in the
    # expert branch would EP-shard its d_model dim).
    if "router" in names:
        return P()
    # MoE experts: [L?, E, din, dout] — EP on data, TP inside expert
    if "moe" in names and ndim >= 3 and leaf in ("w", "packed"):
        ep = "data" if has_data else None
        if leaf == "w":
            tail = [ep, None, "model"] if parent in ("wi", "wg") else [ep, "model", None]
        else:  # packed [L?, E, dout, din/5]
            tail = [ep, "model", None] if parent in ("wi", "wg") else [ep, None, "model"]
        return pad(tail)

    if leaf == "b":  # biases follow their matrix's out dim
        if parent in _OUT_MODEL and head_safe(parent):
            return pad(["model"])
        return P()
    if leaf == "w":
        if parent in _OUT_MODEL and ndim >= 2 and head_safe(parent):
            return pad([None, "model"])
        if parent in _IN_MODEL and ndim >= 2:
            return pad(["model", None])
        return P()
    if leaf == "packed":  # [..., dout, din/5]
        if parent in _OUT_MODEL and ndim >= 2 and head_safe(parent):
            return pad(["model", None])
        if parent in _IN_MODEL and ndim >= 2:
            # column-parallel for packed in-projections: shard dout, NOT the
            # packed byte dim (see the _IN_MODEL rationale above) — each
            # device holds whole packed rows and emits complete d_model
            # columns, so the unpack slice sees full byte rows and no
            # partial-sum all-reduce exists to introduce reduce-order drift
            return pad(["model", None])
        return P()
    # norms, scales, gates, conv, A_log, dt_bias, ... replicated
    return P()


def _path_names(path) -> tuple[str, ...]:
    out = []
    for k in path:
        if hasattr(k, "key"):
            out.append(str(k.key))
        elif hasattr(k, "idx"):
            out.append(str(k.idx))
    return tuple(out)


def _validate(spec: P, shape, mesh: Mesh) -> P:
    """Drop any axis whose shard count does not divide the dim exactly —
    jax.jit input shardings require even chunks.  Non-divisible dims (e.g.
    yi-34b's 56 heads on a 16-way axis) fall back to replication on that dim;
    internal GSPMD propagation may still shard them with padding.

    A spec *longer* than the array's rank is a rule/shape mismatch, not a
    divisibility concern — silently truncating it would shard the wrong dims
    (or none), so it raises."""
    if len(spec) > len(shape):
        raise ValueError(
            f"PartitionSpec {spec} has {len(spec)} axes but the array has "
            f"rank {len(shape)} (shape {tuple(shape)}); sharding rules must "
            f"not exceed the array's rank")
    dims = list(spec) + [None] * (len(shape) - len(spec))
    out = []
    for size, axes in zip(shape, dims):
        if axes is None:
            out.append(None)
            continue
        shards = 1
        for a in (axes if isinstance(axes, tuple) else (axes,)):
            shards *= mesh.shape[a]
        out.append(axes if size % shards == 0 else None)
    return P(*out)


def param_specs(params: Any, mesh: Mesh, *, heads=None):
    """Pytree of PartitionSpec mirroring ``params``.

    ``heads`` (optional) supplies head geometry — ``{"wq": n_heads,
    "wk": n_kv_heads}`` — so attention projections are model-sharded only at
    whole-head granularity (MQA/GQA kv projections replicate when the head
    count does not divide the model axis)."""
    tied = isinstance(params, dict) and "embed" in params and \
        "lm_head" not in params
    return jax.tree_util.tree_map_with_path(
        lambda path, x: _validate(
            _param_spec(_path_names(path), getattr(x, "ndim", 0), mesh,
                        tied_embed=tied, heads=heads),
            getattr(x, "shape", ()), mesh),
        params)


def param_shardings(params: Any, mesh: Mesh, *, heads=None):
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        param_specs(params, mesh, heads=heads))


def cache_specs(cache: Any, mesh: Mesh, *, kv_heads: int | None = None):
    """KV/state caches.  By default KV is sharded on head_dim (not kv-heads:
    GQA kv=8 doesn't divide a 16-way model axis); SSM states on their
    (large) head dim; batch over pod+data when divisible.

    With ``kv_heads`` given (the serving engine passes ``cfg.n_kv_heads``),
    KV shards the *head* dim instead — whole heads only, falling back to
    replication when the head count doesn't divide the model axis — matching
    the head-gated parameter rule (``param_specs(heads=...)``): attention
    reads the cache through per-head ops (rope-rotated q against it, online-
    softmax per head), and a mid-head-sharded layout both forces intra-head
    collectives and miscompiles on CPU SPMD under partial replication."""
    ba = batch_axes(mesh)

    def spec(path, x):
        names = _path_names(path)
        nd = x.ndim
        leaf = names[-1] if names else ""
        if leaf in ("k", "v", "cross_k", "cross_v") and nd == 5:
            if kv_heads is not None:               # [L, B, S, Hkv, hd]
                s = P(None, ba, None, "model", None)
            else:
                s = P(None, ba, None, None, "model")
        elif leaf == "pos":
            s = P()
        elif leaf == "ssm" and nd == 5:            # [L, B, H, N, P]
            # replicated, not head-sharded: the mamba2 block's projections
            # are replicated on combined meshes (wx is segment-gated, wz is
            # in _NO_TP_ROLES), so a model-sharded state pins a per-step
            # reshard of replicated compute — and that resharding hits the
            # same CPU SPMD partial-replication miscompile (observed:
            # diverging zamba2 decode streams at 2x4 with everything else
            # exact).  Memory cost is modest: the state is [H, N, P] per
            # slot, far smaller than a KV cache over max_len.
            s = P(None, ba, None, None, None)
        elif leaf == "conv" and nd == 4:           # [L, B, K-1, C]
            # channels are the [xs | B | C] causal-conv concat, sliced back
            # apart at fixed boundaries each step — model-sharding them
            # shears the slices across shards exactly like the gated ``wx``
            # projection that feeds it (see _SPLIT_ROLES), so they replicate
            s = P(None, ba, None, None)
        elif leaf == "mC" and nd == 5:             # [half, B, H, dk, dv]
            s = P(None, ba, None, "model", None)
        elif leaf == "mn" and nd == 4:
            s = P(None, ba, None, "model")
        elif leaf == "mm" and nd == 3:
            s = P(None, ba, None)
        elif leaf in ("sc", "sn", "sh", "sm") and nd == 3:
            s = P(None, ba, "model")
        elif nd >= 2:
            s = P(None, ba)
        else:
            s = P()
        return _validate(s, x.shape, mesh)

    return jax.tree_util.tree_map_with_path(spec, cache)


def block_slab_specs(slab: Any, mesh: Mesh, *, kv_heads: int | None = None):
    """Prefix-cache KV block slabs (``repro.serving.prefix_cache``):
    ``{"k": [L, C, Hkv, hd], "v": [L, C, Hkv, hd]}`` — the single-row cache
    leaves of :func:`cache_specs` minus the batch dim, sharded with the SAME
    kv-head rule so the engine's jitted extract/splice move no bytes between
    the admission cache layout and the stored slab: whole kv-heads on
    ``model`` when ``kv_heads`` is given and divides the axis, else the
    head_dim (legacy) or replication."""

    def spec(x):
        nd = getattr(x, "ndim", 0)
        if nd == 4:                                # [L, C, Hkv, hd]
            if kv_heads is not None:
                s = P(None, None, "model", None)
            else:
                s = P(None, None, None, "model")
        else:
            s = P()
        return _validate(s, getattr(x, "shape", ()), mesh)

    return jax.tree.map(spec, slab)


def batch_specs(batch: Any, mesh: Mesh):
    """Input batches: shard dim 0 (batch) over pod+data when divisible
    (long_500k has global_batch=1 → replicated; the data axis idles, which is
    the correct execution for that workload)."""
    ba = batch_axes(mesh)

    def spec(x):
        nd = getattr(x, "ndim", 0)
        if nd == 0:
            return P()
        return _validate(P(ba, *([None] * (nd - 1))), x.shape, mesh)

    return jax.tree.map(spec, batch)


def engine_state_specs(state: Any, mesh: Mesh, *, kv_heads: int | None = None):
    """Serving-engine scheduler state (``DecodeEngine.sched_start``):
    the KV/state ``cache`` through :func:`cache_specs`, every per-slot
    control vector (``logits``/``live``/``index``/``remaining``/``stop``)
    batch-sharded on dim 0 when divisible — the layout the mesh-mode
    engine pins on its jitted admit-commit / sched-step entry points.
    A speculative draft cache (``dcache``) replicates whole: the draft
    model runs replicated (params and KV alike — it is small by
    construction), matching the engine's draft ``ShardInfo(model=1)``."""
    control = {k: v for k, v in state.items()
               if k not in ("cache", "dcache")}
    specs = batch_specs(control, mesh)
    specs["cache"] = cache_specs(state["cache"], mesh, kv_heads=kv_heads)
    if "dcache" in state:
        specs["dcache"] = jax.tree.map(lambda _: P(), state["dcache"])
    return specs


def to_shardings(tree_specs: Any, mesh: Mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_specs,
                        is_leaf=lambda s: isinstance(s, P))
