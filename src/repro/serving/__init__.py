"""repro.serving subsystem."""
