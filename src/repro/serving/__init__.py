"""repro.serving subsystem: the batched decode engine, the
continuous-batching scheduler that drives it, and the hashed shared-prefix
KV block store admission reuses."""

from repro.serving.engine import DecodeEngine, Request, SamplerConfig
from repro.serving.prefix_cache import PrefixBlockStore, PrefixStoreStats
from repro.serving.scheduler import ContinuousScheduler, ScheduleBackend

__all__ = ["DecodeEngine", "Request", "SamplerConfig", "ContinuousScheduler",
           "ScheduleBackend", "PrefixBlockStore", "PrefixStoreStats"]
