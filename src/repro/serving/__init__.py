"""repro.serving subsystem: the batched decode engine and the
continuous-batching scheduler that drives it."""

from repro.serving.engine import DecodeEngine, Request, SamplerConfig
from repro.serving.scheduler import ContinuousScheduler, ScheduleBackend

__all__ = ["DecodeEngine", "Request", "SamplerConfig", "ContinuousScheduler",
           "ScheduleBackend"]
