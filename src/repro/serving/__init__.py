"""repro.serving subsystem: the batched decode engine, the
continuous-batching scheduler that drives it, the hashed shared-prefix
KV block store admission reuses, and the workload/load-generation layer
that measures it all under multi-tenant traffic."""

from repro.serving.engine import DecodeEngine, Request, SamplerConfig
from repro.serving.loadgen import (ArrivalEvent, LoadGenerator, LoadResult,
                                   RequestRecord, generate_trace,
                                   latency_summary, percentile)
from repro.serving.prefix_cache import PrefixBlockStore, PrefixStoreStats
from repro.serving.scheduler import ContinuousScheduler, ScheduleBackend
from repro.serving.workload import (SCENARIOS, ArrivalProcess, Dist,
                                    Scenario, TenantSpec, get_scenario)

__all__ = ["DecodeEngine", "Request", "SamplerConfig", "ContinuousScheduler",
           "ScheduleBackend", "PrefixBlockStore", "PrefixStoreStats",
           "Dist", "ArrivalProcess", "TenantSpec", "Scenario", "SCENARIOS",
           "get_scenario", "ArrivalEvent", "RequestRecord", "LoadResult",
           "LoadGenerator", "generate_trace", "percentile",
           "latency_summary"]
