"""Batched serving engine over packed-ternary weights.

The deployment story the paper targets: weights live in HBM at 1.6 bits each
(``quantize_for_serving``), prefill builds the KV/state caches, and the
decode loop streams packed weights through the dequant path every step —
memory-bound, which is exactly where the 10× weight-byte reduction pays.

The engine adds the serving substrate around the model's decode_step:
  * request batching with left-padded prompts of unequal length,
  * greedy / temperature / top-k sampling,
  * per-step token callbacks (streaming) and stop-token handling,
  * continuous-batching slot reuse (a finished request's slot is refilled
    by the next queued prompt at its prefill length).
"""

from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.models.decode import decode_step, prefill


@dataclass
class SamplerConfig:
    temperature: float = 0.0  # 0 → greedy
    top_k: int = 0
    seed: int = 0


def sample_tokens(logits: jax.Array, cfg: SamplerConfig, key) -> jax.Array:
    if cfg.temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = logits / cfg.temperature
    if cfg.top_k:
        kth = jax.lax.top_k(logits, cfg.top_k)[0][..., -1:]
        logits = jnp.where(logits < kth, -1e30, logits)
    return jax.random.categorical(key, logits).astype(jnp.int32)


@dataclass
class Request:
    prompt: list[int]
    max_new_tokens: int = 32
    stop_token: int | None = None
    out: list[int] = field(default_factory=list)
    done: bool = False


class DecodeEngine:
    def __init__(self, params, cfg: ModelConfig, *, batch_size: int,
                 max_len: int, sampler: SamplerConfig | None = None,
                 matmul_policy: str | None = None):
        """``matmul_policy`` overrides ``cfg.matmul_policy`` for every ternary
        projection this engine executes ("auto" | "prior" | "fixed:<kernel>",
        see :mod:`repro.kernels.dispatch`).  Kernel selection happens once,
        at trace time of the jitted prefill/decode step."""
        if matmul_policy is not None:
            cfg = cfg.with_(matmul_policy=matmul_policy)
        self.params = params
        self.cfg = cfg
        self.B = batch_size
        self.max_len = max_len
        self.sampler = sampler or SamplerConfig()
        self._step = jax.jit(
            lambda p, c, t, i: decode_step(p, cfg, c, t, i))
        self._key = jax.random.PRNGKey(self.sampler.seed)

    def autotune_shapes(self, **autotune_kw) -> dict:
        """Populate the dispatch autotune cache for this engine's per-step
        matmul shapes (see :func:`repro.models.decode.layer_matmul_shapes`);
        call before the first `run` so ``policy="auto"`` dispatches on
        measurements instead of the analytical prior."""
        from repro.kernels.dispatch import autotune, get_autotune_cache
        from repro.models.decode import layer_matmul_shapes

        cache = get_autotune_cache()
        results = {}
        for (m, k, n) in layer_matmul_shapes(self.cfg, self.B):
            results[(m, k, n)] = autotune(m, k, n, self.cfg.dtype,
                                          mu=self.cfg.mu, cache=cache,
                                          save=False, **autotune_kw)
        cache.save()  # one write for the whole shape set
        return results

    def run(self, requests: list[Request]) -> list[Request]:
        """Run a batch of requests to completion (simple generational
        batching: all requests share one prompt length via left-trim)."""
        assert len(requests) <= self.B
        reqs = list(requests) + [Request(prompt=[1], max_new_tokens=0)
                                 for _ in range(self.B - len(requests))]
        plen = max(len(r.prompt) for r in reqs)
        toks = np.ones((self.B, plen), np.int32)
        for i, r in enumerate(reqs):
            toks[i, plen - len(r.prompt):] = r.prompt  # left-pad
        batch = {"tokens": jnp.asarray(toks)}
        if self.cfg.frontend == "audio_stub":
            batch["frames"] = jnp.zeros((self.B, self.cfg.enc_seq,
                                         self.cfg.d_model), jnp.bfloat16)
        if self.cfg.frontend == "vit_stub":
            batch["vision_embeds"] = jnp.zeros(
                (self.B, self.cfg.vision_tokens, self.cfg.d_model), jnp.bfloat16)
        cache, logits = prefill(self.params, self.cfg, batch, s_max=self.max_len)

        max_new = max(r.max_new_tokens for r in reqs)
        cur = jnp.asarray(plen - 1, jnp.int32)
        for t in range(max_new):
            self._key, k = jax.random.split(self._key)
            tokens = sample_tokens(logits, self.sampler, k)
            arr = np.asarray(tokens)
            for i, r in enumerate(reqs):
                if r.done or t >= r.max_new_tokens:
                    continue
                tok = int(arr[i])
                r.out.append(tok)
                if r.stop_token is not None and tok == r.stop_token:
                    r.done = True
            if all(r.done or len(r.out) >= r.max_new_tokens for r in reqs):
                break
            cur = cur + 1
            logits, cache = self._step(self.params, cache, tokens, cur)
        return requests
