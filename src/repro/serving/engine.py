"""Batched serving engine over packed-ternary weights.

The deployment story the paper targets: weights live in HBM at 1.6 bits each
(``quantize_for_serving``), prefill builds the KV/state caches, and the
decode loop streams packed weights through the dequant path every step —
memory-bound, which is exactly where the 10× weight-byte reduction pays.

The engine adds the serving substrate around the model's decode_step:
  * request batching with left-padded prompts of unequal length,
  * greedy / temperature / top-k sampling,
  * per-step token callbacks (streaming) and stop-token handling,
  * two batching disciplines: ``run`` (generational — the whole batch turns
    over at the pace of its slowest request; kept as a simple oracle and
    baseline) and ``serve`` (continuous — per-slot positions, finished slots
    refilled mid-flight from a FIFO queue via
    :class:`repro.serving.scheduler.ContinuousScheduler`).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.models.decode import decode_step, init_cache, prefill, prefill_into_slot


@dataclass
class SamplerConfig:
    temperature: float = 0.0  # 0 → greedy
    top_k: int = 0
    seed: int = 0


def sample_tokens(logits: jax.Array, cfg: SamplerConfig, key) -> jax.Array:
    if cfg.temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = logits / cfg.temperature
    if cfg.top_k:
        kth = jax.lax.top_k(logits, cfg.top_k)[0][..., -1:]
        logits = jnp.where(logits < kth, -1e30, logits)
    return jax.random.categorical(key, logits).astype(jnp.int32)


@dataclass
class Request:
    prompt: list[int]
    max_new_tokens: int = 32
    stop_token: int | None = None
    #: streaming callback, fired as ``on_token(request, token)`` per emitted
    #: token (overrides any scheduler-wide callback)
    on_token: Callable[["Request", int], None] | None = None
    out: list[int] = field(default_factory=list)
    done: bool = False


#: token fed to dead/padding slots (any in-vocab id works; outputs of those
#: rows are never surfaced)
PAD_TOKEN = 1


class DecodeEngine:
    def __init__(self, params, cfg: ModelConfig, *, batch_size: int,
                 max_len: int, sampler: SamplerConfig | None = None,
                 matmul_policy: str | None = None):
        """``matmul_policy`` overrides ``cfg.matmul_policy`` for every ternary
        projection this engine executes ("auto" | "prior" | "fixed:<kernel>",
        see :mod:`repro.kernels.dispatch`).  Kernel selection happens once,
        at trace time of the jitted prefill/decode step."""
        if matmul_policy is not None:
            cfg = cfg.with_(matmul_policy=matmul_policy)
        self.params = params
        self.cfg = cfg
        self.B = batch_size
        self.batch_size = batch_size  # ScheduleBackend protocol name
        self.max_len = max_len
        self.sampler = sampler or SamplerConfig()
        # cache buffers are donated on every decode path (callers always
        # rebind the returned cache) so XLA updates KV in place
        self._step = jax.jit(
            lambda p, c, t, i: decode_step(p, cfg, c, t, i),
            donate_argnums=(1,))
        self._prefill = jax.jit(
            lambda p, b: prefill(p, cfg, b, s_max=self.max_len))
        # continuous-batching paths: refill one slot (retraces per prompt
        # length) and the fused sample→mask→decode step.  The live cache /
        # state is donated — callers always replace it with the returned
        # value — so XLA updates the KV buffers in place instead of copying
        # the whole cache every token (same convention as launch.dryrun).
        self._prefill_slot = jax.jit(
            lambda p, c, b, s: prefill_into_slot(p, cfg, c, b, s,
                                                 s_max=self.max_len),
            donate_argnums=(1,))
        self._sched_step_fn = jax.jit(self._make_sched_step(),
                                      donate_argnums=(1,))
        self._key = jax.random.PRNGKey(self.sampler.seed)

    def autotune_shapes(self, **autotune_kw) -> dict:
        """Populate the dispatch autotune cache for this engine's per-step
        matmul shapes (see :func:`repro.models.decode.layer_matmul_shapes`);
        call before the first `run` so ``policy="auto"`` dispatches on
        measurements instead of the analytical prior."""
        from repro.kernels.dispatch import autotune, get_autotune_cache
        from repro.models.decode import layer_matmul_shapes

        cache = get_autotune_cache()
        results = {}
        for (m, k, n) in layer_matmul_shapes(self.cfg, self.B):
            results[(m, k, n)] = autotune(m, k, n, self.cfg.dtype,
                                          mu=self.cfg.mu, cache=cache,
                                          save=False, **autotune_kw)
        cache.save()  # one write for the whole shape set
        return results

    def _stub_inputs(self, B: int) -> dict:
        extras: dict[str, Any] = {}
        if self.cfg.frontend == "audio_stub":
            extras["frames"] = jnp.zeros((B, self.cfg.enc_seq,
                                          self.cfg.d_model), jnp.bfloat16)
        if self.cfg.frontend == "vit_stub":
            extras["vision_embeds"] = jnp.zeros(
                (B, self.cfg.vision_tokens, self.cfg.d_model), jnp.bfloat16)
        return extras

    # ------------------------------------------------------------------
    # generational batching (baseline / oracle path)
    # ------------------------------------------------------------------

    def run(self, requests: list[Request]) -> list[Request]:
        """Run a batch of requests to completion (generational batching: all
        requests share one prompt length via left-trim and the batch turns
        over at the pace of its slowest request — use :meth:`serve` for
        continuous batching)."""
        if len(requests) > self.B:
            raise ValueError(
                f"got {len(requests)} requests for batch_size {self.B}; "
                "generational run() cannot queue — use serve() instead")
        reqs = list(requests) + [Request(prompt=[PAD_TOKEN], max_new_tokens=0)
                                 for _ in range(self.B - len(requests))]
        plen = max(len(r.prompt) for r in reqs)
        max_new = max(r.max_new_tokens for r in reqs)
        if not self.cfg.window and plen + max_new > self.max_len:
            # out-of-range positions would silently scatter-drop KV writes
            raise ValueError(
                f"prompt ({plen}) + max_new_tokens ({max_new}) exceeds "
                f"engine max_len {self.max_len}")
        toks = np.full((self.B, plen), PAD_TOKEN, np.int32)
        for i, r in enumerate(reqs):
            toks[i, plen - len(r.prompt):] = r.prompt  # left-pad
        batch = {"tokens": jnp.asarray(toks), **self._stub_inputs(self.B)}
        cache, logits = self._prefill(self.params, batch)

        cur = jnp.asarray(plen - 1, jnp.int32)
        for t in range(max_new):
            self._key, k = jax.random.split(self._key)
            tokens = sample_tokens(logits, self.sampler, k)
            arr = np.asarray(tokens)
            for i, r in enumerate(reqs):
                if r.done or t >= r.max_new_tokens:
                    continue
                tok = int(arr[i])
                r.out.append(tok)
                if r.on_token is not None:
                    r.on_token(r, tok)
                if r.stop_token is not None and tok == r.stop_token:
                    r.done = True
            if all(r.done or len(r.out) >= r.max_new_tokens for r in reqs):
                break
            cur = cur + 1
            logits, cache = self._step(self.params, cache, tokens, cur)
        return requests

    # ------------------------------------------------------------------
    # continuous batching (ScheduleBackend protocol; driven by the
    # ContinuousScheduler — see repro/serving/scheduler.py)
    # ------------------------------------------------------------------

    def _make_sched_step(self):
        """Fused per-step fn: sample → mask dead slots → advance per-slot
        positions → decode → on-device stop/budget masking.  The host sees
        only the (tokens, alive) pair."""
        cfg, sampler = self.cfg, self.sampler

        def step(p, state, key):
            live = state["live"]
            toks = sample_tokens(state["logits"], sampler, key)
            toks = jnp.where(live, toks, PAD_TOKEN)
            index = state["index"] + live  # only live slots advance
            logits, cache = decode_step(p, cfg, state["cache"], toks, index)
            remaining = state["remaining"] - live
            alive = live & (toks != state["stop"]) & (remaining > 0)
            state = dict(cache=cache, logits=logits, index=index,
                         remaining=remaining, stop=state["stop"], live=alive)
            return state, toks, alive

        return step

    def sched_start(self) -> dict:
        """Fresh scheduler state: empty cache, all slots dead."""
        B, V = self.B, self.cfg.padded_vocab
        return {
            "cache": init_cache(self.cfg, B, self.max_len),
            "logits": jnp.zeros((B, V), jnp.float32),
            "live": jnp.zeros((B,), bool),
            "index": jnp.zeros((B,), jnp.int32),
            "remaining": jnp.zeros((B,), jnp.int32),
            "stop": jnp.full((B,), -1, jnp.int32),
        }

    def sched_admit(self, state: dict, slot: int, request: Request) -> dict:
        """Prefill ``request`` alone and splice it into batch row ``slot``."""
        plen = len(request.prompt)
        if plen < 1:
            raise ValueError("empty prompt")
        if not self.cfg.window and plen + request.max_new_tokens > self.max_len:
            raise ValueError(
                f"prompt ({plen}) + max_new_tokens ({request.max_new_tokens}) "
                f"exceeds engine max_len {self.max_len}")
        batch = {"tokens": jnp.asarray(np.asarray(request.prompt,
                                                  np.int32)[None]),
                 **self._stub_inputs(1)}
        cache, logits1 = self._prefill_slot(self.params, state["cache"], batch,
                                            jnp.asarray(slot, jnp.int32))
        stop = -1 if request.stop_token is None else int(request.stop_token)
        return dict(
            cache=cache,
            logits=state["logits"].at[slot].set(logits1),
            live=state["live"].at[slot].set(True),
            index=state["index"].at[slot].set(plen - 1),
            remaining=state["remaining"].at[slot].set(request.max_new_tokens),
            stop=state["stop"].at[slot].set(stop),
        )

    def sched_step(self, state: dict):
        self._key, k = jax.random.split(self._key)
        state, toks, alive = self._sched_step_fn(self.params, state, k)
        return state, np.asarray(toks), np.asarray(alive)

    def serve(self, requests: list[Request], *,
              on_token: Callable[[Request, int], None] | None = None,
              max_steps: int | None = None) -> list[Request]:
        """Run requests through the continuous-batching scheduler: FIFO
        admission, per-slot positions, finished slots refilled mid-flight.
        Any number of requests — slots turn over as requests finish.
        Returns ``requests`` (same objects, ``out`` filled, in input order).
        """
        from repro.serving.scheduler import ContinuousScheduler

        sched = ContinuousScheduler(self, on_token=on_token)
        for r in requests:
            sched.submit(r)
        sched.run(max_steps=max_steps)
        return requests
