"""Batched serving engine over packed-ternary weights.

The deployment story the paper targets: weights live in HBM at 1.6 bits each
(``quantize_for_serving``), prefill builds the KV/state caches, and the
decode loop streams packed weights through the dequant path every step —
memory-bound, which is exactly where the 10× weight-byte reduction pays.

The engine adds the serving substrate around the model's decode_step:
  * request batching with left-padded prompts of unequal length,
  * greedy / temperature / top-k sampling,
  * per-step token callbacks (streaming) and stop-token handling,
  * two batching disciplines: ``run`` (generational — the whole batch turns
    over at the pace of its slowest request; kept as a simple oracle and
    baseline) and ``serve`` (continuous — per-slot positions, finished slots
    refilled mid-flight from a FIFO queue via
    :class:`repro.serving.scheduler.ContinuousScheduler`).
"""

from __future__ import annotations

import itertools
import logging
from collections import Counter
from dataclasses import dataclass, field
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.models.decode import (
    cache_len,
    decode_step,
    init_cache,
    prefill,
    prefill_chunk as model_prefill_chunk,  # `prefill_chunk` is an engine kwarg
    prefill_chunks_of,
    supports_chunked_prefill,
)


logger = logging.getLogger(__name__)


@dataclass
class SamplerConfig:
    temperature: float = 0.0  # 0 → greedy
    top_k: int = 0
    seed: int = 0
    #: greedy selection via :func:`greedy_tokens` (bf16-canonicalized argmax)
    #: instead of raw f32 argmax.  The speculative path ALWAYS selects
    #: canonically (its free token, draft proposals, and verify predictions
    #: must agree across differently-compiled programs); set this on a
    #: non-speculative engine to make its greedy stream byte-comparable to a
    #: speculative one.  Off by default: raw argmax is the historical
    #: semantic, and the bf16 grid draws its own tie boundaries (a sharded
    #: run whose psum drift spans a grid edge can flip differently than raw).
    canonical_greedy: bool = False


def greedy_tokens(logits: jax.Array) -> jax.Array:
    """Canonical greedy selection: round logits to bf16, then argmax.

    Logits come off a bf16 matmul, so adjacent candidates routinely sit
    within one bf16 ulp of each other — and XLA compiles the *same* float
    math to slightly different last bits in different programs (jitted
    sched_step vs the fused speculative round vs op-by-op eager; measured
    ~3e-4 drift on this backend, ~50x below the bf16 grid at logit scale).
    Raw f32 argmax lets that sub-ulp drift flip near-tie tokens between
    programs, which would break the speculative path's byte-identity
    guarantee.  Rounding to bf16 first collapses sub-ulp drift back onto one
    grid point, and exact bf16 ties resolve to the lowest token id in every
    code path — so every greedy consumer in the speculative round (the
    sampler via ``canonical_greedy``, draft proposals, verify predictions)
    picks the same token for the same underlying distribution.
    """
    return jnp.argmax(logits.astype(jnp.bfloat16), axis=-1).astype(jnp.int32)


def sample_tokens(logits: jax.Array, cfg: SamplerConfig, key) -> jax.Array:
    if cfg.temperature <= 0.0:
        if cfg.canonical_greedy:
            return greedy_tokens(logits)
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = logits / cfg.temperature
    if cfg.top_k:
        kth = jax.lax.top_k(logits, cfg.top_k)[0][..., -1:]
        logits = jnp.where(logits < kth, -1e30, logits)
    return jax.random.categorical(key, logits).astype(jnp.int32)


#: process-wide monotonic request-id source (see ``Request.rid``)
_RID = itertools.count()


@dataclass
class Request:
    prompt: list[int]
    max_new_tokens: int = 32
    stop_token: int | None = None
    #: streaming callback, fired as ``on_token(request, token)`` per emitted
    #: token (overrides any scheduler-wide callback)
    on_token: Callable[["Request", int], None] | None = None
    out: list[int] = field(default_factory=list)
    done: bool = False
    #: traffic class this request belongs to (multi-tenant workload replay);
    #: None for direct API use — the engine never reads it, but scheduler
    #: stats and the load-generator's SLO analysis group by it
    tenant: str | None = None
    #: stable monotonically-assigned request id — the key for any per-request
    #: bookkeeping map (TTFT/TPOT/acceptance).  ``id(request)`` is NOT safe
    #: for that: CPython reuses object ids after GC, so a long-running server
    #: keyed on identity can silently merge two requests' stats.
    rid: int = field(default_factory=_RID.__next__)


#: token fed to dead/padding slots (any in-vocab id works; outputs of those
#: rows are never surfaced)
PAD_TOKEN = 1


class DecodeEngine:
    def __init__(self, params, cfg: ModelConfig, *, batch_size: int,
                 max_len: int, sampler: SamplerConfig | None = None,
                 matmul_policy: str | None = None, prefill_chunk: int = 32,
                 mesh=None, prefix_cache=False,
                 prefix_cache_mb: float = 64.0,
                 draft: tuple[Any, ModelConfig] | None = None,
                 spec_k: int = 4):
        """``matmul_policy`` overrides ``cfg.matmul_policy`` for every ternary
        projection this engine executes ("auto" | "prior" | "fixed:<kernel>",
        see :mod:`repro.kernels.dispatch`).  Kernel selection happens once,
        at trace time of the jitted prefill/decode step.

        ``prefill_chunk`` sets the admission chunk size: prompts are padded
        to a multiple of it and scanned chunk-by-chunk through one compiled
        trace (clamped to the ring length on windowed configs so a chunk
        never collides with itself).  Architectures without chunked-prefill
        support fall back to whole-prompt admission, which retraces per
        prompt length.

        ``mesh`` (a ``jax.sharding.Mesh`` with the repo's ``data``/``model``
        axes, see ``launch.mesh``) turns on sharded serving: packed weights
        are placed per the TP/EP rules in :mod:`repro.parallel.sharding`
        (``param_shardings``), the scheduler state's KV/state cache per
        ``cache_specs``, and the jitted prefill-chunk / admit-commit /
        sched-step entry points carry explicit in/out shardings so GSPMD
        partitions every step.  Kernel dispatch runs under a
        ``dispatch.shard_scope`` whose :class:`~repro.kernels.dispatch.ShardInfo`
        maps each matmul to its per-device shard — autotune-cache keys and
        prior scores are derived from the *local* problem.  The scheduling
        protocol is unchanged: a ``ContinuousScheduler`` drives a sharded
        engine exactly like a single-device one.

        ``prefix_cache`` turns on hashed shared-prefix KV reuse: pass True
        (a fresh :class:`repro.serving.prefix_cache.PrefixBlockStore` with a
        ``prefix_cache_mb`` byte budget) or a store instance to share across
        engines.  Admission then consults the store per prompt block
        (block = one ``prefill_chunk``), splices cached KV slabs instead of
        recomputing hit blocks, and publishes each freshly-computed full
        block.  Only effective on chunked-admission architectures — the
        whole-prompt fallback families carry recurrent state a KV slab
        cannot capture — and on windowed configs reuse depth is capped at
        the ring length (deeper blocks would be overwritten before the
        prompt tail attends them).

        ``draft`` = ``(draft_params, draft_cfg)`` turns on draft-and-verify
        speculative decoding on the continuous path: each scheduler step the
        (small, replicated) draft model proposes ``spec_k - 1`` greedy
        continuations of the target's free next token and the target scores
        all ``spec_k`` candidates in ONE batched ``verify_step`` forward;
        the accepted prefix is kept, the rejected suffix's KV/pos writes are
        rewound on both caches (``rollback_kv_window``).  Greedy streams are
        preserved exactly: every emitted token is, by construction, the
        target's own argmax — the draft only decides how many of them one
        step yields.  Requires temperature-0 sampling, a shared
        tokenizer/vocab, and chunked-prefill-capable architectures on both
        sides (the batched verify is the chunk forward); admission prefills
        the draft cache alongside the target's.  The generational ``run()``
        path ignores the draft."""
        if matmul_policy is not None:
            cfg = cfg.with_(matmul_policy=matmul_policy)
        self.cfg = cfg
        self.B = batch_size
        self.batch_size = batch_size  # ScheduleBackend protocol name
        self.max_len = max_len
        self.sampler = sampler or SamplerConfig()
        self.prefill_chunk = max(1, min(prefill_chunk,
                                        cache_len(cfg, max_len)))
        self.chunked_admission = supports_chunked_prefill(params, cfg)
        self._CL = cache_len(cfg, max_len)
        self.prefix_store = self._make_prefix_store(prefix_cache,
                                                    prefix_cache_mb)
        #: speculative decoding: 0 = off; >= 2 = candidates scored per
        #: verify step (1 free target token + spec_k - 1 drafted)
        self.spec_k = 0
        self.draft_params = None
        self.draft_cfg: ModelConfig | None = None
        if draft is not None:
            draft_params, draft_cfg = draft
            if matmul_policy is not None:
                draft_cfg = draft_cfg.with_(matmul_policy=matmul_policy)
            if draft_cfg.vocab_size != cfg.vocab_size:
                raise ValueError(
                    f"draft/target tokenizer mismatch: draft "
                    f"{draft_cfg.name} has vocab_size {draft_cfg.vocab_size} "
                    f"but target {cfg.name} has {cfg.vocab_size}; "
                    f"speculative decoding compares token ids directly, so "
                    f"draft and target must share one tokenizer/vocab")
            if self.sampler.temperature > 0.0:
                raise ValueError(
                    f"speculative decoding preserves greedy streams only "
                    f"(temperature=0); got temperature="
                    f"{self.sampler.temperature}")
            if spec_k < 2:
                raise ValueError(
                    f"spec_k must be >= 2 (the target's free next token plus "
                    f"at least one drafted candidate); got {spec_k}")
            for side, c in (("target", cfg), ("draft", draft_cfg)):
                if spec_k > cache_len(c, max_len):
                    raise ValueError(
                        f"spec_k {spec_k} exceeds the {side} ring length "
                        f"{cache_len(c, max_len)}: one verify window would "
                        f"collide with itself in the KV ring")
            for side, pp, c in (("target", params, cfg),
                                ("draft", draft_params, draft_cfg)):
                if not supports_chunked_prefill(pp, c):
                    raise ValueError(
                        f"speculative decoding needs the batched verify "
                        f"forward (the chunked-prefill path), which the "
                        f"{side} architecture {c.name} does not support "
                        f"(block_pattern={c.block_pattern})")
            self.spec_k = spec_k
            self.draft_cfg = draft_cfg
            self.draft_params = draft_params
        self.mesh = mesh
        #: per-entry-point trace-time shard geometry (mesh mode only).  The
        #: batch divisor differs per entry: the batched decode step shards
        #: its M = B rows on the data axis, while admission prefills one
        #: request at a time (M = chunk length — sequence, not batch).
        self._shard_infos: dict[str, Any] = {}
        if mesh is not None:
            from jax.sharding import NamedSharding, PartitionSpec
            from repro.kernels.dispatch import ShardInfo
            from repro.parallel import sharding as sh

            axes = dict(zip(mesh.axis_names, mesh.devices.shape))
            model = axes.get("model", 1)
            data = axes.get("pod", 1) * axes.get("data", 1)
            heads = dict(wq=cfg.n_heads, wk=cfg.n_kv_heads)
            decode_info = ShardInfo(model=model, data=data, batch=data,
                                    n_heads=cfg.n_heads,
                                    n_kv_heads=cfg.n_kv_heads)
            admit_info = ShardInfo(model=model, data=data, batch=1,
                                   n_heads=cfg.n_heads,
                                   n_kv_heads=cfg.n_kv_heads)
            self._shard_infos = {
                "decode_step": decode_info, "sched_step": decode_info,
                "prefill": admit_info, "prefill_chunk": admit_info,
                "admit_commit": admit_info,
            }
            if self.spec_k:
                # the draft model runs replicated (model=1): its params and
                # cache are small by construction, and TP collectives on a
                # sub-billion-parameter draft would cost more than they save.
                # The verify half of spec_step is the TARGET forward and
                # keeps the decode-step TP geometry.
                self._shard_infos["spec_step"] = decode_info
                self._shard_infos["spec_draft"] = ShardInfo(
                    model=1, data=data, batch=data,
                    n_heads=self.draft_cfg.n_heads,
                    n_kv_heads=self.draft_cfg.n_kv_heads)
                self._shard_infos["draft_prefill_chunk"] = ShardInfo(
                    model=1, data=data, batch=1,
                    n_heads=self.draft_cfg.n_heads,
                    n_kv_heads=self.draft_cfg.n_kv_heads)
            self._psh = sh.param_shardings(params, mesh, heads=heads)
            params = jax.device_put(params, self._psh)
            repl = NamedSharding(mesh, PartitionSpec())
            if self.spec_k:
                self.draft_params = jax.device_put(self.draft_params, repl)
            state_sds = jax.eval_shape(self._state_template)
            self._state_sh = sh.to_shardings(
                sh.engine_state_specs(state_sds, mesh,
                                      kv_heads=cfg.n_kv_heads), mesh)
            cache1_sds = jax.eval_shape(
                lambda: init_cache(cfg, 1, self.max_len))
            self._cache1_sh = sh.to_shardings(
                sh.cache_specs(cache1_sds, mesh, kv_heads=cfg.n_kv_heads),
                mesh)

            def shardings(in_sh, out_sh):
                return {"in_shardings": in_sh, "out_shardings": out_sh}
        else:
            repl = None

            def shardings(in_sh, out_sh):
                return {}
        self.params = params
        #: jit traces per compiled entry point — the bucketed-admission
        #: guarantee is observable here: a mixed-length request stream keeps
        #: ``trace_counts["prefill_chunk"] == 1`` (one bucket shape)
        self.trace_counts: Counter[str] = Counter()
        # cache buffers are donated on every decode path (callers always
        # rebind the returned cache) so XLA updates KV in place
        self._step = jax.jit(
            self._counted("decode_step",
                          lambda p, c, t, i: decode_step(p, cfg, c, t, i)),
            donate_argnums=(1,))
        # the whole-prompt prefill also pins shardings in mesh mode: the
        # archs without chunked-prefill support admit through `_admit_whole`
        # → `_prefill`, and an unpinned jit would hand the commit a cache /
        # logits pair in whatever layout GSPMD propagated (observed: model-
        # sharded logits rejected by `_admit_commit_fn`'s replicated pin)
        self._prefill = jax.jit(
            self._counted("prefill",
                          lambda p, b: prefill(p, cfg, b, s_max=self.max_len)),
            **shardings(
                (getattr(self, "_psh", None), repl),
                (getattr(self, "_cache1_sh", None), repl)))
        # continuous-batching paths: the fixed-shape prefill chunk +
        # admission commit (bucketed path: one trace each; the whole-prompt
        # fallback reuses `_prefill` at B=1 — retraces per prompt length —
        # and the same commit), and the fused sample→mask→decode step.  The
        # live cache / state is donated — callers always replace it with the
        # returned value — so XLA updates the KV buffers in place instead of
        # copying the whole cache every token (same convention as
        # launch.dryrun).  In mesh mode these three entry points pin their
        # in/out shardings (params/cache/state per the sharding rules,
        # host-read outputs replicated) so the state's device layout is
        # stable step-over-step and donation aliases shard-for-shard.
        self._prefill_chunk_fn = jax.jit(
            self._counted("prefill_chunk",
                          lambda p, c, t, pos, take: model_prefill_chunk(
                              p, cfg, c, t, pos, take)),
            donate_argnums=(1,),
            **shardings(
                (getattr(self, "_psh", None), getattr(self, "_cache1_sh", None),
                 repl, repl, repl),
                (getattr(self, "_cache1_sh", None), repl)))
        # donate only the big state: the single-row chunk cache cannot alias
        # any [B, ...] output buffer, so donating it would just warn
        if self.spec_k:
            self._admit_commit_fn = jax.jit(
                self._counted("admit_commit", self._admit_commit_spec),
                donate_argnums=(0,),
                **shardings(
                    (getattr(self, "_state_sh", None),
                     getattr(self, "_cache1_sh", None), repl, repl, repl,
                     repl, repl, repl),
                    getattr(self, "_state_sh", None)))
        else:
            self._admit_commit_fn = jax.jit(
                self._counted("admit_commit", self._admit_commit),
                donate_argnums=(0,),
                **shardings(
                    (getattr(self, "_state_sh", None),
                     getattr(self, "_cache1_sh", None), repl, repl, repl,
                     repl, repl),
                    getattr(self, "_state_sh", None)))
        self._sched_step_fn = jax.jit(
            self._counted("sched_step", self._make_sched_step()),
            donate_argnums=(1,),
            **shardings(
                (getattr(self, "_psh", None), getattr(self, "_state_sh", None),
                 repl),
                (getattr(self, "_state_sh", None), repl, repl)))
        if self.spec_k:
            # the whole speculative round — draft-K scan, batched verify,
            # accept mask, rollback of both caches — is ONE jitted call per
            # scheduler step: K drafted positions plus K verified positions
            # ride a single host round-trip, so per-call overhead is paid
            # once per K-token window instead of once per token.  Draft
            # params/cache replicate; target entries keep their TP layout.
            dcfg = self.draft_cfg
            self._draft_prefill_chunk_fn = jax.jit(
                self._counted("draft_prefill_chunk",
                              lambda p, c, t, pos, take: model_prefill_chunk(
                                  p, dcfg, c, t, pos, take)),
                donate_argnums=(1,),
                **shardings((repl, repl, repl, repl, repl), (repl, repl)))
            self._spec_step_fn = jax.jit(
                self._counted("spec_step", self._make_spec_step()),
                donate_argnums=(2,),
                **shardings(
                    (getattr(self, "_psh", None), repl,
                     getattr(self, "_state_sh", None), repl),
                    (getattr(self, "_state_sh", None), repl, repl, repl,
                     repl)))
        if self.prefix_store is not None:
            # prefix-cache entry points: splice a stored KV slab into the
            # single-row admission cache / extract a just-prefilled block
            # for publication.  Both take the block start position as traced
            # int32 — one trace serves every block index — and both are
            # `_counted`, so the trace-honesty tests can assert cache HITS
            # mint no new prefill traces.  The slab layout matches
            # `sharding.block_slab_specs` in mesh mode (kv-head sharded
            # alongside the cache), so splicing stays resident per shard.
            from repro.models.decode import (extract_kv_blocks,
                                             splice_kv_blocks)

            C = self.prefill_chunk
            slab_sh = None
            if mesh is not None:
                from repro.parallel import sharding as sh

                slab_sds = jax.eval_shape(lambda: extract_kv_blocks(
                    cfg, init_cache(cfg, 1, self.max_len), 0, C))
                slab_sh = sh.to_shardings(
                    sh.block_slab_specs(slab_sds, mesh,
                                        kv_heads=cfg.n_kv_heads), mesh)
                self._slab_sh = slab_sh
            self._splice_block_fn = jax.jit(
                self._counted("splice_block",
                              lambda c, kb, vb, s: splice_kv_blocks(
                                  cfg, c, {"k": kb, "v": vb}, s)),
                donate_argnums=(0,),
                **shardings(
                    (getattr(self, "_cache1_sh", None),
                     slab_sh["k"] if slab_sh else None,
                     slab_sh["v"] if slab_sh else None, repl),
                    getattr(self, "_cache1_sh", None)))
            # the admission cache is NOT donated here: the caller keeps
            # prefilling through it after the extraction
            self._extract_block_fn = jax.jit(
                self._counted("extract_block",
                              lambda c, s: extract_kv_blocks(cfg, c, s, C)),
                **shardings(
                    (getattr(self, "_cache1_sh", None), repl), slab_sh))
        self._key = jax.random.PRNGKey(self.sampler.seed)

    def _make_prefix_store(self, prefix_cache, prefix_cache_mb: float):
        """Resolve the ``prefix_cache`` constructor arg into a
        :class:`~repro.serving.prefix_cache.PrefixBlockStore` (or None).
        The store's hash namespace binds the KV-producing geometry — config
        name, depth, kv-head shape — so slabs can never be replayed across
        engines whose caches they would not fit."""
        # identity checks, not truthiness: an EMPTY store instance is falsy
        # (len() == 0) but must still be wired in and validated
        if prefix_cache is None or prefix_cache is False:
            return None
        if not self.chunked_admission:
            logger.warning(
                "prefix cache requested but %s admits through whole-prompt "
                "fallback (no chunked prefill); prefix reuse disabled",
                self.cfg.name)
            return None
        from repro.serving.prefix_cache import PrefixBlockStore

        ns = (f"{self.cfg.name}:{self.cfg.n_layers}:{self.cfg.n_kv_heads}:"
              f"{self.cfg.head_dim}:{self.cfg.d_model}").encode()
        if prefix_cache is True:
            return PrefixBlockStore(
                self.prefill_chunk,
                max_bytes=max(1, int(prefix_cache_mb * (1 << 20))),
                namespace=ns)
        store = prefix_cache
        if store.block_tokens != self.prefill_chunk:
            raise ValueError(
                f"prefix store block size {store.block_tokens} != engine "
                f"prefill_chunk {self.prefill_chunk}: blocks are admission "
                f"chunks, the sizes must agree")
        if store.namespace != ns:
            raise ValueError(
                "prefix store namespace mismatch: the store was built for a "
                "different model geometry; sharing it would splice foreign "
                "KV slabs")
        return store

    def _counted(self, name: str, fn):
        """Wrap a to-be-jitted callable so each (re)trace bumps
        ``trace_counts[name]`` — cache hits never re-enter the wrapper.
        In mesh mode the trace also runs under the entry point's
        ``dispatch.shard_scope``, so every ternary-matmul selection inside
        keys on the per-device local problem."""
        info = self._shard_infos.get(name)

        def wrapped(*args):
            from repro.kernels.dispatch import shard_scope

            self.trace_counts[name] += 1
            with shard_scope(info):
                return fn(*args)
        return wrapped

    def matmul_shape_universe(self, *, include_prefill: bool = True
                              ) -> list[tuple[int, ...]]:
        """Every ternary-matmul problem this engine's steady-state serving
        paths dispatch: dense ``(M, K, N)`` triples — decode (``M = B``)
        plus, with ``include_prefill``, the admission-chunk bucket shape
        (``M = 1 · chunk`` — requests are prefilled one at a time, chunk by
        chunk) — and, for MoE configs, grouped ``(E, C, K, N)`` quads at the
        matching per-expert capacities (the expert stacks dispatch through
        ``grouped_ternary_matmul``).  Generational ``run()`` prefills at
        ``M = B · prompt_len`` for whatever prompt lengths arrive; those are
        workload-dependent and belong to ``benchmarks/autotune_sweep.py``,
        not the engine's fixed universe.

        With a draft model the universe also covers the speculative
        operating points: the target's K-token verify (``M = B · spec_k``
        through the ``spec_step`` geometry), the draft's per-step decode and
        admission-chunk problems (``model=1`` — the draft runs replicated,
        so its local problems are its global ones).

        In mesh mode the universe is **per-shard**: every problem is mapped
        through the entry point's ``ShardInfo`` (the same localization
        dispatch applies inside ``shard_scope``), so ``autotune_shapes``
        measures and records exactly the local problems each device runs."""
        from repro.models.decode import (layer_grouped_matmul_problems,
                                         layer_matmul_problems)

        shapes: set[tuple[int, ...]] = set()
        for c, bs, sl, entry in self._shape_sources(
                include_prefill=include_prefill):
            info = self._shard_infos.get(entry)
            for role, m, k, n in layer_matmul_problems(c, bs, seq_len=sl):
                if info is not None:
                    m, k, n = info.local_dense(role, m, k, n)
                shapes.add((m, k, n))
            for role, e, cap, k, n in layer_grouped_matmul_problems(
                    c, bs, seq_len=sl):
                if info is not None:
                    e, cap, k, n = info.local_grouped(role, e, cap, k, n)
                shapes.add((e, cap, k, n))
        return sorted(shapes)

    def _shape_sources(self, *, include_prefill: bool = True
                       ) -> list[tuple[ModelConfig, int, int, str]]:
        """The ``(cfg, batch_size, seq_len, entry_point)`` tuples whose
        matmul problems make up this engine's steady-state shape universe —
        target decode + admission chunk, and with a draft: target verify,
        draft decode, draft admission chunk."""
        sources = [(self.cfg, self.B, 1, "sched_step")]
        if include_prefill:
            sources.append((self.cfg, 1, self.prefill_chunk,
                            "prefill_chunk"))
        if self.spec_k:
            sources.append((self.cfg, self.B, self.spec_k, "spec_step"))
            sources.append((self.draft_cfg, self.B, 1, "spec_draft"))
            if include_prefill:
                sources.append((self.draft_cfg, 1, self.prefill_chunk,
                                "draft_prefill_chunk"))
        return sources

    def autotune_shapes(self, *, include_prefill: bool = True,
                        **autotune_kw) -> dict:
        """Populate the dispatch autotune cache for this engine's per-step
        matmul shapes — decode *and* (by default) the prefill bucket shapes,
        dense and grouped-expert alike, so ``policy="auto"`` serving
        dispatches on measurements instead of always falling back to the
        analytical prior.  Call before the first `run`/`serve`."""
        from repro.kernels.dispatch import autotune, get_autotune_cache
        from repro.models.decode import (layer_grouped_matmul_problems,
                                         layer_matmul_problems)

        cache = get_autotune_cache()
        results = {}
        seen: set[tuple] = set()
        # iterate per source (not the merged universe): the act dtype the
        # dispatch keys on is per-config — a bf16-act draft and an int8-act
        # target may share a shape yet tune different kernel families
        for c, bs, sl, entry in self._shape_sources(
                include_prefill=include_prefill):
            info = self._shard_infos.get(entry)
            # under act_dtype="int8" every packed projection receives
            # pre-quantized int8 activations, so that is the dtype the
            # serving dispatch keys on (w2a8/tl2 become eligible)
            act = "int8" if c.act_dtype == "int8" else c.dtype
            probs: list[tuple[tuple[int, ...], int | None]] = []
            for role, m, k, n in layer_matmul_problems(c, bs, seq_len=sl):
                if info is not None:
                    m, k, n = info.local_dense(role, m, k, n)
                probs.append(((m, k, n), None))
            for role, e, cap, k, n in layer_grouped_matmul_problems(
                    c, bs, seq_len=sl):
                if info is not None:
                    e, cap, k, n = info.local_grouped(role, e, cap, k, n)
                probs.append(((e, cap, k, n), e))
            for shape, e in probs:
                if (shape, act) in seen:
                    continue
                seen.add((shape, act))
                if e is not None:
                    _, m, k, n = shape
                else:
                    m, k, n = shape
                results[shape] = autotune(m, k, n, act,
                                          mu=c.mu, cache=cache,
                                          save=False, e=e, **autotune_kw)
        cache.save()  # one write for the whole shape set
        return results

    def _stub_inputs(self, B: int) -> dict:
        extras: dict[str, Any] = {}
        if self.cfg.frontend == "audio_stub":
            extras["frames"] = jnp.zeros((B, self.cfg.enc_seq,
                                          self.cfg.d_model), jnp.bfloat16)
        if self.cfg.frontend == "vit_stub":
            extras["vision_embeds"] = jnp.zeros(
                (B, self.cfg.vision_tokens, self.cfg.d_model), jnp.bfloat16)
        return extras

    # ------------------------------------------------------------------
    # generational batching (baseline / oracle path)
    # ------------------------------------------------------------------

    def run(self, requests: list[Request]) -> list[Request]:
        """Run a batch of requests to completion (generational batching: all
        requests share one prompt length via left-trim and the batch turns
        over at the pace of its slowest request — use :meth:`serve` for
        continuous batching)."""
        if len(requests) > self.B:
            raise ValueError(
                f"got {len(requests)} requests for batch_size {self.B}; "
                "generational run() cannot queue — use serve() instead")
        reqs = list(requests) + [Request(prompt=[PAD_TOKEN], max_new_tokens=0)
                                 for _ in range(self.B - len(requests))]
        plen = max(len(r.prompt) for r in reqs)
        max_new = max(r.max_new_tokens for r in reqs)
        if not self.cfg.window and plen + max_new > self.max_len:
            # out-of-range positions would silently scatter-drop KV writes
            raise ValueError(
                f"prompt ({plen}) + max_new_tokens ({max_new}) exceeds "
                f"engine max_len {self.max_len}")
        toks = np.full((self.B, plen), PAD_TOKEN, np.int32)
        for i, r in enumerate(reqs):
            toks[i, plen - len(r.prompt):] = r.prompt  # left-pad
        batch = {"tokens": jnp.asarray(toks), **self._stub_inputs(self.B)}
        cache, logits = self._prefill(self.params, batch)

        cur = jnp.asarray(plen - 1, jnp.int32)
        for t in range(max_new):
            self._key, k = jax.random.split(self._key)
            tokens = sample_tokens(logits, self.sampler, k)
            arr = np.asarray(tokens)
            for i, r in enumerate(reqs):
                if r.done or t >= r.max_new_tokens:
                    continue
                tok = int(arr[i])
                r.out.append(tok)
                if r.on_token is not None:
                    r.on_token(r, tok)
                if r.stop_token is not None and tok == r.stop_token:
                    r.done = True
            if all(r.done or len(r.out) >= r.max_new_tokens for r in reqs):
                break
            cur = cur + 1
            logits, cache = self._step(self.params, cache, tokens, cur)
        for r in requests:
            if not r.done and len(r.out) >= r.max_new_tokens:
                # budget exhausted (or zero budget): the request is finished
                # even without a stop-token hit — same completion semantics
                # as serve()'s on-device alive mask (live & !stop &
                # remaining > 0), so a run() result can never slip past the
                # scheduler's resubmission guard
                r.done = True
        return requests

    # ------------------------------------------------------------------
    # continuous batching (ScheduleBackend protocol; driven by the
    # ContinuousScheduler — see repro/serving/scheduler.py)
    # ------------------------------------------------------------------

    def _make_sched_step(self):
        """Fused per-step fn: sample → mask dead slots → advance per-slot
        positions → decode → on-device stop/budget masking.  The host sees
        only the (tokens, alive) pair."""
        cfg, sampler = self.cfg, self.sampler

        def step(p, state, key):
            live = state["live"]
            toks = sample_tokens(state["logits"], sampler, key)
            toks = jnp.where(live, toks, PAD_TOKEN)
            index = state["index"] + live  # only live slots advance
            # dead rows decode at the -1 sentinel: their KV/pos writes drop,
            # so a slot mid-chunked-prefill (or simply idle) never pollutes
            # the ring while decode steps interleave with admission chunks
            logits, cache = decode_step(p, cfg, state["cache"], toks,
                                        jnp.where(live, index, -1))
            remaining = state["remaining"] - live
            alive = live & (toks != state["stop"]) & (remaining > 0)
            state = dict(state, cache=cache, logits=logits, index=index,
                         remaining=remaining, stop=state["stop"], live=alive)
            return state, toks, alive

        return step

    def _make_spec_step(self):
        """Fused speculative round (continuous path, greedy only):

        1. the target's FREE next token ``c0 = argmax(state["logits"])`` —
           already exactly what the non-speculative step would emit;
        2. a K-step draft scan proposes ``c1..c_{K-1}`` greedily and writes
           ALL K candidates into the draft ring, so the draft cache stays
           position-synced for any acceptance count;
        3. one batched target ``verify_step`` scores all K candidates;
           candidate ``j >= 1`` is accepted iff it equals the target's own
           argmax after candidates ``0..j-1`` — i.e. iff it IS the token the
           sequential greedy engine would have emitted;
        4. stop/budget masking over the accepted window, then
           ``rollback_kv_window`` rewinds both rings past the accepted
           prefix.

        Dead rows (``live = False``) verify at position -1: no KV/pos write
        lands and ``n_acc = n_emit = 0``.  Returns ``(state, cands [B, K],
        n_acc [B], n_emit [B], alive [B])``.

        ``window`` (traced, [B] int32 in ``[2, K]``) caps the accepted
        prefix per slot: candidates at positions ``>= window[b]`` are
        treated as rejected, so ``n_acc[b] <= window[b]``.  The draft scan
        and verify still run all K positions — ONE compiled trace serves
        every window combination, and the rollback already rewinds whatever
        was not accepted — so ``window[b] = K`` reproduces the fixed-K round
        bit-for-bit.  The scheduler's dynamic-``spec_k`` policy sizes this
        from measured acceptance (the saved work shows up in the acceptance
        accounting, which charges only ``window - 1`` drafts per round).
        """
        from repro.kernels.dispatch import shard_scope
        from repro.models.decode import (rollback_kv_window,
                                         snapshot_kv_window, verify_step)

        cfg, dcfg, K = self.cfg, self.draft_cfg, self.spec_k
        dinfo = self._shard_infos.get("spec_draft")

        def step(p, dp, state, window):
            live = state["live"]
            index = state["index"]
            B = live.shape[0]
            start = jnp.where(live, index + 1, -1)
            c0 = jnp.where(live, greedy_tokens(state["logits"]), PAD_TOKEN)
            dcache = state["dcache"]
            with shard_scope(dinfo):
                dundo = snapshot_kv_window(dcfg, dcache, start, K)

                def draft_body(carry, j):
                    dc, tok = carry
                    dlogits, dc = decode_step(dp, dcfg, dc, tok,
                                              jnp.where(live, index + 1 + j,
                                                        -1))
                    nxt = jnp.where(live, greedy_tokens(dlogits), PAD_TOKEN)
                    return (dc, nxt), tok

                (dcache, _), cands = jax.lax.scan(
                    draft_body, (dcache, c0), jnp.arange(K, dtype=jnp.int32))
            cands = jnp.swapaxes(cands, 0, 1)  # [B, K]
            undo = snapshot_kv_window(cfg, state["cache"], start, K)
            vlogits, cache = verify_step(p, cfg, state["cache"], cands, start)
            pred = greedy_tokens(vlogits)  # [B, K]
            # accepted prefix: candidate j (>=1) must equal the target's
            # argmax after consuming candidates 0..j-1 AND sit inside the
            # slot's draft window; c0 is always accepted
            in_win = jnp.arange(1, K, dtype=jnp.int32)[None, :] < \
                window[:, None]
            match = ((cands[:, 1:] == pred[:, :-1]) & in_win).astype(
                jnp.int32)
            n_acc = jnp.where(
                live, 1 + jnp.sum(jnp.cumprod(match, axis=1), axis=1), 0)
            # stop/budget masking over the accepted window: emit up to (and
            # including) the first stop token, never past the budget
            j_iota = jnp.arange(K, dtype=jnp.int32)[None, :]
            is_stop = (j_iota < n_acc[:, None]) & \
                (cands == state["stop"][:, None])
            stop_at = jnp.min(jnp.where(is_stop, j_iota, K), axis=1)
            n_emit = jnp.minimum(jnp.minimum(n_acc, stop_at + 1),
                                 state["remaining"])
            remaining = state["remaining"] - n_emit
            stopped = stop_at < n_emit  # the stop token was actually emitted
            alive = live & ~stopped & (remaining > 0)
            cache = rollback_kv_window(cfg, cache, undo, n_acc)
            dcache = rollback_kv_window(dcfg, dcache, dundo, n_acc)
            # next round's free token comes from the target's logits at the
            # last accepted position (the "bonus" distribution verify paid
            # for); dead rows keep their stale logits untouched
            rows = jnp.arange(B)
            nlog = vlogits[rows, jnp.maximum(n_acc - 1, 0)]
            logits = jnp.where(live[:, None], nlog, state["logits"])
            state = dict(state, cache=cache, dcache=dcache, logits=logits,
                         index=index + n_acc, remaining=remaining,
                         stop=state["stop"], live=alive)
            return state, cands, n_acc, n_emit, alive

        return step

    def _state_template(self) -> dict:
        """The scheduler-state pytree (also eval_shape'd in mesh mode to
        derive the state shardings pinned on the jitted entry points)."""
        B, V = self.B, self.cfg.padded_vocab
        state = {
            "cache": init_cache(self.cfg, B, self.max_len),
            "logits": jnp.zeros((B, V), jnp.float32),
            "live": jnp.zeros((B,), bool),
            "index": jnp.zeros((B,), jnp.int32),
            "remaining": jnp.zeros((B,), jnp.int32),
            "stop": jnp.full((B,), -1, jnp.int32),
        }
        if self.spec_k:
            # the draft's KV ring rides in the scheduler state: its per-slot
            # position trajectory is the target's (admission and every spec
            # round write both in lockstep), so one `index` serves both
            state["dcache"] = init_cache(self.draft_cfg, B, self.max_len)
        return state

    def sched_start(self) -> dict:
        """Fresh scheduler state: empty cache, all slots dead.  In mesh mode
        the state is laid out per ``sharding.engine_state_specs`` up front,
        so the first jitted step never reshards."""
        state = self._state_template()
        if self.mesh is not None:
            state = jax.device_put(state, self._state_sh)
        return state

    def _validate_request(self, request: Request) -> int:
        plen = len(request.prompt)
        if plen < 1:
            raise ValueError("empty prompt")
        if not self.cfg.window and plen + request.max_new_tokens > self.max_len:
            raise ValueError(
                f"prompt ({plen}) + max_new_tokens ({request.max_new_tokens}) "
                f"exceeds engine max_len {self.max_len}")
        return plen

    @staticmethod
    def _admit_commit(state: dict, cache1: dict, logits1, slot, index0,
                      remaining, stop) -> dict:
        """Splice a fully-prefilled single-row cache into batch row ``slot``
        and arm the slot — the ONE place the per-slot arming invariant
        (cache/logits/live/index/remaining/stop) lives; both the chunked and
        the whole-prompt admission paths commit through it.  All scalars
        arrive as traced int32, so one trace serves every (slot,
        prompt-length, budget) combination."""
        def splice(big, one):
            idx = (0, slot) + (0,) * (big.ndim - 2)
            return jax.lax.dynamic_update_slice(big, one.astype(big.dtype), idx)

        return dict(
            state,
            cache=jax.tree.map(splice, state["cache"], cache1),
            logits=state["logits"].at[slot].set(logits1),
            live=state["live"].at[slot].set(True),
            index=state["index"].at[slot].set(index0),
            remaining=state["remaining"].at[slot].set(remaining),
            stop=state["stop"].at[slot].set(stop),
        )

    @staticmethod
    def _admit_commit_spec(state: dict, cache1: dict, dcache1: dict, logits1,
                           slot, index0, remaining, stop) -> dict:
        """Speculative variant of :meth:`_admit_commit`: the draft's freshly
        prefilled single-row cache is spliced into ``state["dcache"]`` at the
        same slot, so the slot's draft ring starts in lockstep with the
        target's (both hold the prompt's KV at positions ``0..plen-1``)."""
        state = DecodeEngine._admit_commit(state, cache1, logits1, slot,
                                           index0, remaining, stop)

        def splice(big, one):
            idx = (0, slot) + (0,) * (big.ndim - 2)
            return jax.lax.dynamic_update_slice(big, one.astype(big.dtype), idx)

        return dict(state,
                    dcache=jax.tree.map(splice, state["dcache"], dcache1))

    def _commit(self, state: dict, slot: int, cache1: dict, logits1,
                request: Request, dcache1: dict | None = None) -> dict:
        stop = -1 if request.stop_token is None else int(request.stop_token)
        scalars = (jnp.asarray(slot, jnp.int32),
                   jnp.asarray(len(request.prompt) - 1, jnp.int32),
                   jnp.asarray(request.max_new_tokens, jnp.int32),
                   jnp.asarray(stop, jnp.int32))
        if self.spec_k:
            return self._admit_commit_fn(state, cache1, dcache1, logits1,
                                         *scalars)
        return self._admit_commit_fn(state, cache1, logits1, *scalars)

    def sched_admit_start(self, state: dict, slot: int, request: Request):
        """Begin admitting ``request`` into ``slot``.  Returns
        ``(state, pending)``: ``pending is None`` means the admission
        completed atomically (whole-prompt fallback archs); otherwise feed it
        to :meth:`sched_admit_step` until it returns ``None`` — each call
        prefills one fixed-size prompt chunk, so a scheduler can interleave
        decode steps to bound co-batched time-to-first-token.

        The in-flight prefill runs against a private single-row cache and is
        spliced into the live batch only on the final chunk, so decode steps
        on the other rows proceed untouched throughout.

        With a prefix store, the store is consulted first: the longest
        hashed-prefix run of cached blocks is spliced into the private cache
        (jitted ``splice_block`` — NO prefill-chunk trace runs for a hit, so
        ``trace_counts`` stays honest) and chunked prefill resumes at the
        first miss.  The final chunk is always computed — the slot needs its
        last-position logits, which no KV slab carries."""
        plen = self._validate_request(request)
        if not self.chunked_admission:
            return self._admit_whole(state, slot, request), None
        C = self.prefill_chunk
        prompt = np.asarray(request.prompt, np.int32)
        chunks = []
        for start, valid in prefill_chunks_of(plen, C):
            toks = np.full((1, C), PAD_TOKEN, np.int32)
            toks[0, :valid] = prompt[start:start + valid]
            pos = np.full((1, C), -1, np.int32)
            pos[0, :valid] = np.arange(start, start + valid)
            chunks.append((jnp.asarray(toks), jnp.asarray(pos),
                           jnp.asarray(valid - 1, jnp.int32)))
        cache1 = init_cache(self.cfg, 1, self.max_len)
        hits, hashes = 0, []
        if self.prefix_store is not None:
            store = self.prefix_store
            hashes = store.block_hashes(prompt,
                                        n_blocks=self._publishable_blocks(plen))
            # reusable depth: full blocks strictly before the final chunk
            # (the final chunk always recomputes for its logits); the
            # publishable cap already bounded depth at the ring length
            n_reusable = min(len(chunks) - 1, len(hashes))
            hits = store.match(hashes[:n_reusable])
            for i in range(hits):
                slab = store.get(hashes[i])
                cache1 = self._splice_block_fn(
                    cache1, slab["k"], slab["v"],
                    jnp.asarray(i * C, jnp.int32))
            store.stats.reused_tokens += hits * C
        pending = {
            "request": request, "slot": slot, "plen": plen,
            "chunks": chunks, "i": hits, "hashes": hashes,
            "cache": cache1, "logits1": None,
            # draft prefill cursor: the draft has no prefix store, so it
            # computes EVERY chunk from 0 even when the target spliced hits —
            # prefix reuse composes with speculation without touching the
            # draft ring's contents
            "di": 0 if self.spec_k else len(chunks),
            "dcache": (init_cache(self.draft_cfg, 1, self.max_len)
                       if self.spec_k else None),
        }
        return state, pending

    def _publishable_blocks(self, plen: int) -> int:
        """How many leading full blocks of a ``plen``-token prompt the
        prefix store may hold: every full ``prefill_chunk`` block, capped on
        windowed configs at the blocks fully inside the first ``CL``
        positions — deeper blocks are overwritten in the ring before the
        prompt's tail attends them, so their slabs could neither be
        extracted after prefill-time wraparound nor spliced usefully."""
        n_full = plen // self.prefill_chunk
        if self.cfg.window:
            n_full = min(n_full, self._CL // self.prefill_chunk)
        return n_full

    def sched_admit_step(self, state: dict, pending: dict):
        """Advance an in-flight admission by one prompt chunk; on the final
        chunk splice the prefilled row into the live state and arm the slot.
        Returns ``(state, pending | None)``.

        When a prefix store is attached, each freshly-computed full block
        within reuse depth is extracted from the just-written ring slots and
        published, so the next request sharing the prefix splices instead of
        recomputing.

        With a draft model, each call also advances the draft's own prefill
        by one chunk (same tokens/positions, its private single-row cache),
        so admission completes with BOTH rings armed; total call count stays
        ``len(chunks)`` — the draft catches up during the calls the target
        skipped via prefix hits."""
        n = len(pending["chunks"])
        if pending["di"] < n:
            toks, pos, take = pending["chunks"][pending["di"]]
            pending["dcache"], _ = self._draft_prefill_chunk_fn(
                self.draft_params, pending["dcache"], toks, pos, take)
            pending["di"] += 1
        i = pending["i"]
        if i < n:
            toks, pos, take = pending["chunks"][i]
            pending["cache"], logits1 = self._prefill_chunk_fn(
                self.params, pending["cache"], toks, pos, take)
            if i < len(pending["hashes"]) and \
                    pending["hashes"][i] not in self.prefix_store:
                slab = self._extract_block_fn(
                    pending["cache"],
                    jnp.asarray(i * self.prefill_chunk, jnp.int32))
                self.prefix_store.put(pending["hashes"][i], slab)
            pending["i"] += 1
            if pending["i"] >= n:
                pending["logits1"] = logits1
        if pending["i"] < n or pending["di"] < n:
            return state, pending
        state = self._commit(state, pending["slot"], pending["cache"],
                             pending["logits1"][0], pending["request"],
                             dcache1=pending["dcache"])
        return state, None

    def prefix_match_len(self, request: Request) -> int:
        """Cached-prefix depth for ``request`` in TOKENS — how much prefill
        admission would skip right now.  A read-only probe (no LRU bump, no
        hit/miss accounting): the scheduler calls this per queued request to
        order admission by cache affinity, and a probe must not distort
        eviction order or the measured admission hit rate.  0 without a
        store."""
        if self.prefix_store is None:
            return 0
        plen = len(request.prompt)
        n_reusable = min((plen - 1) // self.prefill_chunk,
                         self._publishable_blocks(plen))
        if n_reusable <= 0:
            return 0
        hashes = self.prefix_store.block_hashes(request.prompt,
                                                n_blocks=n_reusable)
        return self.prefix_store.match(hashes, peek=True) * self.prefill_chunk

    def _admit_whole(self, state: dict, slot: int, request: Request) -> dict:
        """Whole-prompt fallback admission for architectures without
        chunked-prefill support: one single-row `prefill` (retraces per
        prompt length — the cost the chunked path avoids) committed through
        the same splice as the chunked path."""
        batch = {"tokens": jnp.asarray(np.asarray(request.prompt,
                                                  np.int32)[None]),
                 **self._stub_inputs(1)}
        cache1, logits = self._prefill(self.params, batch)
        return self._commit(state, slot, cache1, logits[0], request)

    def sched_admit(self, state: dict, slot: int, request: Request) -> dict:
        """Atomic admission: prefill ``request`` (chunked where supported)
        and splice it into batch row ``slot`` before returning."""
        state, pending = self.sched_admit_start(state, slot, request)
        while pending is not None:
            state, pending = self.sched_admit_step(state, pending)
        return state

    def sched_step(self, state: dict):
        self._key, k = jax.random.split(self._key)
        state, toks, alive = self._sched_step_fn(self.params, state, k)
        return state, np.asarray(toks), np.asarray(alive)

    #: sched_spec_step accepts per-slot draft windows (dynamic spec_k)
    spec_window_aware = True

    def sched_spec_step(self, state: dict, window=None):
        """One speculative round (ScheduleBackend accept/rollback protocol).
        Returns ``(state, cands [B, K], n_acc [B], n_emit [B], alive [B])``:
        slot ``b`` emits ``cands[b, :n_emit[b]]`` — every emitted token is
        the target's own greedy choice; ``n_acc - 1`` of them (live rows)
        were drafted.  ``window`` (length-B ints in ``[2, spec_k]``, None =
        full ``spec_k`` everywhere) caps each slot's accepted prefix — same
        compiled trace either way.  Greedy only; requires a ``draft`` at
        construction."""
        if not self.spec_k:
            raise RuntimeError("sched_spec_step requires draft= at engine "
                               "construction")
        if window is None:
            w = np.full((self.B,), self.spec_k, np.int32)
        else:
            w = np.asarray(window, np.int32)
            if w.shape != (self.B,):
                raise ValueError(f"window must have shape ({self.B},), got "
                                 f"{w.shape}")
        state, cands, n_acc, n_emit, alive = self._spec_step_fn(
            self.params, self.draft_params, state, w)
        return (state, np.asarray(cands), np.asarray(n_acc),
                np.asarray(n_emit), np.asarray(alive))

    def serve(self, requests: list[Request], *,
              on_token: Callable[[Request, int], None] | None = None,
              max_steps: int | None = None,
              admission_budget: int | None = None) -> list[Request]:
        """Run requests through the continuous-batching scheduler: FIFO
        admission, per-slot positions, finished slots refilled mid-flight.
        Any number of requests — slots turn over as requests finish.
        ``admission_budget`` caps prefill chunks per scheduler step (None =
        complete each admission immediately), bounding time-to-first-token
        for co-batched requests while a long prompt is admitted.
        Returns ``requests`` (same objects, ``out`` filled, in input order).
        """
        from repro.serving.scheduler import ContinuousScheduler

        sched = ContinuousScheduler(self, on_token=on_token,
                                    admission_budget=admission_budget)
        for r in requests:
            sched.submit(r)
        sched.run(max_steps=max_steps)
        return requests
