"""Seeded open-loop load generation against the continuous scheduler.

:func:`generate_trace` expands a :class:`~repro.serving.workload.Scenario`
into a flat, time-sorted list of :class:`ArrivalEvent` — every inter-arrival
gap, prompt token, and generation budget drawn from per-tenant
``SeedSequence`` streams, so the same ``(scenario, vocab, seed)`` triple
yields the byte-identical trace on every machine, forever.  Open loop
means arrivals do NOT wait for the system: when the server falls behind,
the queue grows and the latency percentiles say so (closed-loop replay —
what ``serving_bench.py`` did before this module — can never show
saturation, because a slow server throttles its own offered load).

:class:`LoadGenerator` replays a trace through a
:class:`~repro.serving.scheduler.ContinuousScheduler` under one of two
clocks:

  * ``clock="virtual"`` — simulated time.  A request is submitted to the
    scheduler only once the virtual clock reaches its arrival time (the
    *admission shim*: queueing delay is real queueing, not replay
    artifact), and each scheduler step advances the clock by a
    deterministic cost model — ``decode_step_cost_s`` per decode step plus
    ``prefill_chunk_cost_s`` per prefill chunk advanced.  Tokens emitted
    during a step become visible at the step's END, after its cost is
    applied, exactly like a real server.  Everything is deterministic, so
    the per-tenant percentile sections in ``BENCH_serving.json`` are
    byte-reproducible for a fixed seed and CI can diff them PR-over-PR.
    The default costs are placeholders for *relative* analysis (scheduling
    policy, admission budgets, tenant interference), not absolute
    hardware claims — calibrate them from a wall-clock run when absolute
    numbers matter.
  * ``clock="wall"`` — real time.  The generator sleeps until the next
    arrival and timestamps with ``time.perf_counter``; use this to measure
    an actual engine on actual hardware (``repro.launch.serve
    --scenario``).

Each request yields a :class:`RequestRecord` with its
arrival/submit/admit/first-token/done timestamps; TTFT is measured from
*arrival* (the user's clock starts when they hit enter, not when the
scheduler notices).  ``benchmarks/analysis.py`` turns record lists into
per-tenant SLO reports and saturation sweeps.

This module also hosts the repo's shared :func:`percentile` (linear
interpolation, the numpy default — hand-written so the numpy cross-check
in ``tests/test_workload.py`` is a genuine independent check) and
:func:`latency_summary`, used by the bench and launch layers.
"""

from __future__ import annotations

import math
import time
from dataclasses import dataclass, field
from typing import Any

from repro.serving.engine import Request
from repro.serving.scheduler import ContinuousScheduler, SchedulerStats
from repro.serving.workload import Scenario, shared_prefix_tokens, tenant_rng

__all__ = ["ArrivalEvent", "RequestRecord", "LoadResult", "LoadGenerator",
           "generate_trace", "percentile", "latency_summary"]


# -- shared statistics helpers ----------------------------------------------

def percentile(vals, p: float) -> float:
    """The p-th percentile (0..100) with linear interpolation between order
    statistics — numpy's default method, implemented independently."""
    if not 0.0 <= p <= 100.0:
        raise ValueError(f"percentile must be in [0, 100], got {p}")
    s = sorted(float(v) for v in vals)
    if not s:
        return 0.0
    if len(s) == 1:
        return s[0]
    rank = (p / 100.0) * (len(s) - 1)
    lo = int(math.floor(rank))
    hi = min(lo + 1, len(s) - 1)
    frac = rank - lo
    return s[lo] * (1.0 - frac) + s[hi] * frac


def latency_summary(vals, ndigits: int = 6) -> dict:
    """mean/p50/p95/p99/max of a latency sample (zeros when empty) — the
    shape every percentile section in ``BENCH_serving.json`` uses."""
    s = [float(v) for v in vals]
    if not s:
        return {"mean": 0.0, "p50": 0.0, "p95": 0.0, "p99": 0.0, "max": 0.0}
    return {
        "mean": round(sum(s) / len(s), ndigits),
        "p50": round(percentile(s, 50), ndigits),
        "p95": round(percentile(s, 95), ndigits),
        "p99": round(percentile(s, 99), ndigits),
        "max": round(max(s), ndigits),
    }


# -- trace generation --------------------------------------------------------

@dataclass(frozen=True)
class ArrivalEvent:
    """One request arrival: time, traffic class, and fully-drawn content."""
    t: float
    tenant: str
    tenant_index: int
    prompt: tuple[int, ...]
    new_tokens: int
    #: per-tenant arrival ordinal (stable merge tiebreak)
    seq: int


def generate_trace(scenario: Scenario, vocab_size: int,
                   seed: int = 0) -> list[ArrivalEvent]:
    """Expand ``scenario`` into its deterministic arrival trace.

    Per tenant, three disjoint RNG streams (arrival gaps, lengths+content,
    and one per prefix group) are derived from ``(seed, scenario name,
    tenant index)`` — adding a tenant or reordering the registry never
    perturbs another tenant's draws.  Events merge by ``(t, tenant_index,
    seq)`` and truncate to the ``max_requests`` earliest, which preserves
    the offered rate mix."""
    if vocab_size < 4:
        raise ValueError(f"vocab_size must be >= 4, got {vocab_size}")
    events: list[ArrivalEvent] = []
    for ti, ten in enumerate(scenario.tenants):
        arr_rng = tenant_rng(seed, scenario.name, ti, stream=0)
        len_rng = tenant_rng(seed, scenario.name, ti, stream=1)
        prefixes: list[list[int]] = []
        if ten.shared_prefix_len > 0:
            prefixes = [shared_prefix_tokens(seed, scenario.name, ti, g,
                                             ten.shared_prefix_len,
                                             vocab_size)
                        for g in range(ten.prefix_groups)]
        now, seq = 0.0, 0
        while True:
            now += ten.arrival.next_gap(arr_rng)
            if now > scenario.duration_s:
                break
            n_unique = ten.prompt_len.sample(len_rng)
            unique = [int(t) for t in len_rng.integers(
                2, max(vocab_size - 1, 3), size=n_unique)]
            if prefixes:
                group = int(len_rng.integers(len(prefixes)))
                prompt = tuple(prefixes[group]) + tuple(unique)
            else:
                prompt = tuple(unique)
            events.append(ArrivalEvent(
                t=now, tenant=ten.name, tenant_index=ti, prompt=prompt,
                new_tokens=ten.new_tokens.sample(len_rng), seq=seq))
            seq += 1
    events.sort(key=lambda e: (e.t, e.tenant_index, e.seq))
    return events[:scenario.max_requests]


# -- replay ------------------------------------------------------------------

@dataclass
class RequestRecord:
    """Per-request lifecycle timestamps (seconds on the run's clock)."""
    rid: int
    tenant: str
    prompt_len: int
    new_tokens_requested: int
    t_arrival: float
    t_submit: float = 0.0
    #: backend admission time (``t_submit + scheduler queue wait``)
    t_admit: float | None = None
    t_first_token: float | None = None
    t_done: float | None = None
    n_out: int = 0

    @property
    def ttft_s(self) -> float | None:
        """Time to first token from ARRIVAL (includes queueing)."""
        if self.t_first_token is None:
            return None
        return self.t_first_token - self.t_arrival

    @property
    def tpot_s(self) -> float | None:
        """Mean time per output token after the first (needs >= 2 tokens)."""
        if self.t_done is None or self.t_first_token is None or \
                self.n_out < 2:
            return None
        return (self.t_done - self.t_first_token) / (self.n_out - 1)

    @property
    def queue_wait_s(self) -> float | None:
        if self.t_admit is None:
            return None
        return self.t_admit - self.t_submit

    @property
    def e2e_s(self) -> float | None:
        if self.t_done is None:
            return None
        return self.t_done - self.t_arrival


@dataclass
class LoadResult:
    records: list[RequestRecord]
    #: first arrival to last completion, on the run's clock
    makespan_s: float
    #: requests/s the trace asked for (n / span of arrivals)
    offered_qps: float
    #: requests/s actually completed (n / makespan)
    achieved_qps: float
    stats: SchedulerStats
    clock: str
    emitted_tokens: int = 0

    def by_tenant(self) -> dict[str, list[RequestRecord]]:
        out: dict[str, list[RequestRecord]] = {}
        for r in self.records:
            out.setdefault(r.tenant, []).append(r)
        return out


class LoadGenerator:
    """Open-loop replay of an arrival trace against a scheduler backend.

    ``backend`` is any :class:`~repro.serving.scheduler.ScheduleBackend`
    (a real :class:`~repro.serving.engine.DecodeEngine` or a test fake).
    Scheduler knobs (``admission_budget``, ``cache_affinity``,
    ``dynamic_spec_k``) pass through so every serving feature can be
    measured under load.  See the module docstring for the two clocks."""

    def __init__(self, backend: Any, trace: list[ArrivalEvent], *,
                 clock: str = "virtual",
                 decode_step_cost_s: float = 0.01,
                 prefill_chunk_cost_s: float = 0.02,
                 stop_token: int | None = None,
                 admission_budget: int | None = None,
                 cache_affinity: bool = True,
                 dynamic_spec_k: bool = False):
        if clock not in ("virtual", "wall"):
            raise ValueError(f"clock must be 'virtual' or 'wall', got "
                             f"{clock!r}")
        if decode_step_cost_s <= 0 or prefill_chunk_cost_s <= 0:
            raise ValueError("virtual step costs must be > 0")
        self.backend = backend
        self.trace = list(trace)
        self.clock = clock
        self.decode_step_cost_s = decode_step_cost_s
        self.prefill_chunk_cost_s = prefill_chunk_cost_s
        self.stop_token = stop_token
        self._now = 0.0
        self._t0 = 0.0
        self._buffer: list[tuple[Request, int]] = []
        self.sched = ContinuousScheduler(
            backend, on_token=self._on_token,
            admission_budget=admission_budget,
            cache_affinity=cache_affinity,
            dynamic_spec_k=dynamic_spec_k,
            clock=self._read_clock)
        self.records: dict[int, RequestRecord] = {}

    def _read_clock(self) -> float:
        if self.clock == "virtual":
            return self._now
        return time.perf_counter() - self._t0

    def _on_token(self, req: Request, tok: int) -> None:
        # buffered: tokens become visible at end-of-step, after the step's
        # clock cost is applied (see run())
        self._buffer.append((req, tok))

    def _submit_due(self, i: int) -> int:
        """Submit every event whose arrival time has passed; returns the new
        trace cursor.  This IS the virtual-clock admission shim: the
        scheduler cannot see a request before its arrival time."""
        now = self._read_clock()
        while i < len(self.trace) and self.trace[i].t <= now:
            ev = self.trace[i]
            req = Request(prompt=list(ev.prompt),
                          max_new_tokens=ev.new_tokens,
                          stop_token=self.stop_token, tenant=ev.tenant)
            self.records[req.rid] = RequestRecord(
                rid=req.rid, tenant=ev.tenant, prompt_len=len(ev.prompt),
                new_tokens_requested=ev.new_tokens, t_arrival=ev.t,
                t_submit=now)
            self.sched.submit(req)
            i += 1
        return i

    def _drain_buffer(self) -> None:
        now = self._read_clock()
        for req, _tok in self._buffer:
            rec = self.records[req.rid]
            rec.n_out += 1
            if rec.t_first_token is None:
                rec.t_first_token = now
        self._buffer.clear()

    def run(self, max_steps: int | None = 200_000) -> LoadResult:
        if not self.trace:
            raise ValueError("empty arrival trace")
        self._now, self._t0 = 0.0, time.perf_counter()
        sched, stats = self.sched, self.sched.stats
        atomic = not hasattr(self.backend, "sched_admit_start")
        i, steps = 0, 0
        while i < len(self.trace) or sched.pending:
            if not sched.pending and i < len(self.trace) and \
                    self.trace[i].t > self._read_clock():
                # idle: jump (virtual) or sleep (wall) to the next arrival
                if self.clock == "virtual":
                    self._now = self.trace[i].t
                else:
                    time.sleep(max(self.trace[i].t - self._read_clock(), 0))
            i = self._submit_due(i)
            if not sched.pending:
                continue
            chunks0, steps0, adm0, admitted0 = (
                stats.prefill_chunks, stats.steps, stats.admission_steps,
                stats.admitted)
            finished = sched.step()
            if self.clock == "virtual":
                dchunks = stats.prefill_chunks - chunks0
                ddecode = (stats.steps - steps0) - \
                    (stats.admission_steps - adm0)
                # atomic-admission backends prefill whole prompts inside
                # sched_admit; charge one chunk per admission so admission
                # is never free
                datomic = (stats.admitted - admitted0) if atomic else 0
                cost = (dchunks + datomic) * self.prefill_chunk_cost_s \
                    + ddecode * self.decode_step_cost_s
                self._now += max(cost, 1e-9)
            self._drain_buffer()
            done_t = self._read_clock()
            for req in finished:
                self.records[req.rid].t_done = done_t
            # admit times are derivable once the scheduler recorded the wait
            for rid, wait in stats.queue_wait_by_rid.items():
                rec = self.records.get(rid)
                if rec is not None and rec.t_admit is None:
                    rec.t_admit = rec.t_submit + wait
            steps += 1
            if max_steps is not None and steps > max_steps:
                raise RuntimeError(
                    f"load run exceeded {max_steps} steps with "
                    f"{sched.num_queued} queued / {sched.num_active} active")
        records = sorted(self.records.values(), key=lambda r: r.rid)
        t_end = max((r.t_done for r in records if r.t_done is not None),
                    default=0.0)
        t_first = min(r.t_arrival for r in records)
        arrival_span = max(records[-1].t_arrival - t_first, 1e-9)
        makespan = max(t_end - t_first, 1e-9)
        return LoadResult(
            records=records, makespan_s=makespan,
            offered_qps=len(records) / arrival_span,
            achieved_qps=sum(r.t_done is not None for r in records)
            / makespan,
            stats=stats, clock=self.clock,
            emitted_tokens=stats.emitted_tokens)
