"""Hashed shared-prefix KV block store for cache-aware admission.

Ternary packing attacks the weight-bandwidth wall, which leaves *prefill
compute* as the dominant admission cost — and production traffic is
dominated by shared system prompts and multi-turn re-submission, where most
of that prefill recomputes KV another request just produced.  This module
holds those KV blocks so admission can splice instead of recompute.

The reuse unit is one **admission chunk** (``prefill_chunk`` tokens): the
engine already pads every prompt to chunk multiples and prefills it one
fixed-shape chunk at a time, so a chunk's KV is exactly the slab a later
request with the same token prefix would recompute.  Blocks are keyed by a
**chained content hash**: block ``i``'s key digests block ``i-1``'s key plus
block ``i``'s token ids, so a key identifies the *entire* prefix up to and
including the block — two prompts share block ``i`` iff their first
``(i+1)·C`` tokens are identical.  The chain is what makes lookup a pure
prefix match: hits are always a contiguous prefix of the prompt's blocks,
never an interior fragment that the attention causality would invalidate.

The store is host-side bookkeeping over device-resident slabs
(``{"k": [L, C, Hkv, hd], "v": [L, C, Hkv, hd]}`` — in mesh mode sharded on
kv-heads per :func:`repro.parallel.sharding.block_slab_specs`), with LRU
eviction under a byte budget.  It never touches model state itself: the
engine extracts slabs from its single-row admission cache after each miss
chunk (:func:`repro.models.decode.extract_kv_blocks`) and splices hits back
through the matching jitted entry point
(:func:`repro.models.decode.splice_kv_blocks`), both honouring the
canonical ring invariant (position ``p`` → slot ``p % CL``).  Windowed
configs cap reusable depth at the ring length ``CL``: blocks past the first
``CL`` positions would be overwritten before the prompt's tail attends
them, so they are neither published nor consulted.
"""

from __future__ import annotations

import hashlib
from collections import OrderedDict
from dataclasses import dataclass
from typing import Any, Sequence

import numpy as np

__all__ = ["PrefixBlockStore", "PrefixStoreStats", "chain_block_hashes"]


def chain_block_hashes(tokens: Sequence[int], block_tokens: int,
                       n_blocks: int | None = None,
                       namespace: bytes = b"") -> list[bytes]:
    """Chained content hashes for the full ``block_tokens``-sized blocks of a
    token-id sequence (the trailing partial block, if any, is never hashed —
    it is not a reuse unit).

    ``hash[i] = H(hash[i-1] || tokens[i*C:(i+1)*C])``, so ``hash[i]`` is a
    content address for the whole ``(i+1)*C``-token prefix.  The digest
    depends only on the token ids (plus ``namespace``, which callers use to
    separate incompatible KV producers — model config / chunk size): it is
    invariant to batch composition, admission order, scheduler state, and
    everything else about the serving context.  ``n_blocks`` truncates the
    chain (e.g. the windowed reuse-depth cap).
    """
    toks = np.ascontiguousarray(np.asarray(tokens, dtype=np.int32))
    total = len(toks) // block_tokens
    if n_blocks is not None:
        total = min(total, n_blocks)
    prev = hashlib.blake2b(namespace + np.int32(block_tokens).tobytes(),
                           digest_size=16).digest()
    out: list[bytes] = []
    for i in range(total):
        h = hashlib.blake2b(digest_size=16)
        h.update(prev)
        h.update(toks[i * block_tokens:(i + 1) * block_tokens].tobytes())
        prev = h.digest()
        out.append(prev)
    return out


@dataclass
class PrefixStoreStats:
    #: block-granular lookup tally over admissions (peeks excluded)
    hit_blocks: int = 0
    miss_blocks: int = 0
    published_blocks: int = 0
    evicted_blocks: int = 0
    #: token-granular: prompt tokens whose prefill was skipped via splice
    reused_tokens: int = 0

    @property
    def lookups(self) -> int:
        return self.hit_blocks + self.miss_blocks

    @property
    def hit_rate(self) -> float:
        """Block hit rate over all admission lookups (0.0 when none ran)."""
        n = self.lookups
        return self.hit_blocks / n if n else 0.0


class PrefixBlockStore:
    """Content-addressed KV block cache: chained prefix hashes → KV slabs,
    LRU-evicted under ``max_bytes``.

    Slabs are opaque pytrees of arrays (the store only sums ``nbytes`` for
    the budget), so device placement/sharding is the caller's concern — the
    engine stores its slabs exactly as its jitted extract produced them.
    """

    def __init__(self, block_tokens: int, max_bytes: int = 64 << 20,
                 namespace: bytes = b""):
        if block_tokens < 1:
            raise ValueError("block_tokens must be >= 1")
        if max_bytes < 1:
            raise ValueError("max_bytes must be >= 1")
        self.block_tokens = int(block_tokens)
        self.max_bytes = int(max_bytes)
        self.namespace = bytes(namespace)
        #: insertion/recency-ordered: oldest-used first (LRU eviction order)
        self._blocks: OrderedDict[bytes, tuple[Any, int]] = OrderedDict()
        self.nbytes = 0
        self.stats = PrefixStoreStats()

    # -- hashing ------------------------------------------------------------

    def block_hashes(self, tokens: Sequence[int],
                     n_blocks: int | None = None) -> list[bytes]:
        return chain_block_hashes(tokens, self.block_tokens,
                                  n_blocks=n_blocks,
                                  namespace=self.namespace)

    # -- lookup -------------------------------------------------------------

    def __len__(self) -> int:
        return len(self._blocks)

    def __contains__(self, h: bytes) -> bool:
        return h in self._blocks

    def match(self, hashes: Sequence[bytes], *, peek: bool = False) -> int:
        """Longest prefix of ``hashes`` present in the store.

        A chained hash makes any interior hit meaningless (its prefix would
        have to be present too), so matching stops at the first absence.
        Counts hit/miss stats and bumps LRU recency on the hit blocks unless
        ``peek`` (the scheduler's affinity probe — a queue reorder decision
        must not distort eviction order or the measured admission hit rate).
        """
        n = 0
        for h in hashes:
            if h not in self._blocks:
                break
            n += 1
        if not peek:
            self.stats.hit_blocks += n
            self.stats.miss_blocks += len(hashes) - n
            for h in hashes[:n]:
                self._blocks.move_to_end(h)
        return n

    def get(self, h: bytes) -> Any | None:
        """The slab for ``h`` (bumping recency), or None."""
        entry = self._blocks.get(h)
        if entry is None:
            return None
        self._blocks.move_to_end(h)
        return entry[0]

    # -- publication --------------------------------------------------------

    @staticmethod
    def _slab_bytes(slab: Any) -> int:
        import jax

        return sum(int(np.prod(x.shape)) * x.dtype.itemsize
                   for x in jax.tree.leaves(slab))

    def put(self, h: bytes, slab: Any) -> bool:
        """Publish a block; evicts LRU entries to honour the byte budget.
        Returns False (and stores nothing) if the block is already present
        or is larger than the whole budget."""
        if h in self._blocks:
            self._blocks.move_to_end(h)
            return False
        size = self._slab_bytes(slab)
        if size > self.max_bytes:
            return False
        while self.nbytes + size > self.max_bytes and self._blocks:
            _, (_, old_size) = self._blocks.popitem(last=False)
            self.nbytes -= old_size
            self.stats.evicted_blocks += 1
        self._blocks[h] = (slab, size)
        self.nbytes += size
        self.stats.published_blocks += 1
        return True

    def clear(self) -> None:
        self._blocks.clear()
        self.nbytes = 0
