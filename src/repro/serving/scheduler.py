"""Continuous-batching decode scheduler.

Generational batching (``DecodeEngine.run``) admits one batch, decodes until
the *slowest* request finishes, and only then admits more — on skewed
workloads most slots idle while one long request drags on, and measured
tok/s collapses (``benchmarks/serving_bench.py`` quantifies this).  The
scheduler here keeps every slot busy instead:

  * **FIFO admission queue** — ``submit()`` order is admission order;
  * **per-slot lifecycle** — the moment a slot's request finishes (stop
    token or token budget), the slot is refilled from the queue mid-flight,
    without touching the other rows or re-prefilling the batch;
  * **chunked, budgeted admission** — on backends that implement incremental
    admission (``sched_admit_start`` / ``sched_admit_step``,
    e.g. :class:`repro.serving.engine.DecodeEngine` via
    :func:`repro.models.decode.prefill_chunk`), a prompt is prefilled a
    fixed-size chunk at a time and ``admission_budget`` caps chunks per
    step, so a long arriving prompt cannot stall co-batched decode — their
    time-to-next-token stays bounded by one decode step plus ``budget``
    chunks;
  * **streaming callbacks** — ``on_token(request, token)`` fires as each
    token is emitted (per-request ``Request.on_token`` overrides the
    scheduler-wide callback);
  * **on-device stop masking** — the stop-token compare, budget countdown,
    and liveness mask are computed inside the backend's jitted step, so the
    decode loop never branches on the host per token; the host reads back
    one small ``(tokens, alive)`` pair per step to drive streaming and
    refills.

The scheduler is pure host-side bookkeeping over a narrow backend protocol
(:class:`ScheduleBackend`), implemented for real models by
:class:`repro.serving.engine.DecodeEngine` — which lets the scheduling
invariants be property-tested against a deterministic fake backend without
running a model (``tests/test_serving_scheduler.py``).
"""

from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Callable, Protocol, runtime_checkable

from repro.serving.engine import Request

__all__ = ["ContinuousScheduler", "ScheduleBackend", "SchedulerStats", "Request"]


@runtime_checkable
class ScheduleBackend(Protocol):
    """What the scheduler drives.  ``state`` is opaque to the scheduler.

    ``sched_step`` returns ``(state, tokens, alive)`` where ``tokens[b]`` is
    the token just emitted by slot ``b`` and ``alive[b]`` is False once slot
    ``b``'s request has finished (stop token hit or budget exhausted).
    Entries for slots the scheduler holds no request in are ignored.

    A backend may additionally implement **incremental admission** —
    ``sched_admit_start(state, slot, request) -> (state, pending | None)``
    and ``sched_admit_step(state, pending) -> (state, pending | None)`` —
    where each ``sched_admit_step`` prefills one prompt chunk and ``None``
    marks the slot armed.  The scheduler then interleaves admission chunks
    with decode steps under ``admission_budget``; backends without the pair
    are admitted atomically via ``sched_admit``.

    A backend may also expose **cache affinity** —
    ``prefix_match_len(request) -> int``, the number of prompt tokens whose
    prefill a prefix cache would skip right now (a read-only probe) — which
    lets the scheduler admit cache-hot requests first (see
    ``ContinuousScheduler(cache_affinity=...)``).

    A backend may also implement the **speculative accept/rollback step** —
    an int attribute ``spec_k >= 2`` plus ``sched_spec_step(state) ->
    (state, tokens, n_acc, n_emit, alive)`` where ``tokens`` is ``[B,
    spec_k]`` candidate tokens per slot, slot ``b`` emits exactly
    ``tokens[b, :n_emit[b]]`` this step (``1 <= n_emit <= spec_k`` for live
    slots; the backend has already rolled back every rejected candidate's
    state), and ``n_acc[b] - 1`` counts the accepted *drafted* tokens (the
    acceptance-rate numerator).  When present, the scheduler drives
    ``sched_spec_step`` instead of ``sched_step`` and fans the ragged
    multi-token windows out to the per-token streaming callbacks.

    A speculative backend may further advertise **per-slot draft windows**
    with a truthy ``spec_window_aware`` attribute, meaning
    ``sched_spec_step(state, window)`` accepts a length-``B`` sequence of
    ints in ``[2, spec_k]`` and slot ``b`` drafts/verifies only
    ``window[b]`` positions this round (``n_acc[b] <= window[b]``).  This
    is what ``ContinuousScheduler(dynamic_spec_k=True)`` drives: requests
    whose measured acceptance is low get a short window next round, so a
    hostile request stops paying for ``spec_k - 1`` wasted drafts forever.
    """

    batch_size: int

    def sched_start(self) -> Any: ...

    def sched_admit(self, state: Any, slot: int, request: Request) -> Any: ...

    def sched_step(self, state: Any) -> tuple[Any, Any, Any]: ...


@dataclass
class SchedulerStats:
    #: every :meth:`ContinuousScheduler.step` call — decode steps AND
    #: admission-only steps (no slot live yet, prefill chunks advancing)
    steps: int = 0
    #: steps that did admission work but ran no decode; wall-clock spent
    #: here is prefill, not decode, so throughput math must not divide by it
    admission_steps: int = 0
    admitted: int = 0
    completed: int = 0
    emitted_tokens: int = 0
    #: prefill chunks advanced through incremental admission
    prefill_chunks: int = 0
    #: per-request wall-clock wait from ``submit()`` to backend admission,
    #: in admission order — the fairness cost of cache-affinity reordering
    #: is visible here next to the TTFT it buys (zero-budget requests never
    #: occupy a slot and are excluded).  Recorded uniformly on EVERY
    #: admission path (pure FIFO, affinity reorder, atomic, incremental),
    #: and mirrored per request in :attr:`queue_wait_by_rid` so per-tenant
    #: analysis can attribute waits instead of reporting zeros
    queue_wait_s: list[float] = field(default_factory=list)
    #: the same waits keyed on ``Request.rid`` (what the load-generator's
    #: per-tenant SLO analysis joins against)
    queue_wait_by_rid: dict[int, float] = field(default_factory=dict)
    #: admissions that jumped ahead of an older queued request on cache
    #: affinity (0 under pure FIFO)
    affinity_reorders: int = 0
    #: speculative rounds run (0 on non-speculative backends)
    spec_rounds: int = 0
    #: candidates the draft proposed across live slots (``spec_k - 1`` per
    #: live slot per round)
    drafted_tokens: int = 0
    #: drafted candidates the target verified and accepted (``n_acc - 1``
    #: summed over live slots) — ``accepted/drafted`` is the acceptance rate
    accepted_drafted_tokens: int = 0
    #: per-request accepted-drafted-token counts keyed on ``Request.rid``
    accepted_by_rid: dict[int, int] = field(default_factory=dict)
    #: per-request draft window used in the most recent speculative round
    #: (only populated under ``dynamic_spec_k=True``)
    spec_window_by_rid: dict[int, int] = field(default_factory=dict)

    @property
    def decode_steps(self) -> int:
        """Steps that ran a backend decode (``sched_step`` or
        ``sched_spec_step``) — the number serving benchmarks report as
        decode steps."""
        return self.steps - self.admission_steps

    @property
    def acceptance_rate(self) -> float:
        """Fraction of drafted candidates the target accepted (0.0 when
        nothing was drafted)."""
        if not self.drafted_tokens:
            return 0.0
        return self.accepted_drafted_tokens / self.drafted_tokens

    def queue_wait_summary(self) -> dict:
        """mean/p50/max of per-request queue wait (seconds; zeros when no
        request was admitted) — the shape serving benchmarks report."""
        if not self.queue_wait_s:
            return {"mean": 0.0, "p50": 0.0, "max": 0.0}
        w = sorted(self.queue_wait_s)
        return {"mean": sum(w) / len(w), "p50": w[len(w) // 2], "max": w[-1]}


class ContinuousScheduler:
    """FIFO continuous-batching scheduler over a :class:`ScheduleBackend`."""

    def __init__(self, backend: ScheduleBackend,
                 on_token: Callable[[Request, int], None] | None = None,
                 admission_budget: int | None = None,
                 cache_affinity: bool = True, affinity_window: int = 8,
                 max_affinity_skips: int = 4,
                 clock: Callable[[], float] | None = None,
                 dynamic_spec_k: bool = False,
                 spec_acc_ewma: float = 0.5):
        """``admission_budget`` caps how many prefill chunks advance per
        :meth:`step` across all in-flight admissions (None = finish each
        admission within the step it starts).  With a budget, a long prompt
        is admitted a few chunks at a time while co-batched live slots keep
        decoding — bounding their time-to-first/next-token.  Only effective
        on backends implementing incremental admission (see
        :class:`ScheduleBackend`).

        ``cache_affinity`` orders admission by prefix-cache affinity on
        backends that expose ``prefix_match_len(request) -> int`` (e.g. a
        :class:`~repro.serving.engine.DecodeEngine` with a prefix store):
        each free slot admits the deepest-matching request among the first
        ``affinity_window`` queued, so a request whose shared prefix is hot
        runs while the blocks are still resident.  The FIFO fairness bound:
        ties (including the no-store all-zero case) go to the oldest
        request, and once the queue head has been jumped
        ``max_affinity_skips`` times it is admitted unconditionally — every
        request reaches the head after at most ``queue position``
        admissions, so no request starves behind an endless stream of
        cache-hot arrivals.

        ``clock`` is the time source for queue-wait accounting (default
        ``time.perf_counter``).  A virtual-clock load generator injects its
        own clock here so submit→admit waits are measured in simulated
        seconds, not wall time.

        ``dynamic_spec_k`` (speculative backends advertising
        ``spec_window_aware`` only) sizes each request's next draft window
        from its measured acceptance: an EWMA of the per-round accepted
        fraction (weight ``spec_acc_ewma`` on the newest round, optimistic
        start at 1.0) maps to a window clamped to ``[2, spec_k]`` — a
        request whose drafts keep getting rejected quickly shrinks to
        window 2 (one drafted token per round) while well-predicted
        requests keep the full ``spec_k``."""
        if admission_budget is not None and admission_budget < 1:
            raise ValueError("admission_budget must be >= 1 (or None)")
        if affinity_window < 1:
            raise ValueError("affinity_window must be >= 1")
        if max_affinity_skips < 0:
            raise ValueError("max_affinity_skips must be >= 0")
        if not 0.0 < spec_acc_ewma <= 1.0:
            raise ValueError("spec_acc_ewma must be in (0, 1]")
        if dynamic_spec_k and getattr(backend, "spec_k", 0) >= 2 and \
                not getattr(backend, "spec_window_aware", False):
            raise ValueError(
                "dynamic_spec_k needs a backend whose sched_spec_step "
                "accepts per-slot windows (spec_window_aware)")
        self.backend = backend
        self.B = backend.batch_size
        self.on_token = on_token
        self.admission_budget = admission_budget
        self.cache_affinity = cache_affinity
        self.affinity_window = affinity_window
        self.max_affinity_skips = max_affinity_skips
        self.clock = clock if clock is not None else time.perf_counter
        self.dynamic_spec_k = dynamic_spec_k
        self.spec_acc_ewma = spec_acc_ewma
        #: request.rid → EWMA of per-round accepted-draft fraction
        self._acc_ewma: dict[int, float] = {}
        #: request.rid → times an affinity pick jumped it while queued
        self._skips: dict[int, int] = {}
        #: request.rid → clock() at submit (queue-wait accounting)
        self._enqueue_t: dict[int, float] = {}
        self.queue: deque[Request] = deque()
        self.slots: list[Request | None] = [None] * self.B
        #: slot → (request, backend pending) for prefills in flight; dict
        #: order is admission order, so budget drains FIFO
        self.prefilling: dict[int, tuple[Request, Any]] = {}
        self.completed: list[Request] = []
        #: requests in the order they were handed to the backend (FIFO proof)
        self.admission_order: list[Request] = []
        self.stats = SchedulerStats()
        self._state: Any = None

    # -- introspection ------------------------------------------------------

    @property
    def num_active(self) -> int:
        return sum(r is not None for r in self.slots)

    @property
    def num_prefilling(self) -> int:
        return len(self.prefilling)

    @property
    def num_queued(self) -> int:
        return len(self.queue)

    @property
    def pending(self) -> bool:
        return bool(self.queue) or self.num_active > 0 or bool(self.prefilling)

    # -- driving ------------------------------------------------------------

    def submit(self, request: Request) -> None:
        """Enqueue a request (FIFO arrival order; admission may reorder
        within the affinity window).  Safe to call mid-run, between steps."""
        if request.done:
            raise ValueError("request already completed; submit a fresh one")
        self._enqueue_t[request.rid] = self.clock()
        self.queue.append(request)

    def _pop_next(self) -> Request:
        """Pop the next request to admit.  Pure FIFO unless cache affinity
        is on and the backend can score prefix matches; then the deepest
        match within the lookahead window wins, ties to the oldest, and a
        head that has been jumped ``max_affinity_skips`` times is forced
        (the starvation bound)."""
        match_len = getattr(self.backend, "prefix_match_len", None)
        if not self.cache_affinity or match_len is None or len(self.queue) == 1:
            return self.queue.popleft()
        head = self.queue[0]
        if self._skips.get(head.rid, 0) >= self.max_affinity_skips:
            self._skips.pop(head.rid, None)
            return self.queue.popleft()
        best_i, best = 0, -1
        for i in range(min(len(self.queue), self.affinity_window)):
            m = match_len(self.queue[i])
            if m > best:
                best_i, best = i, m
        req = self.queue[best_i]
        del self.queue[best_i]
        self._skips.pop(req.rid, None)
        if best_i > 0:
            self.stats.affinity_reorders += 1
            for j in range(best_i):  # everyone older than the pick was jumped
                jumped = self.queue[j]
                self._skips[jumped.rid] = self._skips.get(jumped.rid, 0) + 1
        return req

    def _record_admission(self, req: Request) -> None:
        t0 = self._enqueue_t.pop(req.rid, None)
        if t0 is not None:
            wait = self.clock() - t0
            self.stats.queue_wait_s.append(wait)
            self.stats.queue_wait_by_rid[req.rid] = wait

    def _admit_free_slots(self) -> None:
        start = getattr(self.backend, "sched_admit_start", None)
        for slot in range(self.B):
            if self.slots[slot] is not None or slot in self.prefilling:
                continue
            while self.queue:
                req = self._pop_next()
                if req.max_new_tokens <= 0:  # zero-budget: completes at once
                    req.done = True
                    self._enqueue_t.pop(req.rid, None)
                    self.completed.append(req)
                    self.stats.completed += 1
                    continue
                if start is None:  # atomic-admission backend
                    self._state = self.backend.sched_admit(self._state, slot,
                                                           req)
                    self.slots[slot] = req
                else:
                    self._state, pend = start(self._state, slot, req)
                    if pend is None:
                        self.slots[slot] = req
                    else:
                        self.prefilling[slot] = (req, pend)
                self.admission_order.append(req)
                self._record_admission(req)
                self.stats.admitted += 1
                break

    def _advance_prefills(self) -> None:
        """Advance in-flight admissions FIFO, at most ``admission_budget``
        prefill chunks this step (None = drain them all)."""
        budget = self.admission_budget
        for slot in list(self.prefilling):
            while True:
                if budget is not None and budget <= 0:
                    return
                req, pend = self.prefilling[slot]
                self._state, pend = self.backend.sched_admit_step(self._state,
                                                                  pend)
                self.stats.prefill_chunks += 1
                if budget is not None:
                    budget -= 1
                if pend is None:  # admission complete: slot is live
                    del self.prefilling[slot]
                    self.slots[slot] = req
                    break
                self.prefilling[slot] = (req, pend)

    def step(self) -> list[Request]:
        """Admit into free slots, advance in-flight prefills under the
        admission budget, run one decode step, deliver tokens.

        Returns the requests that finished this step (possibly empty)."""
        if self._state is None:
            self._state = self.backend.sched_start()
        self._admit_free_slots()
        self._advance_prefills()
        if self.num_active == 0:
            # pure-admission step: prefill chunks advanced, nothing to decode
            # — still a step (it consumed wall-clock), tallied separately so
            # decode throughput math stays honest
            self.stats.steps += 1
            self.stats.admission_steps += 1
            return []
        if getattr(self.backend, "spec_k", 0) >= 2 and \
                hasattr(self.backend, "sched_spec_step"):
            return self._spec_step()
        self._state, tokens, alive = self.backend.sched_step(self._state)
        finished: list[Request] = []
        for slot, req in enumerate(self.slots):
            if req is None:
                continue
            tok = int(tokens[slot])
            req.out.append(tok)
            self.stats.emitted_tokens += 1
            cb = req.on_token or self.on_token
            if cb is not None:
                cb(req, tok)
            if not bool(alive[slot]):
                req.done = True
                self.slots[slot] = None
                self.completed.append(req)
                self.stats.completed += 1
                finished.append(req)
        self.stats.steps += 1
        return finished

    def _spec_window(self, req: Request, K: int) -> int:
        """Next-round draft window for ``req`` from its acceptance EWMA:
        optimistic full window until evidence arrives, then
        ``2 + round(ewma * (K - 2))`` — clamped to ``[2, K]`` so every
        round still verifies at least one drafted token (window 2 = the
        cheapest speculative round; falling back to plain decode would
        forfeit the chance to ever re-measure acceptance)."""
        ewma = self._acc_ewma.get(req.rid, 1.0)
        return max(2, min(K, 2 + int(round(ewma * (K - 2)))))

    def _spec_step(self) -> list[Request]:
        """One speculative round: every live slot emits a ragged 1..spec_k
        token window (the backend already rolled back rejected candidates),
        streaming callbacks fire per token in order, and acceptance is
        tallied globally and per request (``stats.accepted_by_rid``).
        Under ``dynamic_spec_k`` each slot's window is sized from its
        request's acceptance history before the round runs."""
        K = self.backend.spec_k
        if self.dynamic_spec_k:
            window = [self._spec_window(req, K) if req is not None else K
                      for req in self.slots]
            self._state, tokens, n_acc, n_emit, alive = \
                self.backend.sched_spec_step(self._state, window)
        else:
            window = [K] * self.B
            self._state, tokens, n_acc, n_emit, alive = \
                self.backend.sched_spec_step(self._state)
        self.stats.spec_rounds += 1
        finished: list[Request] = []
        for slot, req in enumerate(self.slots):
            if req is None:
                continue
            w = window[slot]
            accepted = max(int(n_acc[slot]) - 1, 0)
            self.stats.drafted_tokens += w - 1
            self.stats.accepted_drafted_tokens += accepted
            self.stats.accepted_by_rid[req.rid] = \
                self.stats.accepted_by_rid.get(req.rid, 0) + accepted
            if self.dynamic_spec_k:
                self.stats.spec_window_by_rid[req.rid] = w
                frac = accepted / (w - 1)
                a = self.spec_acc_ewma
                self._acc_ewma[req.rid] = \
                    a * frac + (1.0 - a) * self._acc_ewma.get(req.rid, 1.0)
            cb = req.on_token or self.on_token
            for j in range(int(n_emit[slot])):
                tok = int(tokens[slot, j])
                req.out.append(tok)
                self.stats.emitted_tokens += 1
                if cb is not None:
                    cb(req, tok)
            if not bool(alive[slot]):
                req.done = True
                self.slots[slot] = None
                self._acc_ewma.pop(req.rid, None)
                self.completed.append(req)
                self.stats.completed += 1
                finished.append(req)
        self.stats.steps += 1
        return finished

    def run(self, max_steps: int | None = None) -> list[Request]:
        """Drain: step until every submitted request completes.

        Returns completed requests in completion order (``admission_order``
        has FIFO order).  ``max_steps`` bounds runaway loops (RuntimeError).
        """
        steps = 0
        while self.pending:
            if max_steps is not None and steps >= max_steps:
                raise RuntimeError(
                    f"scheduler did not drain in {max_steps} steps: "
                    f"{self.num_active} active, {self.num_prefilling} "
                    f"prefilling, {self.num_queued} queued")
            self.step()
            steps += 1
        return list(self.completed)
