"""Declarative multi-tenant serving workloads (named scenarios).

Every serving performance claim in this repo used to come from one fixed
skewed-length request list replayed at batch≈4.  Real load is nothing like
that: requests *arrive* — Poisson for open user populations, bursty for
agentic tool loops, near-constant for machine traffic — from several
tenants at once, each with its own prompt/generation length distributions,
shared-prefix structure (system prompts, RAG templates, resent
conversation state), and latency SLOs.  This module describes such traffic
declaratively, the way ``llm-d-benchmark``'s workload profiles do, so a
scenario is data that every harness (benchmark, launcher, saturation
sweep, test) interprets identically:

  * :class:`Dist` — a bounded integer length distribution (``fixed`` /
    ``uniform`` / ``lognormal`` / ``choice``).  Bounded on purpose: the
    engine's ``max_len`` and the KV ring geometry are derived from
    ``upper()`` before any request is drawn.
  * :class:`ArrivalProcess` — ``poisson`` (exponential inter-arrivals),
    ``gamma_burst`` (gamma inter-arrivals with coefficient of variation
    ``cv`` > 1: bursts separated by lulls, same mean rate), or ``fixed``
    (constant spacing).
  * :class:`TenantSpec` — one traffic class: its arrival process, length
    distributions, shared-prefix structure (``shared_prefix_len`` tokens
    drawn per ``prefix_groups`` distinct group), and per-tenant TTFT/TPOT
    SLO thresholds.
  * :class:`Scenario` — a named set of tenants plus a generation horizon.
    ``scaled(f)`` multiplies every tenant's arrival rate by ``f`` (the
    saturation-sweep knob); ``smoke()`` shrinks lengths/volume to the
    CPU-CI operating point without changing the traffic *shape*.

Everything downstream is seeded and deterministic: the same ``(scenario,
vocab, seed)`` triple always yields the byte-identical arrival trace (see
:mod:`repro.serving.loadgen`), which is what lets CI diff percentile
sections PR-over-PR instead of chasing sampling noise.

The four built-in scenarios mirror the paper family's deployment stories
(Bitnet.cpp-style edge chat, RAG long-prefill, agentic bursts,
code-completion short-gen); ``get_scenario(name)`` resolves them.
"""

from __future__ import annotations

import zlib
from dataclasses import dataclass, field, replace

import numpy as np

__all__ = ["Dist", "ArrivalProcess", "TenantSpec", "Scenario",
           "SCENARIOS", "get_scenario", "tenant_rng", "shared_prefix_tokens"]


@dataclass(frozen=True)
class Dist:
    """Bounded integer distribution.  ``kind`` ∈ {fixed, uniform, lognormal,
    choice}; ``a``/``b`` are (value,), (lo, hi), (median, hi) respectively;
    ``sigma`` is the lognormal shape; ``choices`` the choice support."""

    kind: str
    a: int = 1
    b: int = 1
    sigma: float = 0.5
    choices: tuple[int, ...] = ()

    def __post_init__(self):
        if self.kind not in ("fixed", "uniform", "lognormal", "choice"):
            raise ValueError(f"unknown Dist kind {self.kind!r}")
        if self.kind == "choice" and not self.choices:
            raise ValueError("choice Dist needs a non-empty support")
        if self.kind in ("uniform", "lognormal") and self.b < self.a:
            raise ValueError(f"Dist upper bound {self.b} < lower {self.a}")

    def sample(self, rng: np.random.Generator) -> int:
        if self.kind == "fixed":
            return int(self.a)
        if self.kind == "uniform":
            return int(rng.integers(self.a, self.b + 1))
        if self.kind == "choice":
            return int(self.choices[rng.integers(len(self.choices))])
        # lognormal around median ``a`` (lognormal's median IS exp(mu)),
        # clipped into [1, b] so the engine geometry bound holds
        v = int(round(self.a * float(np.exp(self.sigma
                                            * rng.standard_normal()))))
        return int(min(max(v, 1), self.b))

    def upper(self) -> int:
        """Hard upper bound of the support (engine max_len derivation)."""
        if self.kind == "fixed":
            return int(self.a)
        if self.kind == "choice":
            return int(max(self.choices))
        return int(self.b)

    def shrunk(self, factor: int, lo: int = 2) -> "Dist":
        """Divide the support by ``factor`` with a floor — the smoke
        transformation (same shape, CPU-CI sized)."""
        sc = lambda v: max(int(v) // factor, lo)
        if self.kind == "choice":
            return replace(self, choices=tuple(sorted({sc(c)
                                                       for c in self.choices})))
        a, b = sc(self.a), sc(self.b)
        return replace(self, a=a, b=max(a, b))


@dataclass(frozen=True)
class ArrivalProcess:
    """Open-loop arrival process at mean ``rate`` requests/second.

    ``poisson``: exponential inter-arrivals (memoryless user population).
    ``gamma_burst``: gamma inter-arrivals with coefficient of variation
    ``cv`` — shape ``1/cv²``, scale ``cv²/rate`` (mean ``1/rate``); cv > 1
    clumps arrivals into bursts separated by long gaps, the agentic
    tool-loop shape.  ``fixed``: constant ``1/rate`` spacing.
    """

    kind: str
    rate: float
    cv: float = 2.0

    def __post_init__(self):
        if self.kind not in ("poisson", "gamma_burst", "fixed"):
            raise ValueError(f"unknown arrival kind {self.kind!r}")
        if self.rate <= 0:
            raise ValueError(f"arrival rate must be > 0, got {self.rate}")
        if self.kind == "gamma_burst" and self.cv <= 0:
            raise ValueError(f"gamma_burst cv must be > 0, got {self.cv}")

    def next_gap(self, rng: np.random.Generator) -> float:
        if self.kind == "fixed":
            return 1.0 / self.rate
        if self.kind == "poisson":
            return float(rng.exponential(1.0 / self.rate))
        shape = 1.0 / (self.cv ** 2)
        scale = (self.cv ** 2) / self.rate
        return float(rng.gamma(shape, scale))

    def scaled(self, f: float) -> "ArrivalProcess":
        return replace(self, rate=self.rate * f)


@dataclass(frozen=True)
class TenantSpec:
    """One traffic class: arrivals, lengths, prefix sharing, SLOs.

    ``prompt_len`` draws the UNIQUE part of each prompt; the total prompt is
    ``shared_prefix_len + prompt_len`` tokens, with the shared prefix drawn
    once per ``(tenant, group)`` — ``prefix_groups`` distinct prefixes
    rotate uniformly, so a prefix cache sees realistic partial sharing
    rather than one global system prompt.  ``slo_ttft_s`` / ``slo_tpot_s``
    are the per-tenant attainment thresholds the analysis layer scores
    against."""

    name: str
    arrival: ArrivalProcess
    prompt_len: Dist
    new_tokens: Dist
    shared_prefix_len: int = 0
    prefix_groups: int = 1
    slo_ttft_s: float = 1.0
    slo_tpot_s: float = 0.1

    def max_prompt_len(self) -> int:
        return self.shared_prefix_len + self.prompt_len.upper()


@dataclass(frozen=True)
class Scenario:
    """A named multi-tenant workload over a generation horizon.

    Arrivals are generated per tenant until ``duration_s`` of virtual time,
    merged by arrival time, and truncated to the ``max_requests`` earliest
    (truncation preserves the rate mix).  ``smoke_*`` parameterize the
    CPU-CI shrink applied by :meth:`smoke`."""

    name: str
    description: str
    tenants: tuple[TenantSpec, ...]
    duration_s: float = 60.0
    max_requests: int = 2048
    smoke_len_factor: int = 8
    smoke_duration_s: float = 4.0
    smoke_max_requests: int = 24

    def __post_init__(self):
        if not self.tenants:
            raise ValueError(f"scenario {self.name!r} has no tenants")
        names = [t.name for t in self.tenants]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate tenant names in {self.name!r}")

    def scaled(self, f: float) -> "Scenario":
        """Multiply every tenant's arrival rate by ``f`` (saturation-sweep
        knob); lengths and SLOs are untouched."""
        return replace(self, tenants=tuple(
            replace(t, arrival=t.arrival.scaled(f)) for t in self.tenants))

    def smoke(self) -> "Scenario":
        """The CPU-CI operating point: same tenants, same arrival shapes,
        lengths shrunk by ``smoke_len_factor``, shorter horizon, capped
        request count.  SLOs are NOT shrunk — the virtual-clock cost model
        (see loadgen) keeps them meaningful at smoke scale."""
        f = self.smoke_len_factor
        tenants = tuple(replace(
            t,
            prompt_len=t.prompt_len.shrunk(f),
            new_tokens=t.new_tokens.shrunk(f, lo=3),
            shared_prefix_len=(max(t.shared_prefix_len // f, 8)
                               if t.shared_prefix_len else 0),
        ) for t in self.tenants)
        return replace(self, tenants=tenants,
                       duration_s=self.smoke_duration_s,
                       max_requests=self.smoke_max_requests)

    def max_prompt_len(self) -> int:
        return max(t.max_prompt_len() for t in self.tenants)

    def max_new_tokens(self) -> int:
        return max(t.new_tokens.upper() for t in self.tenants)

    def offered_qps(self) -> float:
        """Mean offered load (sum of tenant rates)."""
        return sum(t.arrival.rate for t in self.tenants)

    def slo_ttft_budget(self) -> float:
        """The loosest tenant TTFT SLO — the saturation sweep's default
        p99-TTFT budget (the system is 'sustaining' a rate only if even the
        most lenient class still attains)."""
        return max(t.slo_ttft_s for t in self.tenants)


def _salt(name: str) -> int:
    """Stable 32-bit scenario/tenant salt (NOT Python's randomized hash)."""
    return zlib.crc32(name.encode())


def tenant_rng(seed: int, scenario: str, tenant_index: int,
               stream: int = 0) -> np.random.Generator:
    """The per-tenant deterministic generator: seeded from ``(seed, scenario
    name, tenant index, stream)`` via SeedSequence, so adding a tenant or a
    stream never perturbs the draws of the others."""
    return np.random.default_rng([seed, _salt(scenario), tenant_index,
                                  stream])


def shared_prefix_tokens(seed: int, scenario: str, tenant_index: int,
                         group: int, length: int,
                         vocab_size: int) -> list[int]:
    """The shared prefix for one ``(tenant, group)``: deterministic in the
    trace seed, disjoint RNG stream from arrivals/lengths (stream
    ``1000 + group``)."""
    rng = tenant_rng(seed, scenario, tenant_index, stream=1000 + group)
    return [int(t) for t in rng.integers(2, max(vocab_size - 1, 3),
                                         size=length)]


def _chat() -> Scenario:
    return Scenario(
        name="chat",
        description="interactive chat + background batch tenant; Poisson "
                    "arrivals, moderate prompts, lognormal generations, "
                    "shared system prompts",
        tenants=(
            TenantSpec("interactive",
                       ArrivalProcess("poisson", rate=8.0),
                       prompt_len=Dist("uniform", 32, 192),
                       new_tokens=Dist("lognormal", 96, 320, sigma=0.6),
                       shared_prefix_len=64, prefix_groups=4,
                       slo_ttft_s=0.5, slo_tpot_s=0.05),
            TenantSpec("batch",
                       ArrivalProcess("poisson", rate=2.0),
                       prompt_len=Dist("uniform", 64, 384),
                       new_tokens=Dist("uniform", 64, 256),
                       slo_ttft_s=2.0, slo_tpot_s=0.10),
        ))


def _rag() -> Scenario:
    return Scenario(
        name="rag",
        description="RAG long-prefill: fat retrieval-stuffed prompts with a "
                    "shared template prefix, short grounded answers",
        tenants=(
            TenantSpec("rag",
                       ArrivalProcess("poisson", rate=4.0),
                       prompt_len=Dist("uniform", 512, 1280),
                       new_tokens=Dist("uniform", 32, 128),
                       shared_prefix_len=256, prefix_groups=8,
                       slo_ttft_s=2.0, slo_tpot_s=0.08),
            TenantSpec("control",
                       ArrivalProcess("poisson", rate=1.0),
                       prompt_len=Dist("uniform", 16, 64),
                       new_tokens=Dist("uniform", 16, 64),
                       slo_ttft_s=0.5, slo_tpot_s=0.05),
        ))


def _agentic() -> Scenario:
    return Scenario(
        name="agentic",
        description="agent tool loops: gamma-burst arrivals (cv≈3) resending "
                    "conversation state as a shared prefix, plus a trickle "
                    "of long background jobs",
        tenants=(
            TenantSpec("agent",
                       ArrivalProcess("gamma_burst", rate=6.0, cv=3.0),
                       prompt_len=Dist("uniform", 48, 256),
                       new_tokens=Dist("uniform", 16, 96),
                       shared_prefix_len=128, prefix_groups=2,
                       slo_ttft_s=0.4, slo_tpot_s=0.05),
            TenantSpec("background",
                       ArrivalProcess("fixed", rate=0.5),
                       prompt_len=Dist("uniform", 64, 256),
                       new_tokens=Dist("uniform", 128, 384),
                       slo_ttft_s=4.0, slo_tpot_s=0.15),
        ))


def _code() -> Scenario:
    return Scenario(
        name="code",
        description="code completion: high-rate bursty short generations "
                    "with tight TTFT, plus an assistant-chat tenant",
        tenants=(
            TenantSpec("completion",
                       ArrivalProcess("gamma_burst", rate=20.0, cv=2.0),
                       prompt_len=Dist("uniform", 96, 384),
                       new_tokens=Dist("choice", choices=(4, 8, 12, 16, 24)),
                       shared_prefix_len=64, prefix_groups=6,
                       slo_ttft_s=0.2, slo_tpot_s=0.03),
            TenantSpec("assistant",
                       ArrivalProcess("poisson", rate=1.5),
                       prompt_len=Dist("uniform", 48, 192),
                       new_tokens=Dist("uniform", 32, 128),
                       slo_ttft_s=1.0, slo_tpot_s=0.08),
        ))


#: the named-scenario registry (factories so a caller can never mutate the
#: canonical definitions)
SCENARIOS: dict[str, object] = {
    "chat": _chat, "rag": _rag, "agentic": _agentic, "code": _code,
}


def get_scenario(name: str) -> Scenario:
    try:
        return SCENARIOS[name]()  # type: ignore[operator]
    except KeyError:
        raise KeyError(f"unknown scenario {name!r}; available: "
                       f"{sorted(SCENARIOS)}") from None
