"""Deterministic fallback for the subset of `hypothesis` this suite uses.

The real `hypothesis` is a dev dependency (see requirements-dev.txt) and is
what CI runs.  In environments where it is not installed, ``conftest.py``
registers this module as ``sys.modules["hypothesis"]`` so the suite still
*collects and runs*: ``@given`` replays a fixed number of deterministic
pseudo-random examples (seeded per test name) instead of hard-erroring at
import time.  Strategies outside the supported subset degrade to a
skip-with-reason rather than a collection error.

Supported: ``given``, ``settings(max_examples=, deadline=)``, ``assume``,
``strategies.integers(min, max)``, ``strategies.sampled_from(seq)``,
``strategies.booleans()``.
"""

from __future__ import annotations

import functools
import inspect
import random
import zlib

DEFAULT_MAX_EXAMPLES = 25


class _Strategy:
    """A draw rule: ``draw(rng)`` returns one example."""

    def __init__(self, draw, label):
        self._draw = draw
        self.label = label

    def draw(self, rng: random.Random):
        return self._draw(rng)

    def __repr__(self):
        return f"_Strategy({self.label})"


class _UnsupportedStrategy(_Strategy):
    def __init__(self, label):
        super().__init__(lambda rng: None, label)


class _Strategies:
    @staticmethod
    def integers(min_value: int, max_value: int) -> _Strategy:
        return _Strategy(lambda rng: rng.randint(min_value, max_value),
                         f"integers({min_value}, {max_value})")

    @staticmethod
    def sampled_from(seq) -> _Strategy:
        elems = list(seq)
        return _Strategy(lambda rng: rng.choice(elems), f"sampled_from({elems!r})")

    @staticmethod
    def booleans() -> _Strategy:
        return _Strategy(lambda rng: bool(rng.getrandbits(1)), "booleans()")

    def __getattr__(self, name):  # unknown strategy → skip, not crash
        return lambda *a, **kw: _UnsupportedStrategy(f"{name}(...)")


strategies = _Strategies()


class _Rejected(Exception):
    pass


def assume(condition) -> bool:
    if not condition:
        raise _Rejected
    return True


class HealthCheck:
    too_slow = "too_slow"
    data_too_large = "data_too_large"
    filter_too_much = "filter_too_much"

    @classmethod
    def all(cls):
        return []


def settings(max_examples: int = DEFAULT_MAX_EXAMPLES, deadline=None, **_kw):
    def deco(fn):
        fn._mini_max_examples = max_examples
        return fn

    return deco


def given(*strats, **kw_strats):
    unsupported = [s.label for s in (*strats, *kw_strats.values())
                   if isinstance(s, _UnsupportedStrategy)]

    def deco(fn):
        if unsupported:
            @functools.wraps(fn)
            def skipper(*a, **kw):
                import pytest

                pytest.skip("hypothesis not installed; minihypothesis does not "
                            f"support strategies: {', '.join(unsupported)}")

            return skipper

        # As in real hypothesis: positional strategies fill the *rightmost*
        # parameters; anything left of them (fixtures) stays in the wrapper's
        # signature so pytest injects it.
        params = [p for p in inspect.signature(fn).parameters.values()
                  if p.name not in kw_strats]
        strat_names = [p.name for p in params[len(params) - len(strats):]]
        fixture_params = params[:len(params) - len(strats)]

        @functools.wraps(fn)
        def runner(**fixture_kwargs):
            n = getattr(runner, "_mini_max_examples",
                        getattr(fn, "_mini_max_examples", DEFAULT_MAX_EXAMPLES))
            # Stable per-test seed (hash() is randomized per process; crc32 not).
            rng = random.Random(zlib.crc32(fn.__qualname__.encode()))
            ran = 0
            for _ in range(n * 10):  # headroom for assume() rejections
                if ran >= n:
                    break
                call = dict(fixture_kwargs)
                call.update(zip(strat_names, (s.draw(rng) for s in strats)))
                call.update({k: s.draw(rng) for k, s in kw_strats.items()})
                try:
                    fn(**call)
                except _Rejected:
                    continue
                ran += 1
            if ran == 0:  # mirror hypothesis' Unsatisfied: never pass vacuously
                raise AssertionError(
                    f"minihypothesis: no example satisfied assume() for "
                    f"{fn.__qualname__} after {n * 10} attempts")

        runner.__signature__ = inspect.Signature(fixture_params)

        # Mimic real hypothesis' marker: plugins (e.g. anyio) reach for
        # ``fn.hypothesis.inner_test``.
        runner.hypothesis = type("_Meta", (), {"inner_test": staticmethod(fn)})()
        runner.is_hypothesis_test = True
        return runner

    return deco
