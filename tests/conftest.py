import os

# Tests run on the single real CPU device (the 512-device dry-run sets its
# own XLA_FLAGS in repro.launch.dryrun, never globally).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax
import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)
