import importlib.util
import os
import sys
import tempfile
import warnings

# Tests run on the single real CPU device (the 512-device dry-run sets its
# own XLA_FLAGS in repro.launch.dryrun, never globally).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

# Hermetic kernel dispatch: never read the developer's ~/.cache autotune
# entries (a stale entry could route CPU tests through interpret-mode Pallas)
# or an exported policy pin.  Tests that exercise these knobs set them
# explicitly (tmp_autotune_cache fixture / monkeypatch).
os.environ["REPRO_AUTOTUNE_CACHE"] = os.path.join(
    tempfile.mkdtemp(prefix="repro-test-autotune-"), "autotune.json")
os.environ.pop("REPRO_TERNARY_POLICY", None)

# Optional dev deps degrade to skip/fallback instead of collection errors.
# CI installs requirements-dev.txt and exercises the real hypothesis; a bare
# environment gets the deterministic subset shim in tests/_minihypothesis.py
# (unsupported strategies skip-with-reason rather than hard-error).
if importlib.util.find_spec("hypothesis") is None:
    _here = os.path.dirname(__file__)
    if _here not in sys.path:
        sys.path.insert(0, _here)
    import _minihypothesis

    sys.modules["hypothesis"] = _minihypothesis
    sys.modules["hypothesis.strategies"] = _minihypothesis.strategies  # type: ignore[assignment]
    warnings.warn("hypothesis not installed; using tests/_minihypothesis.py "
                  "deterministic fallback (pip install -r requirements-dev.txt "
                  "for the real property-based runs)")

import jax
import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)


@pytest.fixture(scope="session")
def jaxpr_shape_walker():
    """Recursive jaxpr scanner: returns ``walk(jaxpr, shapes) -> [(prim,
    shape), ...]`` listing every equation output (descending into
    scan/jit/cond sub-jaxprs) whose aval shape is in ``shapes``.  The shared
    memory oracle for "this dense intermediate must never materialize"
    assertions (dispatch + MoE tests)."""

    def walk(jaxpr, shapes, found=None):
        found = [] if found is None else found
        for eqn in jaxpr.eqns:
            for v in eqn.outvars:
                aval = getattr(v, "aval", None)
                if aval is not None and tuple(aval.shape) in shapes:
                    found.append((eqn.primitive.name, tuple(aval.shape)))
            for sub in eqn.params.values():
                subs = sub if isinstance(sub, (list, tuple)) else [sub]
                for s in subs:
                    if hasattr(s, "jaxpr"):
                        walk(s.jaxpr, shapes, found)
        return found

    return walk


@pytest.fixture()
def tmp_autotune_cache(tmp_path, monkeypatch):
    """Point the dispatch autotune cache at a throwaway file."""
    path = tmp_path / "autotune.json"
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(path))
    from repro.kernels import dispatch

    dispatch.reset_autotune_cache()
    yield path
    dispatch.reset_autotune_cache()
