"""Analytical cost model (§IV) against the paper's published results."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import cost_model as cm
from repro.core import dse


def area(mu, n, m, dt):
    return cm.area_gates_lut(mu, n, m, cm.get_coeffs(dt))


def test_optimal_mu_fp16_is_3():
    """Fig. 5/6b: 32×32 FP16 optimum at mu=3."""
    assert cm.optimal_mu(32, 32, "fp16") == 3


def test_table_iv_ratios():
    """Table IV: dequant 2.23×, sign-flip 1.64× vs LUT(mu=3) @32×32 FP16."""
    c = cm.get_coeffs("fp16")
    lut = area(3, 32, 32, "fp16")
    assert cm.area_gates_dequant_baseline(32, 32, c) / lut == pytest.approx(2.23, rel=0.05)
    assert cm.area_gates_signflip_baseline(32, 32, c) / lut == pytest.approx(1.64, rel=0.05)


def test_table_iv_absolute_area():
    """Table IV anchor: 0.120 mm² for the 32×32 FP16 mu=3 core."""
    assert cm.lut_core_area_mm2(3, 32, 32, "fp16") == pytest.approx(0.120, rel=0.01)


def test_table_v_absolute_area():
    """Table V anchor: (L,mu,K)=(34,2,30) INT8 @16nm → 33 125 µm²."""
    p = dse.DesignPoint(mu=2, L=34, K=30, dtype="int8")
    assert p.area_um2() == pytest.approx(33_125, rel=0.01)


def test_int8_lut_benefit_minimal():
    """Fig. 6a / §V-C: LUT benefit for INT8 is minimal (mu=1 close to opt)."""
    areas = {mu: area(mu, 32, 32, "int8") for mu in (1, 2, 3)}
    opt = min(areas.values())
    assert areas[1] / opt < 1.2
    assert cm.optimal_mu(32, 32, "int8") in (1, 2)


@settings(max_examples=20, deadline=None)
@given(st.sampled_from([8, 16, 32, 48, 64, 96]), st.sampled_from(["fp16", "int8"]))
def test_density_monotone_in_tile_size(t, dt):
    """Fig. 7: TOPS/mm² improves monotonically with core size."""
    mu = cm.optimal_mu(t, t, dt, mu_range=[m for m in (1, 2, 3, 4) if t % m == 0])
    bigger = 2 * t
    mu2 = cm.optimal_mu(bigger, bigger, dt,
                        mu_range=[m for m in (1, 2, 3, 4) if bigger % m == 0])
    assert cm.tops_per_mm2(mu2, bigger, bigger, dt) >= cm.tops_per_mm2(mu, t, t, dt)


def test_fig8_geometry_directions():
    """FP16 optimum elongates toward K > L·mu; INT8 toward L·mu > K."""
    g_fp = dse.optimal_geometry(1024, "fp16")
    g_i8 = dse.optimal_geometry(1024, "int8")
    assert g_fp.m > g_fp.n
    assert g_i8.n > g_i8.m


def test_eq10_overhead_terms_vanish():
    """Eq. 10: area/throughput decreases in both n and m."""
    c = cm.get_coeffs("fp16")
    a1 = cm.area_per_throughput(3, 48, 16, c)
    a2 = cm.area_per_throughput(3, 96, 16, c)
    a3 = cm.area_per_throughput(3, 48, 64, c)
    assert a2 < a1 and a3 < a1


def test_exact_mode_cheaper_than_paper_fit():
    """The constructive netlist gives ≤ the curve-fit Eq. 5 build adders."""
    for mu in (2, 3, 4, 5):
        assert cm.build_cost(mu, 96, mode="exact") <= \
            cm.build_cost(mu, 96, mode="bound") + 1e-9


def test_sota_comparison_tenet_near_optimal():
    """Table V: TENET's (32,2,32) sits ~1.00× from the model optimum."""
    rows = {r["work"]: r for r in dse.sota_comparison()}
    assert rows["tenet"]["model_prediction"] == pytest.approx(1.004, abs=0.05)
    assert rows["tellme_v2"]["model_prediction"] > 1.1  # off the frontier
    # published-area comparison: TENET 28nm→16nm vs ours ≈ 7.9×
    assert rows["tenet"]["area_decrease_vs_published"] == pytest.approx(7.9, rel=0.15)


def test_optimal_config_respects_throughput():
    p = dse.optimal_config_at_throughput(2048, "int8")
    assert 2048 * 0.98 <= p.throughput <= 2048


def test_power_proxy_same_optimum():
    """Fig. 5b: power tracks area with the same optimal mu."""
    pw = {mu: cm.power_proxy_breakdown(mu, 32, 32, "fp16")["total"]
          for mu in (1, 2, 3, 4)}
    ar = {mu: area(mu, 32, 32, "fp16") for mu in (1, 2, 3, 4)}
    assert min(pw, key=pw.get) == min(ar, key=ar.get) == 3
