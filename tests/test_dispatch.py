"""Unified ternary-matmul dispatch: differential matrix, selection
properties, autotune-cache behavior, and serving end-to-end.

The differential matrix is the cross-kernel equivalence oracle: every
registered kernel must match the pure-jnp ``repro.kernels.ref`` oracle within
dtype-appropriate tolerance, across shapes, activation dtypes (fp32 / bf16 /
fp16 / int8), and LUT fetch modes.  The property tests pin the dispatch
invariant that ``policy="auto"`` always resolves to a registered,
constraint-satisfying kernel — with or without cache entries, on any backend.
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import dispatch as dp
from repro.kernels import ref as ref_oracle

KERNELS = sorted(dp.kernel_names())
GROUPED_KERNELS = sorted(n for n in KERNELS if dp.get_kernel(n).grouped)
DENSE_KERNELS = sorted(n for n in KERNELS if not dp.get_kernel(n).grouped)
DTYPES = ["float32", "bfloat16", "float16", "int8"]
SHAPES = [(1, 15, 9), (4, 64, 32), (8, 60, 33)]
#: grouped problems (E, C, K, N): decode-like C=1, ragged dims, byte-aligned
GROUPED_SHAPES = [(2, 1, 15, 9), (4, 3, 64, 32), (3, 8, 60, 33)]
#: int8 activations: every path accumulates exactly (int32 or f32 on small
#: ints) → bit-exact.  Float paths differ only by output-cast rounding.
TOL = {
    "float32": dict(rtol=3e-5, atol=3e-5),
    "bfloat16": dict(rtol=2e-2, atol=8e-2),
    "float16": dict(rtol=4e-3, atol=2e-2),
    "int8": dict(rtol=0, atol=0),
}


def _problem(m, k, n, dtype, seed=0):
    rng = np.random.default_rng(seed)
    w_t = jnp.asarray(rng.integers(-1, 2, size=(n, k)), jnp.int8)
    if dtype == "int8":
        x = jnp.asarray(rng.integers(-127, 128, size=(m, k)), jnp.int8)
        scale = 1.0
    else:
        x = jnp.asarray(rng.normal(size=(m, k)), dtype)
        scale = 0.7
    tw = dp.TernaryWeight.from_ternary(w_t, scale)
    ref = np.asarray(
        ref_oracle.signflip_matmul_ref(x.astype(jnp.float32), w_t) * scale)
    return x, tw, ref


# ---------------------------------------------------------------------------
# differential matrix: every kernel ≡ ref
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("m,k,n", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("kernel", KERNELS)
def test_kernel_matches_ref(kernel, dtype, m, k, n):
    spec = dp.get_kernel(kernel)
    if not spec.supports(m, k, n, dtype):
        pytest.skip(f"{kernel} does not support {dtype}")
    x, tw, ref = _problem(m, k, n, dtype)
    y = np.asarray(dp.ternary_matmul(x, tw, policy=f"fixed:{kernel}"),
                   np.float32)
    np.testing.assert_allclose(y, ref, **TOL[dtype])


@pytest.mark.parametrize("mu", [1, 2, 4, 5])
@pytest.mark.parametrize("kernel", ["lut_onehot", "lut_gather"])
def test_lut_fetch_modes_across_mu(kernel, mu):
    x, tw, ref = _problem(3, 30, 17, "float32")
    y = np.asarray(dp.ternary_matmul(x, tw, policy=f"fixed:{kernel}", mu=mu))
    np.testing.assert_allclose(y, ref, **TOL["float32"])


def test_dispatch_under_jit_matches_eager():
    """Weights arriving as jit arguments (the serving path) must not leak
    tracers through the lazy encoding cache."""
    x, tw, ref = _problem(4, 40, 21, "float32")
    packed, scale, k = tw.packed(), tw.scale, tw.in_features

    @jax.jit
    def f(xx, pk):
        w = dp.TernaryWeight.from_packed(pk, scale, k)
        return dp.ternary_matmul(xx, w, policy="fixed:lut_onehot")

    np.testing.assert_allclose(np.asarray(f(x, packed)), ref, **TOL["float32"])
    # second trace with a different fixed kernel reuses nothing stale
    @jax.jit
    def g(xx, pk):
        w = dp.TernaryWeight.from_packed(pk, scale, k)
        return dp.ternary_matmul(xx, w, policy="fixed:lut_gather")

    np.testing.assert_allclose(np.asarray(g(x, packed)), ref, **TOL["float32"])


def test_weight_container_roundtrips():
    x, tw, ref = _problem(2, 25, 11, "float32")
    # packed -> trits -> keys all describe the same matrix
    tw2 = dp.TernaryWeight.from_packed(tw.packed(), tw.scale, tw.in_features)
    assert np.array_equal(np.asarray(tw2.trits()), np.asarray(tw.trits()))
    assert np.array_equal(np.asarray(tw2.keys(3)), np.asarray(tw.keys(3)))
    y = dp.ternary_matmul(x, tw2, policy="fixed:dequant_packed")
    np.testing.assert_allclose(np.asarray(y), ref, **TOL["float32"])


# ---------------------------------------------------------------------------
# grouped (batched-expert) differential matrix: every grouped kernel ≡
# per-expert ref, with per-expert scales
# ---------------------------------------------------------------------------


def _grouped_problem(e, c, k, n, dtype, seed=0):
    rng = np.random.default_rng(seed)
    w_t = jnp.asarray(rng.integers(-1, 2, size=(e, n, k)), jnp.int8)
    scale = jnp.asarray(rng.uniform(0.5, 1.5, size=(e,)), jnp.float32)
    if dtype == "int8":
        x = jnp.asarray(rng.integers(-127, 128, size=(e, c, k)), jnp.int8)
    else:
        x = jnp.asarray(rng.normal(size=(e, c, k)), dtype)
    gw = dp.GroupedTernaryWeight.from_ternary(w_t, scale)
    ref = np.stack([
        np.asarray(ref_oracle.signflip_matmul_ref(
            x[i].astype(jnp.float32), w_t[i])) * float(scale[i])
        for i in range(e)])
    return x, gw, ref


@pytest.mark.parametrize("e,c,k,n", GROUPED_SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("kernel", GROUPED_KERNELS)
def test_grouped_kernel_matches_per_expert_ref(kernel, dtype, e, c, k, n):
    spec = dp.get_kernel(kernel)
    if not spec.supports(c, k, n, dtype, e):
        pytest.skip(f"{kernel} does not support {dtype}")
    x, gw, ref = _grouped_problem(e, c, k, n, dtype)
    y = np.asarray(dp.grouped_ternary_matmul(x, gw, policy=f"fixed:{kernel}"),
                   np.float32)
    np.testing.assert_allclose(y, ref, **TOL[dtype])


def test_grouped_weight_container_roundtrips():
    x, gw, ref = _grouped_problem(3, 2, 25, 11, "float32")
    gw2 = dp.GroupedTernaryWeight.from_packed(gw.packed(), gw.scale,
                                              gw.in_features)
    assert np.array_equal(np.asarray(gw2.trits()), np.asarray(gw.trits()))
    y = dp.grouped_ternary_matmul(x, gw2, policy="fixed:grouped_dequant")
    np.testing.assert_allclose(np.asarray(y), ref, **TOL["float32"])


def test_grouped_accepts_padded_packed_bytes():
    """The serving artifact pads the packed byte dim (TP shardability);
    every grouped kernel must slice the decode at the logical K."""
    x, gw, ref = _grouped_problem(2, 3, 23, 17, "float32")
    packed = gw.packed()
    pad = (-packed.shape[-1]) % 8
    packed = jnp.pad(packed, ((0, 0), (0, 0), (0, pad)))
    gw2 = dp.GroupedTernaryWeight.from_packed(packed, gw.scale,
                                              gw.in_features)
    for kernel in ("grouped_ref", "grouped_dequant"):
        y = dp.grouped_ternary_matmul(x, gw2, policy=f"fixed:{kernel}")
        np.testing.assert_allclose(np.asarray(y), ref, **TOL["float32"])


def test_grouped_dispatch_under_jit_matches_eager():
    """Stacked packed weights arriving as jit arguments (the MoE serving
    path) must not leak tracers through the lazy encoding cache."""
    x, gw, ref = _grouped_problem(4, 2, 40, 21, "float32")
    packed, scale, k = gw.packed(), gw.scale, gw.in_features

    @jax.jit
    def f(xx, pk):
        w = dp.GroupedTernaryWeight.from_packed(pk, scale, k)
        return dp.grouped_ternary_matmul(xx, w, policy="fixed:grouped_ref")

    np.testing.assert_allclose(np.asarray(f(x, packed)), ref,
                               **TOL["float32"])


def test_grouped_no_dense_stack_in_jaxpr(jaxpr_shape_walker):
    """The packed grouped paths must never materialize the dense [E, N, K]
    expert stack — the whole point of streaming 1.6 b/w weights."""
    x, gw, ref = _grouped_problem(4, 2, 40, 24, "float32")
    packed, scale, k = gw.packed(), gw.scale, gw.in_features
    E, N = gw.n_experts, gw.out_features

    for kernel in ("grouped_ref", "grouped_dequant"):
        jaxpr = jax.make_jaxpr(
            lambda xx, pk: dp.grouped_ternary_matmul(
                xx, dp.GroupedTernaryWeight.from_packed(pk, scale, k),
                policy=f"fixed:{kernel}"))(x, packed)
        assert jaxpr_shape_walker(jaxpr.jaxpr, {(E, N, k)}) == [], kernel


# ---------------------------------------------------------------------------
# selection properties
# ---------------------------------------------------------------------------


@settings(max_examples=50, deadline=None)
@given(st.integers(1, 64), st.integers(1, 96), st.integers(1, 96),
       st.sampled_from(DTYPES), st.sampled_from(["cpu", "tpu", "gpu"]))
def test_auto_always_returns_valid_kernel(m, k, n, dtype, backend):
    empty = dp.AutotuneCache(path="/nonexistent/autotune.json")
    for policy in ("auto", "prior"):
        spec = dp.select_kernel(m, k, n, dtype, policy=policy,
                                backend=backend, cache=empty)
        assert spec.name in dp.REGISTRY
        assert spec.supports(m, k, n, dtype)


@settings(max_examples=25, deadline=None)
@given(st.integers(1, 32), st.integers(1, 64), st.integers(1, 64),
       st.sampled_from(KERNELS))
def test_auto_honors_cache_best_when_eligible(m, k, n, kernel):
    cache = dp.AutotuneCache(path="/nonexistent/autotune.json")
    for name in KERNELS:
        cache.record(m, k, n, "float32", "cpu", name,
                     1.0 if name == kernel else 1e6)
    spec = dp.select_kernel(m, k, n, "float32", policy="auto", backend="cpu",
                            cache=cache)
    if dp.get_kernel(kernel).supports(m, k, n, "float32"):
        assert spec.name == kernel
    else:  # ineligible best (w2a8 on float) falls back to a valid kernel
        assert spec.supports(m, k, n, "float32")


def test_fixed_policy_validation():
    with pytest.raises(KeyError, match="unknown kernel"):
        dp.select_kernel(2, 8, 8, "float32", policy="fixed:nope")
    with pytest.raises(ValueError, match="does not support"):
        dp.select_kernel(2, 8, 8, "float32", policy="fixed:w2a8")
    with pytest.raises(ValueError, match="unknown policy"):
        dp.select_kernel(2, 8, 8, "float32", policy="fastest")


def test_prior_tracks_paper_structure():
    """The static prior inherits the paper's findings: at FP16 compute the
    LUT datapath beats dequant and sign-flip; packed paths win the
    bandwidth-bound (small-M) regime over dense-bf16 streaming."""
    on_tpu = functools.partial(dp.static_prior, m=256, k=4096, n=4096,
                               act_dtype="float16", backend="tpu")
    lut = on_tpu(dp.get_kernel("lut_onehot"))
    assert lut < on_tpu(dp.get_kernel("dequant_packed"))
    assert lut < on_tpu(dp.get_kernel("signflip"))
    # decode shape (M=1): 1.6 b/w streaming beats 16 b/w dense ref
    dec = functools.partial(dp.static_prior, m=1, k=4096, n=4096,
                            act_dtype="float16", backend="tpu")
    assert dec(dp.get_kernel("dequant_packed")) < dec(dp.get_kernel("ref"))


def test_env_var_policy(monkeypatch):
    monkeypatch.setenv(dp.DEFAULT_POLICY_ENV, "fixed:signflip")
    assert dp.select_kernel(2, 16, 8, "float32", policy=None).name == "signflip"


# ---------------------------------------------------------------------------
# grouped selection properties
# ---------------------------------------------------------------------------


def test_grouped_and_dense_kernels_never_cross_eligible():
    for name in DENSE_KERNELS:
        assert not dp.get_kernel(name).supports(4, 32, 16, "float32", 8)
    for name in GROUPED_KERNELS:
        assert not dp.get_kernel(name).supports(4, 32, 16, "float32")
    assert {s.name for s in dp.eligible_kernels(4, 32, 16, "float32", 8)} \
        <= set(GROUPED_KERNELS)


@pytest.mark.parametrize("policy", ["auto", "prior"])
@pytest.mark.parametrize("dtype", DTYPES)
def test_grouped_selection_always_valid(policy, dtype):
    empty = dp.AutotuneCache(path="/nonexistent/autotune.json")
    for backend in ("cpu", "tpu", "gpu"):
        spec = dp.select_kernel(1, 64, 48, dtype, policy=policy,
                                backend=backend, cache=empty, e=16)
        assert spec.grouped
        assert spec.supports(1, 64, 48, dtype, 16)


def test_fixed_dense_pin_maps_to_grouped_variant():
    """One policy string governs dense AND MoE layers: fixed:<dense kernel>
    resolves to its grouped analogue on grouped problems."""
    for dense, grouped in [("ref", "grouped_ref"),
                           ("dequant_packed", "grouped_dequant")]:
        spec = dp.select_kernel(2, 30, 20, "float32",
                                policy=f"fixed:{dense}", e=4)
        assert spec.name == grouped
    spec = dp.select_kernel(2, 30, 20, "int8", policy="fixed:w2a8", e=4)
    assert spec.name == "grouped_w2a8"
    # pinning a grouped kernel works directly on grouped problems ...
    assert dp.select_kernel(2, 30, 20, "float32",
                            policy="fixed:grouped_ref", e=4).name == "grouped_ref"
    # ... and kernels without a grouped analogue refuse MoE problems loudly
    with pytest.raises(ValueError, match="no grouped"):
        dp.select_kernel(2, 30, 20, "float32", policy="fixed:lut_onehot", e=4)
    # a grouped pin cannot serve a dense problem
    with pytest.raises(ValueError, match="does not support"):
        dp.select_kernel(2, 30, 20, "float32", policy="fixed:grouped_ref")


def test_grouped_prior_tracks_decode_bandwidth_regime():
    """Decode-time capacity C is tiny, so the grouped prior must be
    dominated by weight bytes streamed: the 1.6 b/w packed grouped kernels
    beat the dense-decoding grouped_ref on hardware at C=1, and grouped_ref
    (non-Pallas) wins on CPU where Pallas kernels are interpreted."""
    dec = functools.partial(dp.static_prior, m=1, k=4096, n=6400,
                            act_dtype="bfloat16", backend="tpu", e=16)
    assert dec(dp.get_kernel("grouped_dequant")) < dec(dp.get_kernel("grouped_ref"))
    on_cpu = dp.select_kernel(1, 4096, 6400, "bfloat16", policy="prior",
                              backend="cpu", e=16)
    assert on_cpu.name == "grouped_ref"
    # the prior scales with the expert count: every expert's weights stream
    one = dp.static_prior(dp.get_kernel("grouped_dequant"), 1, 64, 48,
                          "bfloat16", "tpu", 3, 2)
    many = dp.static_prior(dp.get_kernel("grouped_dequant"), 1, 64, 48,
                           "bfloat16", "tpu", 3, 16)
    assert many == pytest.approx(8 * one)


def test_grouped_autotune_cache_key_isolated_from_dense(tmp_autotune_cache):
    """A grouped measurement must steer only grouped problems of the same
    expert count — never the dense problem with matching (M, K, N)."""
    cache = dp.get_autotune_cache()
    cache.record(2, 20, 9, "float32", "cpu", "grouped_dequant", 1.0, e=4)
    cache.record(2, 20, 9, "float32", "cpu", "ref", 5.0)
    assert cache.best(2, 20, 9, "float32", "cpu", e=4) == "grouped_dequant"
    assert cache.best(2, 20, 9, "float32", "cpu") == "ref"
    assert cache.best(2, 20, 9, "float32", "cpu", e=8) is None
    spec = dp.select_kernel(2, 20, 9, "float32", policy="auto",
                            backend="cpu", cache=cache, e=4)
    assert spec.name == "grouped_dequant"


def test_grouped_autotune_measures_and_dispatch_uses_it(tmp_autotune_cache):
    timings = dp.autotune(2, 20, 9, "float32", e=3, reps=1,
                          kernels=["grouped_ref", "grouped_dequant"])
    assert set(timings) == {"grouped_ref", "grouped_dequant"}
    assert all(t > 0 for t in timings.values())
    best = min(timings, key=timings.get)
    assert dp.select_kernel(2, 20, 9, "float32", policy="auto",
                            e=3).name == best
    # survives a cold reload under the grouped key
    dp.reset_autotune_cache()
    assert dp.select_kernel(2, 20, 9, "float32", policy="auto",
                            e=3).name == best


# ---------------------------------------------------------------------------
# autotune cache
# ---------------------------------------------------------------------------


def test_autotune_cache_roundtrip(tmp_autotune_cache):
    cache = dp.get_autotune_cache()
    assert str(tmp_autotune_cache) == cache.path
    cache.record(4, 32, 16, "float32", "cpu", "signflip", 11.0)
    cache.record(4, 32, 16, "float32", "cpu", "ref", 99.0)
    cache.save()
    reloaded = dp.AutotuneCache.load(cache.path)
    assert reloaded.best(4, 32, 16, "float32", "cpu") == "signflip"
    assert reloaded.timings(4, 32, 16, "float32", "cpu")["ref"] == 99.0
    # stale kernels in a cache file never dispatch
    reloaded.record(4, 32, 16, "float32", "cpu", "deleted_kernel", 0.1)
    assert reloaded.best(4, 32, 16, "float32", "cpu") == "signflip"


def test_autotune_measures_and_dispatch_uses_it(tmp_autotune_cache):
    timings = dp.autotune(2, 20, 9, "float32", reps=1,
                          kernels=["ref", "signflip"])
    assert set(timings) == {"ref", "signflip"}
    assert all(t > 0 for t in timings.values())
    assert tmp_autotune_cache.exists()
    best = min(timings, key=timings.get)
    spec = dp.select_kernel(2, 20, 9, "float32", policy="auto")
    assert spec.name == best
    # and the full entry survives a cold reload
    dp.reset_autotune_cache()
    assert dp.select_kernel(2, 20, 9, "float32", policy="auto").name == best


def test_corrupt_cache_file_is_ignored(tmp_autotune_cache):
    tmp_autotune_cache.write_text("{not json")
    cache = dp.AutotuneCache.load(str(tmp_autotune_cache))
    assert len(cache) == 0


def test_cache_schema_v2_and_v1_compat(tmp_autotune_cache):
    import json as _json

    cache = dp.get_autotune_cache()
    cache.record(4, 32, 16, "float32", "cpu", "ref", 9.0)
    cache.record(2, 32, 16, "float32", "cpu", "grouped_ref", 3.0, e=8)
    cache.save()
    doc = _json.loads(tmp_autotune_cache.read_text())
    assert doc["schema_version"] == dp.CACHE_SCHEMA_VERSION == 2
    assert "E8:M2:K32:N16:mu3:float32:cpu" in doc["entries"]
    # a v1 file (dense-only keys, unchanged format) still loads
    tmp_autotune_cache.write_text(_json.dumps(
        {"schema_version": 1,
         "entries": {"M4:K32:N16:mu3:float32:cpu": {"ref": 7.5}}}))
    old = dp.AutotuneCache.load(str(tmp_autotune_cache))
    assert old.best(4, 32, 16, "float32", "cpu") == "ref"
    # unknown future schemas are ignored, not misread
    tmp_autotune_cache.write_text(_json.dumps(
        {"schema_version": 99, "entries": {"M1:K1:N1:mu3:float32:cpu": {}}}))
    assert len(dp.AutotuneCache.load(str(tmp_autotune_cache))) == 0


def test_cache_save_is_atomic(tmp_autotune_cache):
    """A mid-write kill (stale temp debris) or concurrent writer never
    corrupts the cache: writes go to a unique temp + os.replace, so readers
    always see a complete JSON document."""
    cache = dp.get_autotune_cache()
    cache.record(4, 32, 16, "float32", "cpu", "ref", 9.0)
    cache.save()
    # debris from a killed writer in the same directory is inert
    (tmp_autotune_cache.parent / ".autotune-dead.tmp").write_text("{trunc")
    # a concurrent writer with different entries replaces wholesale
    other = dp.AutotuneCache.load(str(tmp_autotune_cache))
    other.record(8, 64, 32, "float32", "cpu", "signflip", 1.0)
    other.save()
    reloaded = dp.AutotuneCache.load(str(tmp_autotune_cache))
    assert reloaded.best(4, 32, 16, "float32", "cpu") == "ref"
    assert reloaded.best(8, 64, 32, "float32", "cpu") == "signflip"
    # no temp files accumulate from successful saves
    tmps = [p for p in tmp_autotune_cache.parent.iterdir()
            if p.name.endswith(".tmp") and p.name != ".autotune-dead.tmp"]
    assert tmps == []


# ---------------------------------------------------------------------------
# serving end-to-end
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def packed_smoke_model():
    from repro.configs.registry import get_smoke_config
    from repro.models.decode import quantize_for_serving
    from repro.models.model import init_params

    cfg = get_smoke_config("qwen3-0.6b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    return cfg, quantize_for_serving(params, cfg)


def test_engine_end_to_end_policy_auto(packed_smoke_model, tmp_autotune_cache):
    from repro.serving.engine import DecodeEngine, Request

    cfg, sp = packed_smoke_model
    eng = DecodeEngine(sp, cfg, batch_size=2, max_len=32,
                       matmul_policy="auto")
    reqs = eng.run([Request(prompt=[3, 4, 5], max_new_tokens=4),
                    Request(prompt=[7, 8], max_new_tokens=4)])
    assert [len(r.out) for r in reqs] == [4, 4]
    assert all(0 <= t < cfg.padded_vocab for r in reqs for t in r.out)
    # reproducibility pin: a fixed ref dispatch decodes identical tokens
    pin = DecodeEngine(sp, cfg, batch_size=2, max_len=32,
                       matmul_policy="fixed:ref")
    reqs_pin = pin.run([Request(prompt=[3, 4, 5], max_new_tokens=4),
                        Request(prompt=[7, 8], max_new_tokens=4)])
    assert [r.out for r in reqs_pin] == [r.out for r in reqs]


def test_engine_autotune_shapes(packed_smoke_model, tmp_autotune_cache):
    from repro.models.decode import layer_matmul_shapes
    from repro.serving.engine import DecodeEngine

    cfg, sp = packed_smoke_model
    eng = DecodeEngine(sp, cfg, batch_size=2, max_len=32, prefill_chunk=8)
    results = eng.autotune_shapes(reps=1, kernels=["ref", "signflip"])
    # decode shapes (M = B) plus the admission-chunk bucket shape
    # (M = 1·chunk: requests prefill one at a time, chunk by chunk), so
    # policy="auto" admission hits measured entries instead of the prior
    want = set(layer_matmul_shapes(cfg, 2))
    want |= set(layer_matmul_shapes(cfg, 1, seq_len=8))
    assert sorted(results) == sorted(want)
    assert sorted(results) == eng.matmul_shape_universe()
    cache = dp.get_autotune_cache()
    for (m, k, n) in results:
        assert cache.best(m, k, n, cfg.dtype, jax.default_backend()) is not None


def test_layer_matmul_shapes_cover_real_dispatch(packed_smoke_model,
                                                 monkeypatch):
    """Drift guard: every (M, K, N) the serving step actually dispatches must
    be enumerated by layer_matmul_shapes — the hand-written shape arithmetic
    is only trustworthy while this holds."""
    import jax.numpy as jnp

    from repro.models.decode import decode_step, layer_matmul_shapes, prefill

    cfg, sp = packed_smoke_model
    B, S = 2, 8
    seen: set[tuple[int, int, int]] = set()
    orig = dp.select_kernel

    def spy(m, k, n, act_dtype, **kw):
        seen.add((m, k, n))
        return orig(m, k, n, act_dtype, **kw)

    monkeypatch.setattr(dp, "select_kernel", spy)
    batch = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}
    cache, _ = jax.eval_shape(
        lambda p, b: prefill(p, cfg, b, s_max=16), sp, batch)
    prefill_seen = set(seen)
    assert prefill_seen, "prefill dispatched no ternary matmuls"
    assert prefill_seen <= set(layer_matmul_shapes(cfg, B, seq_len=S))

    seen.clear()
    jax.eval_shape(
        lambda p, c: decode_step(p, cfg, c, jnp.zeros((B,), jnp.int32),
                                 jnp.asarray(S, jnp.int32)), sp, cache)
    assert seen, "decode dispatched no ternary matmuls"
    assert seen <= set(layer_matmul_shapes(cfg, B))


def test_layer_matmul_shapes_scale_with_batch():
    from repro.configs.registry import get_smoke_config
    from repro.models.decode import layer_matmul_shapes

    cfg = get_smoke_config("qwen3-0.6b")
    s1 = layer_matmul_shapes(cfg, 1)
    s8 = layer_matmul_shapes(cfg, 1, seq_len=8)
    assert {(k, n) for _, k, n in s1} == {(k, n) for _, k, n in s8}
    assert all(m == 1 for m, _, _ in s1)
    assert all(m == 8 for m, _, _ in s8)
    d = cfg.d_model
    assert (1, d, cfg.q_dim) in s1 and (1, cfg.d_ff, d) in s1


# ---------------------------------------------------------------------------
# quantize_activations_int8 edge-case properties (feeds every int8 dispatch
# path: fused dense/Expert activation quant must never emit NaN codes or
# non-finite scales, whatever the token row looks like)
# ---------------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), rows=st.integers(1, 5),
       cols=st.integers(1, 64), log_mag=st.integers(-30, 30))
def test_act_quant_round_trip_bound(seed, rows, cols, log_mag):
    """For finite input, dequantized codes land within half a quantization
    step of the input per element, codes stay in [-127, 127], and the scale
    is strictly positive and finite — across ~60 orders of magnitude."""
    from repro.core.quantization import quantize_activations_int8

    rng = np.random.default_rng(seed)
    x = rng.standard_normal((rows, cols)).astype(np.float32) * 10.0**log_mag
    x_q, scale = quantize_activations_int8(jnp.asarray(x))
    assert x_q.dtype == jnp.int8
    q = np.asarray(x_q, np.int32)
    s = np.asarray(scale, np.float64)
    assert np.all(np.isfinite(s)) and np.all(s > 0)
    assert q.min() >= -127 and q.max() <= 127
    # absmax quant: |x - q*s| <= s/2 (+ tiny slack for the f32 divide)
    err = np.abs(x.astype(np.float64) - q * s)
    assert np.all(err <= s * 0.5 * (1 + 1e-5) + 1e-30), err.max() / s.min()


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), cols=st.integers(1, 64),
       kind=st.sampled_from(["zero", "inf", "-inf", "nan", "mixed"]))
def test_act_quant_pathological_rows(seed, cols, kind):
    """Hardened edge cases: an all-zero row yields all-zero codes with a
    finite positive scale (no 0/0 NaN); ±inf rows saturate to ±127 instead
    of wrapping through a NaN→int8 cast; NaN entries quantize to code 0.
    Healthy rows alongside a pathological one keep their round-trip."""
    from repro.core.quantization import quantize_activations_int8

    rng = np.random.default_rng(seed)
    healthy = rng.standard_normal((cols,)).astype(np.float32)
    bad = np.zeros((cols,), np.float32)
    if kind == "inf":
        bad[0] = np.inf
    elif kind == "-inf":
        bad[0] = -np.inf
    elif kind == "nan":
        bad[0] = np.nan
    elif kind == "mixed":
        bad[: max(1, cols // 2)] = [np.inf, -np.inf, np.nan][seed % 3]
    x = np.stack([bad, healthy])
    x_q, scale = quantize_activations_int8(jnp.asarray(x))
    q = np.asarray(x_q, np.int32)
    s = np.asarray(scale, np.float64)
    assert np.all(np.isfinite(s)) and np.all(s > 0)
    assert q.min() >= -127 and q.max() <= 127
    if kind == "zero":
        assert not q[0].any()
    elif kind in ("inf", "-inf"):
        assert q[0, 0] == (127 if kind == "inf" else -127)
    elif kind == "nan":
        assert q[0, 0] == 0
    # the healthy row is quantized independently (per-token scales)
    err = np.abs(healthy.astype(np.float64) - q[1] * s[1])
    assert np.all(err <= s[1] * 0.5 * (1 + 1e-5) + 1e-30)


# ---------------------------------------------------------------------------
# W1.58A8 end-to-end: bf16-vs-int8 decode differential + jaxpr purity
# ---------------------------------------------------------------------------

#: per-layer-family logit tolerance for the A8 path: per-token absmax int8
#: introduces ≤ 1/254 relative error per matmul; the MoE family runs more
#: quantized projections per block (router stays full-precision) and its
#: expert sum amplifies the per-expert rounding, so it gets more headroom
A8_LOGIT_TOL = {"dense": 0.25, "moe": 0.45}


def _greedy_logits(cfg, sp, steps=3):
    import jax.numpy as jnp

    from repro.models.decode import decode_step, prefill

    batch = {"tokens": jnp.asarray([[3, 4, 5, 6, 7, 8, 9, 10]], jnp.int32)}
    cache, logits = prefill(sp, cfg, batch, s_max=16)
    out = [logits]
    pos = jnp.asarray(8, jnp.int32)
    for _ in range(steps):
        tok = jnp.argmax(out[-1], axis=-1).astype(jnp.int32)
        logits, cache = decode_step(sp, cfg, cache, tok, pos)
        out.append(logits)
        pos = pos + 1
    return out


@pytest.mark.parametrize("family,arch", [("dense", "qwen3-0.6b"),
                                         ("moe", "phi3.5-moe-42b-a6.6b")])
def test_int8_decode_matches_bf16(family, arch):
    """The A8 path (per-token absmax int8 activations, scale as rank-1
    post-correction) tracks the bf16 activation path within the family
    tolerance on prefill and several greedy decode steps — same packed
    weights, only ``act_dtype`` flips."""
    from repro.configs.registry import get_smoke_config
    from repro.models.decode import quantize_for_serving
    from repro.models.model import init_params

    cfg = get_smoke_config(arch)
    sp = quantize_for_serving(init_params(cfg, jax.random.PRNGKey(0)), cfg)
    ref = _greedy_logits(cfg, sp)
    a8 = _greedy_logits(cfg.with_(act_dtype="int8"), sp)
    for i, (lr, lq) in enumerate(zip(ref, a8)):
        err = float(jnp.max(jnp.abs(lr - lq)))
        assert err <= A8_LOGIT_TOL[family], (i, err)


@pytest.mark.parametrize("policy", ["fixed:w2a8", "fixed:tl2"])
def test_int8_decode_step_jaxpr_no_float_dequant(policy):
    """Acceptance walk for the W1.58A8 decode step: with ``act_dtype="int8"``
    every ternary projection runs an int8-activation kernel — the jaxpr must
    contain no *floating* dense weight materialization at any projection's
    ``[N, K]``/``[K, N]`` (a bf16 dequant-then-matmul fallback would), and
    the activation quantization must actually fuse (int8 converts appear).
    Pinned per kernel family: w2a8 (2 b/w) and tl2 (1.6 b/w)."""
    import jax.numpy as jnp

    from repro.configs.registry import get_smoke_config
    from repro.models.decode import (decode_step, layer_matmul_shapes,
                                     prefill, quantize_for_serving)
    from repro.models.model import init_params

    cfg = get_smoke_config("qwen3-0.6b").with_(act_dtype="int8",
                                              matmul_policy=policy)
    sp = quantize_for_serving(init_params(cfg, jax.random.PRNGKey(0)), cfg)
    B = 1
    batch = {"tokens": jax.ShapeDtypeStruct((B, 8), jnp.int32)}
    cache, _ = jax.eval_shape(lambda p, b: prefill(p, cfg, b, s_max=16),
                              sp, batch)
    jaxpr = jax.make_jaxpr(
        lambda p, c: decode_step(p, cfg, c, jnp.zeros((B,), jnp.int32),
                                 jnp.asarray(8, jnp.int32)))(sp, cache)

    weight_shapes = set()
    for _, k, n in layer_matmul_shapes(cfg, B):
        weight_shapes |= {(k, n), (n, k)}

    def walk(jaxpr, found):
        for eqn in jaxpr.eqns:
            for v in eqn.outvars:
                aval = getattr(v, "aval", None)
                if aval is None:
                    continue
                if (tuple(aval.shape) in weight_shapes
                        and jnp.issubdtype(aval.dtype, jnp.floating)):
                    found.append((eqn.primitive.name, tuple(aval.shape),
                                  str(aval.dtype)))
                if (eqn.primitive.name == "convert_element_type"
                        and aval.dtype == jnp.int8):
                    found.append(("int8_convert", tuple(aval.shape), "int8"))
            for sub in eqn.params.values():
                subs = sub if isinstance(sub, (list, tuple)) else [sub]
                for s in subs:
                    if hasattr(s, "jaxpr"):
                        walk(s.jaxpr, found)
        return found

    found = walk(jaxpr.jaxpr, [])
    dequants = [f for f in found if f[0] != "int8_convert"]
    assert not dequants, f"floating dense-weight materialization: {dequants}"
    assert any(f[0] == "int8_convert" for f in found), \
        "no int8 activation quantization in the decode step"


def test_int8_decode_step_every_dispatch_sees_int8(monkeypatch):
    """Under ``act_dtype="int8"`` with ``policy="auto"``, every dense and
    grouped dispatch in the decode step is keyed on int8 activations — the
    per-token quantization is fused in front of *every* ternary projection
    (dense and per-expert), never silently skipped back to a float path.
    (Which int8-capable kernel wins is the prior/autotune's call.)"""
    import jax.numpy as jnp

    from repro.configs.registry import get_smoke_config
    from repro.models.decode import decode_step, prefill, quantize_for_serving
    from repro.models.model import init_params

    cfg = get_smoke_config("phi3.5-moe-42b-a6.6b").with_(act_dtype="int8")
    sp = quantize_for_serving(init_params(cfg, jax.random.PRNGKey(0)), cfg)
    chosen: set[str] = set()
    orig = dp.select_kernel

    def spy(m, k, n, act_dtype, **kw):
        spec = orig(m, k, n, act_dtype, **kw)
        chosen.add((spec.name, act_dtype))
        return spec

    monkeypatch.setattr(dp, "select_kernel", spy)
    B = 1
    batch = {"tokens": jax.ShapeDtypeStruct((B, 8), jnp.int32)}
    cache, _ = jax.eval_shape(lambda p, b: prefill(p, cfg, b, s_max=16),
                              sp, batch)
    chosen.clear()
    jax.eval_shape(
        lambda p, c: decode_step(p, cfg, c, jnp.zeros((B,), jnp.int32),
                                 jnp.asarray(8, jnp.int32)), sp, cache)
    assert chosen, "decode step dispatched no ternary matmuls"
    assert all(d == "int8" for _, d in chosen), chosen
    # both families (dense + grouped expert) dispatched through int8
    assert any(dp.get_kernel(n).grouped for n, _ in chosen), chosen
    assert any(not dp.get_kernel(n).grouped for n, _ in chosen), chosen
