"""Unified ternary-matmul dispatch: differential matrix, selection
properties, autotune-cache behavior, and serving end-to-end.

The differential matrix is the cross-kernel equivalence oracle: every
registered kernel must match the pure-jnp ``repro.kernels.ref`` oracle within
dtype-appropriate tolerance, across shapes, activation dtypes (fp32 / bf16 /
fp16 / int8), and LUT fetch modes.  The property tests pin the dispatch
invariant that ``policy="auto"`` always resolves to a registered,
constraint-satisfying kernel — with or without cache entries, on any backend.
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import dispatch as dp
from repro.kernels import ref as ref_oracle

KERNELS = sorted(dp.kernel_names())
DTYPES = ["float32", "bfloat16", "float16", "int8"]
SHAPES = [(1, 15, 9), (4, 64, 32), (8, 60, 33)]
#: int8 activations: every path accumulates exactly (int32 or f32 on small
#: ints) → bit-exact.  Float paths differ only by output-cast rounding.
TOL = {
    "float32": dict(rtol=3e-5, atol=3e-5),
    "bfloat16": dict(rtol=2e-2, atol=8e-2),
    "float16": dict(rtol=4e-3, atol=2e-2),
    "int8": dict(rtol=0, atol=0),
}


def _problem(m, k, n, dtype, seed=0):
    rng = np.random.default_rng(seed)
    w_t = jnp.asarray(rng.integers(-1, 2, size=(n, k)), jnp.int8)
    if dtype == "int8":
        x = jnp.asarray(rng.integers(-127, 128, size=(m, k)), jnp.int8)
        scale = 1.0
    else:
        x = jnp.asarray(rng.normal(size=(m, k)), dtype)
        scale = 0.7
    tw = dp.TernaryWeight.from_ternary(w_t, scale)
    ref = np.asarray(
        ref_oracle.signflip_matmul_ref(x.astype(jnp.float32), w_t) * scale)
    return x, tw, ref


# ---------------------------------------------------------------------------
# differential matrix: every kernel ≡ ref
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("m,k,n", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("kernel", KERNELS)
def test_kernel_matches_ref(kernel, dtype, m, k, n):
    spec = dp.get_kernel(kernel)
    if not spec.supports(m, k, n, dtype):
        pytest.skip(f"{kernel} does not support {dtype}")
    x, tw, ref = _problem(m, k, n, dtype)
    y = np.asarray(dp.ternary_matmul(x, tw, policy=f"fixed:{kernel}"),
                   np.float32)
    np.testing.assert_allclose(y, ref, **TOL[dtype])


@pytest.mark.parametrize("mu", [1, 2, 4, 5])
@pytest.mark.parametrize("kernel", ["lut_onehot", "lut_gather"])
def test_lut_fetch_modes_across_mu(kernel, mu):
    x, tw, ref = _problem(3, 30, 17, "float32")
    y = np.asarray(dp.ternary_matmul(x, tw, policy=f"fixed:{kernel}", mu=mu))
    np.testing.assert_allclose(y, ref, **TOL["float32"])


def test_dispatch_under_jit_matches_eager():
    """Weights arriving as jit arguments (the serving path) must not leak
    tracers through the lazy encoding cache."""
    x, tw, ref = _problem(4, 40, 21, "float32")
    packed, scale, k = tw.packed(), tw.scale, tw.in_features

    @jax.jit
    def f(xx, pk):
        w = dp.TernaryWeight.from_packed(pk, scale, k)
        return dp.ternary_matmul(xx, w, policy="fixed:lut_onehot")

    np.testing.assert_allclose(np.asarray(f(x, packed)), ref, **TOL["float32"])
    # second trace with a different fixed kernel reuses nothing stale
    @jax.jit
    def g(xx, pk):
        w = dp.TernaryWeight.from_packed(pk, scale, k)
        return dp.ternary_matmul(xx, w, policy="fixed:lut_gather")

    np.testing.assert_allclose(np.asarray(g(x, packed)), ref, **TOL["float32"])


def test_weight_container_roundtrips():
    x, tw, ref = _problem(2, 25, 11, "float32")
    # packed -> trits -> keys all describe the same matrix
    tw2 = dp.TernaryWeight.from_packed(tw.packed(), tw.scale, tw.in_features)
    assert np.array_equal(np.asarray(tw2.trits()), np.asarray(tw.trits()))
    assert np.array_equal(np.asarray(tw2.keys(3)), np.asarray(tw.keys(3)))
    y = dp.ternary_matmul(x, tw2, policy="fixed:dequant_packed")
    np.testing.assert_allclose(np.asarray(y), ref, **TOL["float32"])


# ---------------------------------------------------------------------------
# selection properties
# ---------------------------------------------------------------------------


@settings(max_examples=50, deadline=None)
@given(st.integers(1, 64), st.integers(1, 96), st.integers(1, 96),
       st.sampled_from(DTYPES), st.sampled_from(["cpu", "tpu", "gpu"]))
def test_auto_always_returns_valid_kernel(m, k, n, dtype, backend):
    empty = dp.AutotuneCache(path="/nonexistent/autotune.json")
    for policy in ("auto", "prior"):
        spec = dp.select_kernel(m, k, n, dtype, policy=policy,
                                backend=backend, cache=empty)
        assert spec.name in dp.REGISTRY
        assert spec.supports(m, k, n, dtype)


@settings(max_examples=25, deadline=None)
@given(st.integers(1, 32), st.integers(1, 64), st.integers(1, 64),
       st.sampled_from(KERNELS))
def test_auto_honors_cache_best_when_eligible(m, k, n, kernel):
    cache = dp.AutotuneCache(path="/nonexistent/autotune.json")
    for name in KERNELS:
        cache.record(m, k, n, "float32", "cpu", name,
                     1.0 if name == kernel else 1e6)
    spec = dp.select_kernel(m, k, n, "float32", policy="auto", backend="cpu",
                            cache=cache)
    if dp.get_kernel(kernel).supports(m, k, n, "float32"):
        assert spec.name == kernel
    else:  # ineligible best (w2a8 on float) falls back to a valid kernel
        assert spec.supports(m, k, n, "float32")


def test_fixed_policy_validation():
    with pytest.raises(KeyError, match="unknown kernel"):
        dp.select_kernel(2, 8, 8, "float32", policy="fixed:nope")
    with pytest.raises(ValueError, match="does not support"):
        dp.select_kernel(2, 8, 8, "float32", policy="fixed:w2a8")
    with pytest.raises(ValueError, match="unknown policy"):
        dp.select_kernel(2, 8, 8, "float32", policy="fastest")


def test_prior_tracks_paper_structure():
    """The static prior inherits the paper's findings: at FP16 compute the
    LUT datapath beats dequant and sign-flip; packed paths win the
    bandwidth-bound (small-M) regime over dense-bf16 streaming."""
    on_tpu = functools.partial(dp.static_prior, m=256, k=4096, n=4096,
                               act_dtype="float16", backend="tpu")
    lut = on_tpu(dp.get_kernel("lut_onehot"))
    assert lut < on_tpu(dp.get_kernel("dequant_packed"))
    assert lut < on_tpu(dp.get_kernel("signflip"))
    # decode shape (M=1): 1.6 b/w streaming beats 16 b/w dense ref
    dec = functools.partial(dp.static_prior, m=1, k=4096, n=4096,
                            act_dtype="float16", backend="tpu")
    assert dec(dp.get_kernel("dequant_packed")) < dec(dp.get_kernel("ref"))


def test_env_var_policy(monkeypatch):
    monkeypatch.setenv(dp.DEFAULT_POLICY_ENV, "fixed:signflip")
    assert dp.select_kernel(2, 16, 8, "float32", policy=None).name == "signflip"


# ---------------------------------------------------------------------------
# autotune cache
# ---------------------------------------------------------------------------


def test_autotune_cache_roundtrip(tmp_autotune_cache):
    cache = dp.get_autotune_cache()
    assert str(tmp_autotune_cache) == cache.path
    cache.record(4, 32, 16, "float32", "cpu", "signflip", 11.0)
    cache.record(4, 32, 16, "float32", "cpu", "ref", 99.0)
    cache.save()
    reloaded = dp.AutotuneCache.load(cache.path)
    assert reloaded.best(4, 32, 16, "float32", "cpu") == "signflip"
    assert reloaded.timings(4, 32, 16, "float32", "cpu")["ref"] == 99.0
    # stale kernels in a cache file never dispatch
    reloaded.record(4, 32, 16, "float32", "cpu", "deleted_kernel", 0.1)
    assert reloaded.best(4, 32, 16, "float32", "cpu") == "signflip"


def test_autotune_measures_and_dispatch_uses_it(tmp_autotune_cache):
    timings = dp.autotune(2, 20, 9, "float32", reps=1,
                          kernels=["ref", "signflip"])
    assert set(timings) == {"ref", "signflip"}
    assert all(t > 0 for t in timings.values())
    assert tmp_autotune_cache.exists()
    best = min(timings, key=timings.get)
    spec = dp.select_kernel(2, 20, 9, "float32", policy="auto")
    assert spec.name == best
    # and the full entry survives a cold reload
    dp.reset_autotune_cache()
    assert dp.select_kernel(2, 20, 9, "float32", policy="auto").name == best


def test_corrupt_cache_file_is_ignored(tmp_autotune_cache):
    tmp_autotune_cache.write_text("{not json")
    cache = dp.AutotuneCache.load(str(tmp_autotune_cache))
    assert len(cache) == 0


# ---------------------------------------------------------------------------
# serving end-to-end
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def packed_smoke_model():
    from repro.configs.registry import get_smoke_config
    from repro.models.decode import quantize_for_serving
    from repro.models.model import init_params

    cfg = get_smoke_config("qwen3-0.6b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    return cfg, quantize_for_serving(params, cfg)


def test_engine_end_to_end_policy_auto(packed_smoke_model, tmp_autotune_cache):
    from repro.serving.engine import DecodeEngine, Request

    cfg, sp = packed_smoke_model
    eng = DecodeEngine(sp, cfg, batch_size=2, max_len=32,
                       matmul_policy="auto")
    reqs = eng.run([Request(prompt=[3, 4, 5], max_new_tokens=4),
                    Request(prompt=[7, 8], max_new_tokens=4)])
    assert [len(r.out) for r in reqs] == [4, 4]
    assert all(0 <= t < cfg.padded_vocab for r in reqs for t in r.out)
    # reproducibility pin: a fixed ref dispatch decodes identical tokens
    pin = DecodeEngine(sp, cfg, batch_size=2, max_len=32,
                       matmul_policy="fixed:ref")
    reqs_pin = pin.run([Request(prompt=[3, 4, 5], max_new_tokens=4),
                        Request(prompt=[7, 8], max_new_tokens=4)])
    assert [r.out for r in reqs_pin] == [r.out for r in reqs]


def test_engine_autotune_shapes(packed_smoke_model, tmp_autotune_cache):
    from repro.models.decode import layer_matmul_shapes
    from repro.serving.engine import DecodeEngine

    cfg, sp = packed_smoke_model
    eng = DecodeEngine(sp, cfg, batch_size=2, max_len=32, prefill_chunk=8)
    results = eng.autotune_shapes(reps=1, kernels=["ref", "signflip"])
    # decode shapes (M = B) plus the admission-chunk bucket shape
    # (M = 1·chunk: requests prefill one at a time, chunk by chunk), so
    # policy="auto" admission hits measured entries instead of the prior
    want = set(layer_matmul_shapes(cfg, 2))
    want |= set(layer_matmul_shapes(cfg, 1, seq_len=8))
    assert sorted(results) == sorted(want)
    assert sorted(results) == eng.matmul_shape_universe()
    cache = dp.get_autotune_cache()
    for (m, k, n) in results:
        assert cache.best(m, k, n, cfg.dtype, jax.default_backend()) is not None


def test_layer_matmul_shapes_cover_real_dispatch(packed_smoke_model,
                                                 monkeypatch):
    """Drift guard: every (M, K, N) the serving step actually dispatches must
    be enumerated by layer_matmul_shapes — the hand-written shape arithmetic
    is only trustworthy while this holds."""
    import jax.numpy as jnp

    from repro.models.decode import decode_step, layer_matmul_shapes, prefill

    cfg, sp = packed_smoke_model
    B, S = 2, 8
    seen: set[tuple[int, int, int]] = set()
    orig = dp.select_kernel

    def spy(m, k, n, act_dtype, **kw):
        seen.add((m, k, n))
        return orig(m, k, n, act_dtype, **kw)

    monkeypatch.setattr(dp, "select_kernel", spy)
    batch = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}
    cache, _ = jax.eval_shape(
        lambda p, b: prefill(p, cfg, b, s_max=16), sp, batch)
    prefill_seen = set(seen)
    assert prefill_seen, "prefill dispatched no ternary matmuls"
    assert prefill_seen <= set(layer_matmul_shapes(cfg, B, seq_len=S))

    seen.clear()
    jax.eval_shape(
        lambda p, c: decode_step(p, cfg, c, jnp.zeros((B,), jnp.int32),
                                 jnp.asarray(S, jnp.int32)), sp, cache)
    assert seen, "decode dispatched no ternary matmuls"
    assert seen <= set(layer_matmul_shapes(cfg, B))


def test_layer_matmul_shapes_scale_with_batch():
    from repro.configs.registry import get_smoke_config
    from repro.models.decode import layer_matmul_shapes

    cfg = get_smoke_config("qwen3-0.6b")
    s1 = layer_matmul_shapes(cfg, 1)
    s8 = layer_matmul_shapes(cfg, 1, seq_len=8)
    assert {(k, n) for _, k, n in s1} == {(k, n) for _, k, n in s8}
    assert all(m == 1 for m, _, _ in s1)
    assert all(m == 8 for m, _, _ in s8)
    d = cfg.d_model
    assert (1, d, cfg.q_dim) in s1 and (1, cfg.d_ff, d) in s1
