"""Offline dense encoding (§III-D): roundtrips, widths, density claims."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import encoding as e

MUS = [1, 2, 3, 4, 5, 6]


@pytest.mark.parametrize("mu", MUS)
def test_group_key_roundtrip_exhaustive(mu):
    """Every ternary combo of size mu encodes/decodes exactly."""
    if 3**mu > 3**6:
        pytest.skip("too large")
    n = 3**mu
    vals = np.arange(n)
    trits = np.stack([(vals // 3**i) % 3 - 1 for i in range(mu)], axis=1).astype(np.int8)
    keys = e.encode_groups(jnp.asarray(trits)[None], mu)
    dec = e.decode_groups(keys, mu)
    np.testing.assert_array_equal(np.asarray(dec)[0], trits)


@settings(max_examples=25, deadline=None)
@given(st.integers(1, 5), st.integers(1, 7), st.integers(1, 9), st.integers(0, 2**31 - 1))
def test_group_key_roundtrip_random(mu, a, b, seed):
    rng = np.random.default_rng(seed)
    w = rng.integers(-1, 2, size=(a, b, mu)).astype(np.int8)
    k = e.encode_groups(jnp.asarray(w), mu)
    assert np.asarray(k).dtype == (np.uint8 if e.key_bits(mu) <= 8 else np.uint16)
    np.testing.assert_array_equal(np.asarray(e.decode_groups(k, mu)), w)


def test_key_widths_match_paper():
    # §III-D: width = ceil(log2((3^mu-1)/2)) + 1; mu=3 → 5 bits, mu=5 → 8 bits
    assert e.key_bits_paper(3) == 5 and e.key_bits(3) == 5
    assert e.key_bits_paper(5) == 8 and e.key_bits(5) == 8
    # our exact width is +1 at mu∈{1,2} (zero-group representability)
    assert e.key_bits(2) == e.key_bits_paper(2) + 1


def test_density_claims():
    # paper: ≈1.6 bits/weight at mu=5, within 1% of log2(3); 20% below 2-bit
    bpw = e.bits_per_weight(5)
    assert bpw == pytest.approx(1.6, abs=1e-9)
    assert bpw / np.log2(3) < 1.01
    assert (2.0 - bpw) / 2.0 == pytest.approx(0.20, abs=1e-9)


@settings(max_examples=25, deadline=None)
@given(st.integers(1, 64), st.integers(1, 6), st.integers(0, 2**31 - 1))
def test_base3_pack_roundtrip(n, rows, seed):
    rng = np.random.default_rng(seed)
    w = rng.integers(-1, 2, size=(rows, n)).astype(np.int8)
    p = e.pack_base3(jnp.asarray(w))
    assert p.shape[-1] == -(-n // 5)
    np.testing.assert_array_equal(np.asarray(e.unpack_base3(p, n)), w)


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 40), st.integers(0, 2**31 - 1))
def test_2bit_pack_roundtrip(n, seed):
    rng = np.random.default_rng(seed)
    w = rng.integers(-1, 2, size=(3, n)).astype(np.int8)
    p = e.pack_2bit(jnp.asarray(w))
    np.testing.assert_array_equal(np.asarray(e.unpack_2bit(p, n)), w)


def test_combo_matrix_symmetry():
    for mu in (1, 2, 3, 4):
        C = e.combo_matrix_np(mu)
        T = e.table_size(mu)
        assert C.shape == (T + 1, mu)
        assert (C[T] == 0).all()  # reserved zero row
        # stored combos are the positive half: most significant non-zero = +1
        for row in C[:T]:
            nz = np.nonzero(row)[0]
            assert len(nz) > 0 and row[nz[-1]] == 1


def test_packed_matrix_density():
    rng = np.random.default_rng(1)
    w = jnp.asarray(rng.integers(-1, 2, size=(64, 100)), jnp.int8)
    p = e.pack_ternary_matrix(w, jnp.float32(0.5))
    assert p.bits_per_weight == pytest.approx(1.6, abs=1e-9)
    np.testing.assert_array_equal(np.asarray(e.unpack_ternary_matrix(p)), np.asarray(w))
