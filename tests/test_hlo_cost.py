"""Trip-count-aware HLO cost analysis: the roofline's FLOP source of truth.

Documents and guards the XLA behavior that motivated it: ``cost_analysis()``
counts while-loop bodies once, so scan-over-layers models are undercounted
by ~n_layers.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.hlo_cost import analyze

X = jax.ShapeDtypeStruct((64, 128), jnp.float32)
W = jax.ShapeDtypeStruct((10, 128, 128), jnp.float32)
FLOPS_ONE = 2 * 64 * 128 * 128
FLOPS_ALL = 10 * FLOPS_ONE


def _scan(x, w):
    def body(c, wi):
        return jnp.tanh(c @ wi), None
    return jax.lax.scan(body, x, w)[0]


def _unroll(x, w):
    for i in range(10):
        x = jnp.tanh(x @ w[i])
    return x


def test_xla_cost_analysis_counts_loops_once():
    c = jax.jit(_scan).lower(X, W).compile()
    ca = c.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    assert float(ca["flops"]) == pytest.approx(FLOPS_ONE, rel=0.01)


def test_analyze_scan_equals_unroll():
    cs = jax.jit(_scan).lower(X, W).compile()
    cu = jax.jit(_unroll).lower(X, W).compile()
    rs, ru = analyze(cs.as_text()), analyze(cu.as_text())
    assert rs["flops"] == pytest.approx(FLOPS_ALL, rel=0.01)
    assert ru["flops"] == pytest.approx(FLOPS_ALL, rel=0.01)
    assert rs["bytes"] > 0


def test_analyze_nested_scans():
    def f(x, w):
        def outer(c, wg):
            c = _scan(c, wg)
            return c, None
        return jax.lax.scan(outer, x, w.reshape(5, 2, 128, 128))[0]
    c = jax.jit(f).lower(X, W).compile()
    assert analyze(c.as_text())["flops"] == pytest.approx(FLOPS_ALL, rel=0.01)


def test_analyze_grad_with_remat():
    def loss(w, x):
        def body(c, wi):
            return jnp.tanh(c @ wi), None
        y, _ = jax.lax.scan(jax.checkpoint(body), x, w)
        return jnp.sum(y ** 2)
    c = jax.jit(jax.grad(loss)).lower(W, X).compile()
    # fwd + remat-fwd + dgrad + wgrad = 4 matmuls per layer
    assert analyze(c.as_text())["flops"] == pytest.approx(4 * FLOPS_ALL, rel=0.02)


def test_model_flops_close_to_analytic():
    """A reduced dense LM's counted train FLOPs ≈ 6·N·D analytic estimate."""
    from repro.configs.registry import get_smoke_config
    from repro.models.model import init_params, train_loss

    cfg = get_smoke_config("qwen3-0.6b").with_(
        n_layers=4, vocab_size=256, loss_chunk=32)
    key = jax.random.PRNGKey(0)
    params = jax.eval_shape(lambda k: init_params(cfg, k), key)
    B, S = 4, 64
    batch = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
             "labels": jax.ShapeDtypeStruct((B, S), jnp.int32),
             "loss_mask": jax.ShapeDtypeStruct((B, S), jnp.float32)}
    g = jax.jit(jax.grad(lambda p, b: train_loss(p, cfg, b)[0]))
    compiled = g.lower(params, batch).compile()
    counted = analyze(compiled.as_text())["flops"]
    analytic = 6 * cfg.param_count() * B * S
    # remat adds ~33% (extra fwd); attention/score flops add more; embed is
    # gather (not counted as dot).  Expect counted within [0.9, 2.5]× of 6ND.
    assert 0.9 * analytic < counted < 2.5 * analytic
