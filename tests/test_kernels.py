"""Pallas kernels vs pure-jnp oracles (interpret mode), sweeping shapes,
dtypes, group sizes and block geometries."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import encoding
from repro.kernels import ref
from repro.kernels.dequant_matmul import packed_matmul
from repro.kernels.lut_matmul import lut_matmul
from repro.kernels.signflip_matmul import signflip_matmul


def _data(seed, B, O, N, dtype=jnp.float32):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(B, N)), dtype)
    w = jnp.asarray(rng.integers(-1, 2, size=(O, N)), jnp.int8)
    return x, w


@pytest.mark.parametrize("B,O,N,bb,bo,bn", [
    (1, 8, 16, 1, 8, 16),
    (4, 37, 60, 2, 16, 20),
    (8, 128, 256, 8, 64, 64),
    (3, 5, 7, 2, 4, 5),
])
def test_signflip_kernel(B, O, N, bb, bo, bn):
    x, w = _data(0, B, O, N)
    y = signflip_matmul(x, w, block_b=bb, block_o=bo, block_n=bn)
    y_ref = ref.signflip_matmul_ref(x, w)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_signflip_dtypes(dtype):
    x, w = _data(1, 4, 16, 40, dtype)
    y = signflip_matmul(x, w, block_b=2, block_o=8, block_n=20)
    y_ref = x.astype(jnp.float32) @ w.astype(jnp.float32).T
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=2e-2 if dtype == jnp.bfloat16 else 1e-5,
                               atol=1e-1 if dtype == jnp.bfloat16 else 1e-4)


@pytest.mark.parametrize("B,O,N", [(1, 8, 15), (4, 37, 60), (2, 9, 101)])
def test_packed_kernel(B, O, N):
    x, w = _data(2, B, O, N)
    p = encoding.pack_base3(w)
    y = packed_matmul(x, p, N, block_b=2, block_o=8, block_n=20)
    y_ref = ref.packed_matmul_ref(x, p, N)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("mu", [1, 2, 3, 4, 5])
@pytest.mark.parametrize("fetch", ["onehot", "gather"])
def test_lut_kernel_mu_sweep(mu, fetch):
    B, O, N = 4, 21, 36
    x, w = _data(3, B, O, N)
    keys = encoding.encode_weight_matrix(w, mu)
    G = keys.shape[1]
    xp = jnp.pad(x, ((0, 0), (0, G * mu - N)))
    y = lut_matmul(xp, keys, mu, block_b=2, block_o=8, block_g=5, fetch=fetch)
    y_ref = ref.lut_matmul_ref(xp, keys, mu)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), rtol=1e-5, atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 5), st.integers(1, 16), st.integers(1, 48),
       st.integers(1, 6), st.integers(0, 2**31 - 1))
def test_lut_kernel_property(mu, O, N, B, seed):
    x, w = _data(seed, B, O, N)
    keys = encoding.encode_weight_matrix(w, mu)
    G = keys.shape[1]
    xp = jnp.pad(x, ((0, 0), (0, G * mu - N)))
    y = lut_matmul(xp, keys, mu, block_b=4, block_o=16, block_g=8)
    np.testing.assert_allclose(
        np.asarray(y), np.asarray(x @ w.astype(jnp.float32).T), rtol=1e-4, atol=1e-3)


def test_ops_wrappers_roundtrip():
    from repro.kernels import ops
    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.normal(size=(4, 40)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(16, 40)), jnp.float32)
    from repro.core.quantization import dequantize, ternarize
    w_t, s = ternarize(w)
    y_want = np.asarray(x @ dequantize(w_t, s, jnp.float32).T)

    keys, scale = ops.encode_for_lut(w, 3)
    G = keys.shape[1]
    y1 = ops.ternary_linear_lut(jnp.pad(x, ((0, 0), (0, G * 3 - 40))), keys, scale, 3)
    np.testing.assert_allclose(np.asarray(y1), y_want, rtol=2e-2, atol=1e-2)

    packed, scale = ops.encode_packed(w)
    y2 = ops.ternary_linear_packed(x, packed, scale, 40)
    np.testing.assert_allclose(np.asarray(y2), y_want, rtol=2e-2, atol=1e-2)

    y3 = ops.ternary_linear_signflip(x, w_t, s)
    np.testing.assert_allclose(np.asarray(y3), y_want, rtol=2e-2, atol=1e-2)
