"""Launch-layer tooling: report rendering, profiler, roofline math,
collective parsing edge cases."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch import hlo_cost, mesh as mesh_mod, roofline as rl
from repro.launch.profile import profile_text
from repro.launch.report import dryrun_table, roofline_table, summary


def _fake_rec(arch="a", shape="train_4k", mesh="16x16", **kw):
    roof = rl.Roofline(chips=256, flops_per_device=1e12, bytes_per_device=1e11,
                       collective_bytes_per_device=1e10)
    r = {"arch": arch, "shape": shape, "mesh": mesh, "ok": True,
         "roofline": roof.as_dict(), "model_flops_ratio": 0.7,
         "param_bytes_per_device": 1e9, "compile_s": 10,
         "memory_analysis": {"temp_size_in_bytes": int(2e9)}}
    r.update(kw)
    return r


def test_roofline_terms_and_bottleneck():
    r = rl.Roofline(chips=256, flops_per_device=197e12,
                    bytes_per_device=819e9, collective_bytes_per_device=0.0)
    assert r.compute_s == pytest.approx(1.0)
    assert r.memory_s == pytest.approx(1.0)
    assert r.bottleneck in ("compute", "memory")
    r2 = rl.Roofline(chips=256, flops_per_device=0, bytes_per_device=0,
                     collective_bytes_per_device=50e9)
    assert r2.bottleneck == "collective" and r2.step_time_s == pytest.approx(1.0)


def test_model_flops_kinds():
    from repro.configs.registry import get_config
    from repro.configs.shapes import SHAPES

    cfg = get_config("qwen3-0.6b")
    tr = rl.model_flops(cfg, SHAPES["train_4k"], "train")
    pf = rl.model_flops(cfg, SHAPES["prefill_32k"], "prefill")
    de = rl.model_flops(cfg, SHAPES["decode_32k"], "decode")
    assert tr == 6 * cfg.active_param_count() * 256 * 4096
    assert pf == 2 * cfg.active_param_count() * 32 * 32768
    assert de == 2 * cfg.active_param_count() * 128


def test_report_tables_render():
    recs = [_fake_rec(), _fake_rec(mesh="2x16x16"),
            _fake_rec(arch="b", shape="decode_32k",
                      cache_bytes_per_device=3e9)]
    t1 = roofline_table(recs)
    assert "| a | train_4k |" in t1
    t2 = dryrun_table(recs)
    assert "2x16x16" in t2
    assert "cells compiled" in summary(recs)


def test_profile_text_on_tiny_program():
    def f(x, w):
        def body(c, wi):
            return jnp.tanh(c @ wi), None
        return jax.lax.scan(body, x, w)[0]

    c = jax.jit(f).lower(jax.ShapeDtypeStruct((32, 64), jnp.float32),
                         jax.ShapeDtypeStruct((4, 64, 64), jnp.float32)).compile()
    out = profile_text(c.as_text(), top=5)
    assert "total:" in out and "GFLOP" in out


def test_collective_parser_shapes():
    text = """HloModule m
ENTRY %main (p: f32[16]) -> f32[16] {
  %p = f32[16]{0} parameter(0)
  %ag = f32[256]{0} all-gather(%p), replica_groups=[16,16]<=[256], dimensions={0}
  %rs = f32[16]{0} reduce-scatter(%ag), replica_groups=[16,16]<=[256], dimensions={0}, to_apply=%add
  ROOT %ar = f32[16]{0} all-reduce(%rs), replica_groups=[16,16]<=[256], to_apply=%add
}
"""
    r = hlo_cost.analyze(text)
    assert r["collectives"]["all-gather"] == 256 * 4
    assert r["collectives"]["reduce-scatter"] == 16 * 4 * 16  # scaled by group
    assert r["collectives"]["all-reduce"] == 16 * 4


def test_hardware_constants():
    assert mesh_mod.PEAK_FLOPS_BF16 == 197e12
    assert mesh_mod.HBM_BW == 819e9
    assert mesh_mod.ICI_BW == 50e9
    assert mesh_mod.CHIPS_MULTI_POD == 2 * 256
