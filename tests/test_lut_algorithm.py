"""Two-phase LUT algorithm oracle: exact equality with plain matmul."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import encoding, lut_algorithm as la


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 5), st.integers(1, 24), st.integers(1, 48),
       st.integers(0, 2**31 - 1))
def test_lut_matmul_equals_matmul_int(mu, o, n, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.integers(-9, 9, size=(3, n)), jnp.int32)
    w = jnp.asarray(rng.integers(-1, 2, size=(o, n)), jnp.int32)
    y = la.lut_matmul(x, w, mu)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(x @ w.T))


@pytest.mark.parametrize("mu", [1, 2, 3, 5])
def test_lut_matmul_float(mu):
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(4, 30)), jnp.float32)
    w = jnp.asarray(rng.integers(-1, 2, size=(11, 30)), jnp.int8)
    y = la.lut_matmul(x, w.astype(jnp.float32), mu)
    np.testing.assert_allclose(np.asarray(y), np.asarray(x @ w.astype(jnp.float32).T),
                               rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("mu", [1, 2, 3])
def test_onehot_fetch_mode(mu):
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(2, 18)), jnp.float32)
    w = jnp.asarray(rng.integers(-1, 2, size=(7, 18)), jnp.int8)
    keys = encoding.encode_weight_matrix(w, mu)
    y = la.lut_matmul_onehot(x, keys, mu)
    np.testing.assert_allclose(np.asarray(y), np.asarray(x @ w.astype(jnp.float32).T),
                               rtol=1e-5, atol=1e-4)


def test_build_phase_table_contents():
    """Table row g must hold every symmetry-reduced partial sum of group g."""
    mu = 3
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.integers(-5, 5, size=(1, 2, mu)), jnp.int32)
    tables = la.lut_build(x, mu)
    C = encoding.combo_matrix_np(mu).astype(np.int64)
    want = np.asarray(x)[0] @ C.T
    np.testing.assert_array_equal(np.asarray(tables)[0], want)
    assert (np.asarray(tables)[..., -1] == 0).all()  # hardwired zero entry
