"""Per-architecture smoke tests (reduced configs, CPU) + decode consistency.

Every assigned arch instantiates a structure-preserving reduced config and
runs one forward/train step asserting output shapes and finiteness; the
attention family additionally checks prefill+decode against a longer
teacher-forced forward.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ARCHS, ASSIGNED, get_smoke_config
from repro.models.decode import (
    decode_step,
    packed_bits_per_weight,
    prefill,
    quantize_for_serving,
)
from repro.models.model import forward, init_params, train_loss

B, S = 2, 24


def make_batch(cfg, tokens=None):
    t = tokens if tokens is not None else jnp.full((B, S), 3, jnp.int32)
    batch = {"tokens": t,
             "labels": jnp.roll(t, -1, axis=1),
             "loss_mask": jnp.ones(t.shape, jnp.float32)}
    if cfg.frontend == "audio_stub":
        batch["frames"] = jnp.full((t.shape[0], cfg.enc_seq, cfg.d_model), 0.1,
                                   jnp.bfloat16)
    if cfg.frontend == "vit_stub":
        batch["vision_embeds"] = jnp.full(
            (t.shape[0], cfg.vision_tokens, cfg.d_model), 0.1, jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_arch_smoke_train_step(arch, key):
    cfg = get_smoke_config(arch)
    p = init_params(cfg, key)
    batch = make_batch(cfg)
    (loss, metrics), grads = jax.value_and_grad(train_loss, has_aux=True)(
        p, cfg, batch)
    assert np.isfinite(float(loss)), arch
    h, _ = forward(p, cfg, batch)
    assert h.shape == (B, S, cfg.d_model)
    assert np.isfinite(np.asarray(h, np.float32)).all()
    gn = sum(float(jnp.sum(jnp.abs(g.astype(jnp.float32))))
             for g in jax.tree.leaves(grads))
    assert np.isfinite(gn) and gn > 0


@pytest.mark.parametrize("arch", sorted(ASSIGNED))
def test_arch_smoke_serving(arch, key):
    cfg = get_smoke_config(arch)
    p = init_params(cfg, key)
    sp = quantize_for_serving(p, cfg)
    assert packed_bits_per_weight(sp) <= 1.61  # paper's density (pad ≤ 1%)
    batch = make_batch(cfg)
    batch.pop("labels"), batch.pop("loss_mask")
    cache, logits = prefill(sp, cfg, batch, s_max=S + 4)
    assert logits.shape == (B, cfg.padded_vocab)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    assert int(tok.max()) < cfg.vocab_size  # padding masked
    logits2, cache = decode_step(sp, cfg, cache, tok, jnp.asarray(S, jnp.int32))
    assert np.isfinite(np.asarray(logits2)).all()


@pytest.mark.parametrize("arch", ["qwen3-0.6b", "gemma-7b", "zamba2-2.7b",
                                  "xlstm-125m", "whisper-large-v3"])
def test_decode_consistency_with_forward(arch, key):
    """prefill(S) + decode(token_S) must match a teacher-forced forward over
    S+1 tokens at the last position (same packed weights both sides)."""
    cfg = get_smoke_config(arch).with_(remat=False)
    p = init_params(cfg, key)
    sp = quantize_for_serving(p, cfg)
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(1, cfg.vocab_size, size=(B, S + 1)), jnp.int32)

    b_long = make_batch(cfg, toks)
    b_long.pop("labels"), b_long.pop("loss_mask")
    _, logits_long = prefill(sp, cfg, b_long, s_max=S + 1)

    b_short = make_batch(cfg, toks[:, :S])
    b_short.pop("labels"), b_short.pop("loss_mask")
    cache, _ = prefill(sp, cfg, b_short, s_max=S + 1)
    logits_step, _ = decode_step(sp, cfg, cache, toks[:, S],
                                 jnp.asarray(S, jnp.int32))

    a = np.asarray(logits_long, np.float32)
    b = np.asarray(logits_step, np.float32)
    # same computation along two code paths; bf16 params + different
    # accumulation orders → loose-but-meaningful tolerance
    mask = np.abs(a) < 1e29  # ignore the -inf vocab padding
    corr = np.corrcoef(a[mask].ravel(), b[mask].ravel())[0, 1]
    assert corr > 0.99, f"{arch}: decode/forward corr {corr}"
    np.testing.assert_allclose(a[mask], b[mask], rtol=0.3, atol=0.3)


def test_vlm_prefix_injection(key):
    cfg = get_smoke_config("internvl2-2b")
    p = init_params(cfg, key)
    batch = make_batch(cfg)
    h1, _ = forward(p, cfg, batch)
    batch2 = dict(batch)
    batch2["vision_embeds"] = batch["vision_embeds"] * 0 + 0.7
    h2, _ = forward(p, cfg, batch2)
    # changing the vision prefix must change hidden states
    assert float(jnp.max(jnp.abs((h1 - h2).astype(jnp.float32)))) > 1e-3


def test_moe_aux_loss_nonzero(key):
    cfg = get_smoke_config("phi3.5-moe-42b-a6.6b")
    p = init_params(cfg, key)
    _, metrics = train_loss(p, cfg, make_batch(cfg))
    assert float(metrics["aux"]) > 0


def test_window_attention_masks_past(key):
    """A sliding window must change logits vs full attention on long inputs."""
    cfg = get_smoke_config("qwen3-0.6b").with_(remat=False)
    p = init_params(cfg, key)
    rng = np.random.default_rng(1)
    toks = jnp.asarray(rng.integers(1, cfg.vocab_size, size=(1, 32)), jnp.int32)
    h_full, _ = forward(p, cfg, {"tokens": toks})
    h_win, _ = forward(p, cfg.with_(window=4), {"tokens": toks})
    assert float(jnp.max(jnp.abs((h_full - h_win).astype(jnp.float32)))) > 1e-3
    # and the first window-positions agree (no past to mask there)
    np.testing.assert_allclose(np.asarray(h_full[:, :4], np.float32),
                               np.asarray(h_win[:, :4], np.float32),
                               rtol=1e-2, atol=1e-2)
