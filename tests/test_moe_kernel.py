"""MoE expert matmuls through the grouped dispatch layer.

Differential oracle: ``moe_ffn`` with packed expert weights now routes
through ``repro.kernels.dispatch.grouped_ternary_matmul`` — its output must
match the pre-dispatch eager-einsum path (full stacked dequant + einsum)
bit-for-bit up to bf16 output rounding, across routing and capacity
dropping, because the rewire changed only the *kernel*, never the math.

Memory oracle: the packed path must never materialize the dense
``[E, d_out, d_in]`` expert stack (asserted on the jaxpr, recursively
through scan/jit bodies) — that full-dequant temporary every step was
exactly the bandwidth bug this kernel family removes.

Plus: the engine's grouped autotune warm-up, dispatch-policy governance of
MoE (pins, shape-universe coverage), and the chunked-prefill fallback debug
log for interleaved-MoE stacks.
"""

import logging

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.models.layers as layers_mod
from repro.core import encoding
from repro.kernels import dispatch as dp
from repro.models.layers import moe_ffn


def _moe_cfg(**overrides):
    from repro.configs.registry import get_smoke_config

    return get_smoke_config("phi3.5-moe-42b-a6.6b", **overrides)


@pytest.fixture(scope="module")
def packed_moe_model():
    from repro.models.decode import quantize_for_serving
    from repro.models.model import init_params

    cfg = _moe_cfg()
    params = init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params, quantize_for_serving(params, cfg)


def _moe_block(sp):
    """Layer-0 slice of the stacked MoE block (what the scan feeds a layer)."""
    return jax.tree.map(lambda t: t[0], sp["blocks"])["moe"]


_DISPATCH_EXPERT_MATMUL = layers_mod._expert_matmul  # pre-monkeypatch binding


def _einsum_reference_expert_matmul(leaf, cfg, d_in, role=None):
    """The pre-dispatch packed path: eager full-stack dequant + one einsum.

    Kept verbatim as the differential oracle for the grouped kernels
    (``role`` is the real path's sharding hint — irrelevant here)."""
    if "packed" in leaf:
        w_t = encoding.unpack_base3(leaf["packed"], d_in)  # [E, dout, din]
        scale = leaf["scale"]

        def f(t):
            y = jnp.einsum("ecd,efd->ecf", t, w_t.astype(t.dtype))
            return y * scale[:, None, None].astype(y.dtype)

        return f
    return _DISPATCH_EXPERT_MATMUL(leaf, cfg, d_in)


# ---------------------------------------------------------------------------
# differential: moe_ffn through dispatch ≡ eager einsum path
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("capacity_factor", [1.25, 0.25])
def test_packed_moe_ffn_matches_einsum_path(packed_moe_model, monkeypatch,
                                            capacity_factor):
    """Routing, gating, capacity dropping and the expert matmuls must be
    unchanged by the dispatch rewire — including when the tiny capacity
    factor forces token drops."""
    cfg, _, sp = packed_moe_model
    cfg = cfg.with_(capacity_factor=capacity_factor)
    moe = _moe_block(sp)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 5, cfg.d_model),
                          jnp.bfloat16)
    out, aux = moe_ffn(moe, x, cfg)

    monkeypatch.setattr(layers_mod, "_expert_matmul",
                        _einsum_reference_expert_matmul)
    out_ref, aux_ref = moe_ffn(moe, x, cfg)
    # identical routing → identical aux loss; outputs agree to bf16 rounding
    np.testing.assert_allclose(float(aux), float(aux_ref), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(out_ref, np.float32),
                               rtol=2e-2, atol=2e-2)


def test_qat_moe_ffn_unchanged_by_dispatch(packed_moe_model, monkeypatch):
    """The QAT/train path (dense fake-quant master weights) does not route
    through dispatch — the reference monkeypatch is a no-op there."""
    cfg, params, _ = packed_moe_model
    moe = _moe_block(params)
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 4, cfg.d_model),
                          jnp.bfloat16)
    out, aux = moe_ffn(moe, x, cfg)
    monkeypatch.setattr(layers_mod, "_expert_matmul",
                        _einsum_reference_expert_matmul)
    out_ref, aux_ref = moe_ffn(moe, x, cfg)
    assert np.array_equal(np.asarray(out, np.float32),
                          np.asarray(out_ref, np.float32))
    assert float(aux) == float(aux_ref)


def test_packed_moe_policy_pins_govern_experts(packed_moe_model):
    """fixed:<dense kernel> pins resolve through the grouped variants for
    the expert stacks: ref and dequant_packed agree; LUT pins (no grouped
    analogue) refuse MoE configs loudly."""
    cfg, _, sp = packed_moe_model
    moe = _moe_block(sp)
    x = jax.random.normal(jax.random.PRNGKey(3), (1, 6, cfg.d_model),
                          jnp.bfloat16)
    y_ref, _ = moe_ffn(moe, x, cfg.with_(matmul_policy="fixed:ref"))
    y_deq, _ = moe_ffn(moe, x, cfg.with_(matmul_policy="fixed:dequant_packed"))
    np.testing.assert_allclose(np.asarray(y_ref, np.float32),
                               np.asarray(y_deq, np.float32),
                               rtol=2e-2, atol=2e-2)
    with pytest.raises(ValueError, match="no grouped"):
        moe_ffn(moe, x, cfg.with_(matmul_policy="fixed:lut_onehot"))


# ---------------------------------------------------------------------------
# memory: no [E, d_out, d_in] dense intermediate on the packed path
# ---------------------------------------------------------------------------


def test_packed_moe_ffn_never_materializes_dense_expert_stack(
        packed_moe_model, jaxpr_shape_walker):
    cfg, _, sp = packed_moe_model
    moe = _moe_block(sp)
    E, d, f = cfg.n_experts, cfg.d_model, cfg.d_ff
    dense_stacks = {(E, f, d), (E, d, f)}
    x = jnp.zeros((2, 4, d), jnp.bfloat16)
    jaxpr = jax.make_jaxpr(lambda p, xx: moe_ffn(p, xx, cfg))(moe, x)
    found = jaxpr_shape_walker(jaxpr.jaxpr, dense_stacks)
    assert found == [], (
        f"packed moe_ffn materialized dense expert stacks: {found}")


def test_dense_stack_detector_catches_the_old_path(packed_moe_model,
                                                   monkeypatch,
                                                   jaxpr_shape_walker):
    """Guard the guard: the jaxpr walker must FIND the dense stack in the
    pre-dispatch eager-einsum path, or the assertion above proves nothing."""
    cfg, _, sp = packed_moe_model
    moe = _moe_block(sp)
    E, d, f = cfg.n_experts, cfg.d_model, cfg.d_ff
    monkeypatch.setattr(layers_mod, "_expert_matmul",
                        _einsum_reference_expert_matmul)
    x = jnp.zeros((2, 4, d), jnp.bfloat16)
    jaxpr = jax.make_jaxpr(lambda p, xx: moe_ffn(p, xx, cfg))(moe, x)
    found = jaxpr_shape_walker(jaxpr.jaxpr, {(E, f, d), (E, d, f)})
    assert found, "walker failed to detect the eager full-dequant einsum path"


# ---------------------------------------------------------------------------
# engine integration: shape universe, autotune warm-up, end-to-end decode
# ---------------------------------------------------------------------------


def test_grouped_shapes_cover_real_moe_dispatch(packed_moe_model, monkeypatch):
    """Drift guard (MoE analogue of the dense test in test_dispatch): every
    grouped problem a serving step dispatches must be enumerated by
    layer_grouped_matmul_shapes."""
    from repro.models.decode import (decode_step, init_cache,
                                     layer_grouped_matmul_shapes)

    cfg, _, sp = packed_moe_model
    B = 2
    seen: set[tuple[int, int, int, int]] = set()
    orig = dp.select_kernel

    def spy(m, k, n, act_dtype, **kw):
        if kw.get("e") is not None:
            seen.add((kw["e"], m, k, n))
        return orig(m, k, n, act_dtype, **kw)

    monkeypatch.setattr(dp, "select_kernel", spy)
    cache = jax.eval_shape(lambda: init_cache(cfg, B, 16))
    jax.eval_shape(
        lambda p, c: decode_step(p, cfg, c, jnp.zeros((B,), jnp.int32),
                                 jnp.zeros((B,), jnp.int32)), sp, cache)
    assert seen, "decode dispatched no grouped ternary matmuls"
    assert seen <= set(layer_grouped_matmul_shapes(cfg, B))


def test_moe_engine_autotune_covers_grouped_shapes(packed_moe_model,
                                                   tmp_autotune_cache):
    from repro.models.decode import (layer_grouped_matmul_shapes,
                                     layer_matmul_shapes)
    from repro.serving.engine import DecodeEngine

    cfg, _, sp = packed_moe_model
    eng = DecodeEngine(sp, cfg, batch_size=2, max_len=32, prefill_chunk=8)
    results = eng.autotune_shapes(reps=1,
                                  kernels=["ref", "signflip", "grouped_ref"])
    want = set(layer_matmul_shapes(cfg, 2))
    want |= set(layer_matmul_shapes(cfg, 1, seq_len=8))
    want |= set(layer_grouped_matmul_shapes(cfg, 2))
    want |= set(layer_grouped_matmul_shapes(cfg, 1, seq_len=8))
    assert sorted(results) == sorted(want)
    assert sorted(results) == eng.matmul_shape_universe()
    cache = dp.get_autotune_cache()
    backend = jax.default_backend()
    for shape in results:
        if len(shape) == 4:
            e, c, k, n = shape
            assert cache.best(c, k, n, cfg.dtype, backend, e=e) is not None
        else:
            m, k, n = shape
            assert cache.best(m, k, n, cfg.dtype, backend) is not None


def test_moe_engine_end_to_end(packed_moe_model, tmp_autotune_cache):
    from repro.serving.engine import DecodeEngine, Request

    cfg, _, sp = packed_moe_model
    eng = DecodeEngine(sp, cfg, batch_size=2, max_len=32,
                       matmul_policy="auto")
    reqs = eng.run([Request(prompt=[3, 4, 5], max_new_tokens=3),
                    Request(prompt=[7, 8], max_new_tokens=3)])
    assert [len(r.out) for r in reqs] == [3, 3]
    assert all(0 <= t < cfg.padded_vocab for r in reqs for t in r.out)
    # a fixed ref pin (grouped_ref on the expert stacks) decodes the same
    # tokens as auto on an empty cache (prior → ref/grouped_ref on CPU)
    pin = DecodeEngine(sp, cfg, batch_size=2, max_len=32,
                       matmul_policy="fixed:ref")
    reqs_pin = pin.run([Request(prompt=[3, 4, 5], max_new_tokens=3),
                        Request(prompt=[7, 8], max_new_tokens=3)])
    assert [r.out for r in reqs_pin] == [r.out for r in reqs]


# ---------------------------------------------------------------------------
# chunked-prefill fallback logging (interleaved MoE)
# ---------------------------------------------------------------------------


def test_chunked_prefill_fallback_logs_reason(caplog):
    from repro.configs.registry import get_smoke_config
    from repro.models.decode import supports_chunked_prefill
    from repro.models.model import init_params

    # llama4: interleaved MoE (dense_blocks) → whole-prompt fallback + log
    cfg = get_smoke_config("llama4-maverick-400b-a17b",
                           n_layers=4, n_experts=4)
    params = init_params(cfg, jax.random.PRNGKey(0))
    assert "dense_blocks" in params
    with caplog.at_level(logging.DEBUG, logger="repro.models.decode"):
        assert not supports_chunked_prefill(params, cfg)
    assert any("prefill_into_slot" in r.message and "dense_blocks" in r.message
               for r in caplog.records)

    # uniform MoE (phi3.5): chunked admission supported, nothing logged
    caplog.clear()
    cfg2 = _moe_cfg()
    with caplog.at_level(logging.DEBUG, logger="repro.models.decode"):
        assert supports_chunked_prefill({"blocks": {}}, cfg2)
    assert not caplog.records
