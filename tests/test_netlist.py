"""Hardware-generator structural tests: Eqs. 2-4, the constructive adder DAG,
and the generated module hierarchy."""

import numpy as np
import pytest

from repro.core import netlist as nl
from repro.core.encoding import combo_matrix_np, table_size
from repro.core.generator import LUTCoreConfig, generate


def test_paper_closed_forms():
    # Eq. 3 S(mu): S(2)=1, S(3)=4, S(4)=13, S(5)=40
    assert [nl.S_redundancy(m) for m in (2, 3, 4, 5)] == [1, 4, 13, 40]
    # Eq. 4 R(mu): R(2)=0, R(3)=4, R(4)=24, R(5)=100
    assert [nl.R_sparsity(m) for m in (2, 3, 4, 5)] == [0, 4, 24, 100]
    # Eq. 2 bound: mu=4 → 44 adders
    assert nl.bound_adders(4) == 44


def test_8189_percent_claim():
    """§III-B: optimizations reduce adders by 'as much as 81.89%' at mu=4."""
    assert nl.adder_reduction_vs_naive(4) * 100 == pytest.approx(81.89, abs=0.05)


@pytest.mark.parametrize("mu", [2, 3, 4, 5, 6])
def test_constructive_dag_beats_or_meets_bound(mu):
    prog = nl.build_program(mu)
    assert prog.n_adders == nl.constructive_adders(mu) == table_size(mu) - mu
    assert prog.n_adders <= nl.bound_adders(mu)


@pytest.mark.parametrize("mu", [1, 2, 3, 4, 5])
def test_build_program_computes_combo_matrix(mu):
    """The emitted DAG ('the RTL') must equal its functional spec exactly."""
    from repro.core.simulator import _run_build_program

    rng = np.random.default_rng(0)
    prog = nl.build_program(mu) if mu > 1 else nl.build_program(mu)
    C = combo_matrix_np(mu).astype(np.int64)
    for _ in range(5):
        x = rng.integers(-50, 50, size=mu).astype(np.int64)
        entries = _run_build_program(prog, x)
        np.testing.assert_array_equal(entries, C @ x)


def test_netlist_counts():
    net = nl.make_netlist(mu=3, L=32, K=32)
    assert net.n == 96 and net.m == 32 and net.throughput == 96 * 32
    assert net.acc_adders == 32 * 32          # Eq. 6: L·K
    assert net.mux2_equiv_paper == 32 * 32 * 13   # Eq. 7: L·K·T
    assert net.out_regs == 32                 # Eq. 8: K
    assert net.lut_regs == 13 * 32            # symmetry-reduced storage
    assert net.build_adders == 10 * 32


def test_generator_module_hierarchy():
    d = generate(LUTCoreConfig(mu=3, L=4, K=2, act_dtype="int8"))
    text = d.module_hierarchy()
    assert "LutArray[L=4]" in text and "FacArray[K=2]" in text
    assert "adders=10" in text
    assert d.kernel_plan.block_n % 128 == 0
    r = d.report()
    assert "TOPS/mm^2" in r


def test_generator_validation():
    with pytest.raises(ValueError):
        LUTCoreConfig(mu=0, L=1, K=1)
    with pytest.raises(ValueError):
        LUTCoreConfig(mu=2, L=0, K=1)
    with pytest.raises(ValueError):
        LUTCoreConfig(mu=2, L=1, K=1, act_dtype="fp64")


def test_build_depth_is_logarithmic_bound():
    # our chain construction has depth ≤ mu-1 (one adder per extra trit)
    for mu in (2, 3, 4, 5):
        assert nl.build_program(mu).depth <= mu - 1
