"""Chunked, length-bucketed prefill: the differential oracle.

Chunked prefill (``prefill_chunk`` scans, the continuous-admission path)
must reproduce whole-prompt ``prefill`` — same ring layout bit-for-bit,
same KV, same last-position logits up to bf16 accumulation noise — for
windowed and non-windowed configs, including prompts with ``S >= CL`` that
wrap the ring (the configuration the pre-fix slot misalignment corrupted).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_smoke_config
from repro.models.decode import (
    cache_len,
    decode_step,
    prefill,
    prefill_chunk,
    prefill_chunked,
    prefill_chunks_of,
    quantize_for_serving,
    supports_chunked_prefill,
)
from repro.models.model import init_params

# a few bf16 ulps at the observed logit scale (|logits| <~ 8 on the tiny
# random models): chunked attention merges online-softmax chunks in a
# different order than the whole-prompt pass, so the last bf16 bits differ
TOL = dict(rtol=2e-2, atol=8e-2)


def _tiny(window=0):
    cfg = get_smoke_config("bitnet-b1.58-2b").with_(
        n_layers=2, d_model=64, n_heads=2, n_kv_heads=2, head_dim=32,
        d_ff=128, vocab_size=256, loss_chunk=32, window=window, remat=False)
    sp = quantize_for_serving(init_params(cfg, jax.random.PRNGKey(0)), cfg)
    return cfg, sp


def _close(a, b, **kw):
    a, b = np.asarray(a, np.float32), np.asarray(b, np.float32)
    m = np.abs(a) < 1e29  # finite logits (vocab padding is -1e30)
    np.testing.assert_allclose(b[m], a[m], **kw)


@pytest.mark.parametrize("window,S,chunk", [
    (0, 12, 5),    # non-windowed, uneven final chunk
    (0, 12, 12),   # single chunk == whole prompt
    (8, 12, 5),    # ring wrap: S >= CL, prefill crosses the ring boundary
    (8, 20, 8),    # chunk == ring length, multiple wraps
    (8, 6, 4),     # windowed but prompt shorter than the ring
])
def test_chunked_prefill_matches_whole_prefill(window, S, chunk):
    cfg, sp = _tiny(window=window)
    s_max = 48
    rng = np.random.default_rng(S * 7 + chunk)
    toks = jnp.asarray(rng.integers(1, cfg.vocab_size, size=(2, S)), jnp.int32)
    batch = {"tokens": toks}
    cache_w, logits_w = prefill(sp, cfg, batch, s_max=s_max)
    cache_c, logits_c = prefill_chunked(sp, cfg, batch, s_max=s_max,
                                        chunk=chunk)
    # identical ring layout: the canonical invariant means slot occupancy is
    # a pure function of the positions written, not of the chunking
    np.testing.assert_array_equal(np.asarray(cache_c["pos"]),
                                  np.asarray(cache_w["pos"]))
    _close(logits_w, logits_c, **TOL)
    np.testing.assert_allclose(np.asarray(cache_c["k"], np.float32),
                               np.asarray(cache_w["k"], np.float32), **TOL)
    np.testing.assert_allclose(np.asarray(cache_c["v"], np.float32),
                               np.asarray(cache_w["v"], np.float32), **TOL)
    # both caches decode on identically from here
    for t in range(S, S + 3):
        tok = jnp.asarray(rng.integers(1, cfg.vocab_size, size=(2,)), jnp.int32)
        lw, cache_w = decode_step(sp, cfg, cache_w, tok, jnp.asarray(t, jnp.int32))
        lc, cache_c = decode_step(sp, cfg, cache_c, tok, jnp.asarray(t, jnp.int32))
        _close(lw, lc, **TOL)


def test_chunk_larger_than_ring_raises():
    cfg, sp = _tiny(window=8)
    cache, _ = prefill(sp, cfg, {"tokens": jnp.ones((1, 4), jnp.int32)},
                       s_max=32)
    toks = jnp.ones((1, 12), jnp.int32)
    pos = jnp.arange(12, dtype=jnp.int32)[None]
    with pytest.raises(ValueError, match="exceeds ring length"):
        prefill_chunk(sp, cfg, cache, toks, pos)


def test_chunked_prefill_unsupported_arch_raises():
    cfg = get_smoke_config("zamba2-2.7b").with_(window=8, remat=False)
    sp = quantize_for_serving(init_params(cfg, jax.random.PRNGKey(0)), cfg)
    assert not supports_chunked_prefill(sp, cfg)
    with pytest.raises(NotImplementedError):
        prefill_chunked(sp, cfg, {"tokens": jnp.ones((1, 8), jnp.int32)},
                        s_max=16, chunk=4)


def test_prefill_chunks_of():
    assert prefill_chunks_of(1, 4) == [(0, 1)]
    assert prefill_chunks_of(8, 4) == [(0, 4), (4, 4)]
    assert prefill_chunks_of(9, 4) == [(0, 4), (4, 4), (8, 1)]
    with pytest.raises(ValueError):
        prefill_chunks_of(0, 4)


def test_padded_tail_never_writes_kv():
    """The padded tail of a final chunk must not write KV, positions, or be
    attendable: pad positions are -1 → their ring slot maps past the cache
    end and the scatter drops."""
    cfg, sp = _tiny(window=0)
    S, chunk = 5, 4  # final chunk has 3 padded tail tokens
    toks = jnp.asarray(np.arange(2, 2 + S)[None], jnp.int32)
    cache, _ = prefill_chunked(sp, cfg, {"tokens": toks}, s_max=16,
                               chunk=chunk)
    pos = np.asarray(cache["pos"][0, 0])
    np.testing.assert_array_equal(pos[:S], np.arange(S))
    np.testing.assert_array_equal(pos[S:], -1)
    assert (np.asarray(cache["k"][0, 0, S:], np.float32) == 0).all()
