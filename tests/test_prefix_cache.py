"""Prefix-cache subsystem: chained block-hash properties (pure + hypothesis),
store LRU/byte-budget units, extract/splice ring roundtrip, the cold- and
warm-store differential oracles against the no-cache baseline (greedy
streams must be BYTE-IDENTICAL — splice reuses the exact KV the baseline
recomputes), trace honesty (cache hits mint no new jit traces), the
windowed reuse-depth cap, cache-affinity admission ordering with its FIFO
starvation bound, queue-wait accounting, and the slab sharding specs."""

import random

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from repro.configs.registry import get_smoke_config
from repro.models.decode import (extract_kv_blocks, init_cache, prefill,
                                 quantize_for_serving, splice_kv_blocks)
from repro.models.model import init_params
from repro.serving.engine import DecodeEngine, Request
from repro.serving.prefix_cache import (PrefixBlockStore, PrefixStoreStats,
                                        chain_block_hashes)
from repro.serving.scheduler import ContinuousScheduler


def _tiny_engine(key, B=2, max_len=48, window=0, prefill_chunk=4,
                 prefix_cache=False, prefix_cache_mb=64.0):
    cfg = get_smoke_config("bitnet-b1.58-2b").with_(
        n_layers=2, d_model=64, n_heads=2, n_kv_heads=2, head_dim=32,
        d_ff=128, vocab_size=256, loss_chunk=32, window=window)
    sp = quantize_for_serving(init_params(cfg, key), cfg)
    return DecodeEngine(sp, cfg, batch_size=B, max_len=max_len,
                        matmul_policy="fixed:ref",
                        prefill_chunk=prefill_chunk,
                        prefix_cache=prefix_cache,
                        prefix_cache_mb=prefix_cache_mb)


# ---------------------------------------------------------------------------
# chained hashes: pure function of token ids
# ---------------------------------------------------------------------------


def test_chain_hashes_basic_properties():
    toks = list(range(10))
    hs = chain_block_hashes(toks, 4)
    assert len(hs) == 2  # trailing partial block (2 tokens) is never hashed
    # n_blocks truncation returns a prefix of the same chain
    assert chain_block_hashes(toks, 4, n_blocks=1) == hs[:1]
    # chaining: same block content at a different depth hashes differently
    assert chain_block_hashes(toks[4:8] + toks[4:8], 4)[0] != hs[1]
    # namespace and block size both change the seed → disjoint key spaces
    assert chain_block_hashes(toks, 4, namespace=b"other") != hs
    assert chain_block_hashes(toks, 5)[0] not in hs


@settings(max_examples=50, deadline=None)
@given(st.integers(0, 10**9), st.integers(1, 8))
def test_chain_hash_equal_iff_token_prefix_equal(seed, block):
    """hash[i] is a content address for the whole (i+1)*C-token prefix —
    invariant to everything except those token ids.  This is the property
    that makes published blocks independent of batch composition and
    admission order (collisions: blake2b-128, negligible).  ``b`` is built
    as a fork of ``a`` (usually sharing a long prefix) so the equal branch
    is actually exercised, not just the differ-at-block-0 case."""
    rng = random.Random(seed)
    a = [rng.randint(0, 255) for _ in range(rng.randint(0, 40))]
    b = list(a)
    if a and rng.random() < 0.7:  # mutate one position: guaranteed fork
        i = rng.randrange(len(a))
        b[i] = (b[i] + rng.randint(1, 255)) % 256
    b = b[:rng.randint(0, 40)]
    b += [rng.randint(0, 255) for _ in range(rng.randint(0, block + 1))]
    ha, hb = chain_block_hashes(a, block), chain_block_hashes(b, block)
    for i in range(min(len(ha), len(hb))):
        n = (i + 1) * block
        assert (ha[i] == hb[i]) == (a[:n] == b[:n])


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 10**9), st.integers(1, 6), st.integers(0, 255))
def test_chain_hash_deterministic_and_suffix_blind(seed, block, extra):
    """Appending tokens past the hashed blocks never changes their hashes
    (an admission can publish block i before the prompt tail is prefilled),
    and recomputation is bit-stable."""
    rng = random.Random(seed)
    toks = [rng.randint(0, 255) for _ in range(rng.randint(1, 32))]
    hs = chain_block_hashes(toks, block)
    assert chain_block_hashes(toks, block) == hs
    n = (len(toks) // block) * block
    assert chain_block_hashes(toks[:n] + [extra], block)[:len(hs)] == hs


# ---------------------------------------------------------------------------
# store: LRU under a byte budget, peek-vs-count lookups
# ---------------------------------------------------------------------------


def _slab(fill, n=64):
    x = np.full(n, fill, np.float32)  # 256 bytes
    return {"k": x, "v": x}


def test_store_lru_eviction_under_byte_budget():
    store = PrefixBlockStore(4, max_bytes=3 * 512)
    h = [bytes([i]) * 16 for i in range(4)]
    assert all(store.put(h[i], _slab(i)) for i in range(3))
    assert store.nbytes == 3 * 512 and len(store) == 3
    store.get(h[0])  # bump: h[1] is now LRU
    assert store.put(h[3], _slab(3))
    assert h[1] not in store and h[0] in store and len(store) == 3
    assert store.stats.evicted_blocks == 1
    # duplicate put: refused, no double-count, but bumps recency
    assert not store.put(h[0], _slab(0))
    assert store.nbytes == 3 * 512
    # a slab larger than the whole budget is refused outright
    assert not store.put(bytes(16), _slab(9, n=3 * 512))
    assert store.stats.published_blocks == 4


def test_store_match_is_prefix_only_and_peek_is_silent():
    store = PrefixBlockStore(4, max_bytes=1 << 20)
    h = [bytes([i]) * 16 for i in range(3)]
    store.put(h[0], _slab(0))
    store.put(h[1], _slab(1))
    assert store.match(h, peek=True) == 2
    assert store.stats.lookups == 0  # peeks never count
    assert store.match(h) == 2
    assert (store.stats.hit_blocks, store.stats.miss_blocks) == (2, 1)
    # chained lookup stops at the first absence: an interior "hit" is dead
    store.clear()
    store.put(h[1], _slab(1))
    assert store.match(h) == 0
    assert store.stats.hit_rate == pytest.approx(2 / 6)


def test_queue_wait_summary_empty_is_zeros():
    from repro.serving.scheduler import SchedulerStats

    assert SchedulerStats().queue_wait_summary() == \
        {"mean": 0.0, "p50": 0.0, "max": 0.0}


# ---------------------------------------------------------------------------
# extract/splice: the ring-invariant roundtrip the reuse path rides on
# ---------------------------------------------------------------------------


def test_extract_splice_roundtrip_dense(key):
    """A block extracted from one admission cache and spliced into a fresh
    one lands bit-identical at the same ring slots, with positions stamped;
    all other slots stay untouched."""
    eng = _tiny_engine(key, B=1)
    sp, cfg = eng.params, eng.cfg
    toks = jnp.asarray([[3, 4, 5, 6, 7, 8, 9, 10]], jnp.int32)
    cache, _ = prefill(sp, cfg, {"tokens": toks}, s_max=eng.max_len)
    blk = extract_kv_blocks(cfg, cache, 4, 4)
    assert blk["k"].shape[1] == 4
    fresh = init_cache(cfg, 1, eng.max_len)
    out = splice_kv_blocks(cfg, fresh, blk, 4)
    sl = np.arange(4, 8)  # dense: slot == position
    for leaf in ("k", "v"):
        np.testing.assert_array_equal(
            np.asarray(out[leaf][:, 0, sl], np.float32),
            np.asarray(cache[leaf][:, 0, sl], np.float32))
        np.testing.assert_array_equal(  # untouched slots: still fresh
            np.asarray(out[leaf][:, 0, :4], np.float32),
            np.asarray(fresh[leaf][:, 0, :4], np.float32))
    np.testing.assert_array_equal(np.asarray(out["pos"][0, 0, sl]), sl)


# ---------------------------------------------------------------------------
# differential oracles: cache on vs cache off must be byte-identical
# ---------------------------------------------------------------------------

_SHARED = [3, 4, 5, 6, 7, 8, 9, 10, 11, 12]


def _oracle_specs():
    # heavy shared-prefix overlap + one cold request + one short prompt
    return [(_SHARED + [20], 4), (_SHARED + [21, 22], 4),
            (_SHARED[:8] + [23], 3), ([9, 8, 7, 6, 5, 4, 3, 2, 1], 4),
            ([2, 2], 3)]


@pytest.mark.parametrize("window", [0, 8])
def test_cold_store_streams_match_baseline(key, window):
    """Differential oracle, cold store: an engine that publishes AND reuses
    blocks mid-serve (later requests hit blocks earlier ones just produced)
    must emit greedy streams byte-identical to the no-cache engine — splice
    returns the exact KV the baseline recomputes, same jitted traces, so
    there is no tolerance here, not even argmax ties."""
    base = _tiny_engine(key, B=2, window=window, prefill_chunk=4)
    cached = _tiny_engine(key, B=2, window=window, prefill_chunk=4,
                          prefix_cache=True)
    specs = _oracle_specs()
    want = [Request(prompt=p, max_new_tokens=n) for p, n in specs]
    base.serve(want, max_steps=400)
    got = [Request(prompt=p, max_new_tokens=n) for p, n in specs]
    cached.serve(got, max_steps=400)
    for w, g in zip(want, got):
        assert g.done and g.out == w.out, (g.out, w.out)
    st = cached.prefix_store.stats
    assert st.published_blocks > 0
    assert st.reused_tokens > 0, "shared prefixes never hit mid-serve"


def test_warm_store_reuse_exact_and_traces_honest(key):
    """Warm store: a second pass over the same shared-prefix workload hits
    hard (skipping most prefill chunks), streams stay byte-identical, and —
    trace honesty — reuse mints NO new jit traces: one prefill_chunk trace,
    one splice trace, one extract trace, however the hit/miss mix varies."""
    eng = _tiny_engine(key, B=2, prefill_chunk=4, prefix_cache=True)
    specs = _oracle_specs()

    def pass_once():
        reqs = [Request(prompt=p, max_new_tokens=n) for p, n in specs]
        sched = ContinuousScheduler(eng)
        for r in reqs:
            sched.submit(r)
        sched.run(max_steps=400)
        return reqs, sched.stats

    first, st1 = pass_once()
    hits_before = eng.prefix_store.stats.hit_blocks
    second, st2 = pass_once()
    for a, b in zip(first, second):
        assert b.out == a.out, "warm-store stream diverged from cold pass"
    assert eng.prefix_store.stats.hit_blocks > hits_before
    # warm pass prefilled strictly fewer chunks than the cold pass
    assert st2.prefill_chunks < st1.prefill_chunks, (st1, st2)
    tc = eng.trace_counts
    assert tc["prefill_chunk"] == 1, tc
    assert tc["splice_block"] == 1, tc
    assert tc["extract_block"] == 1, tc
    assert tc["admit_commit"] == 1, tc
    assert tc["prefill"] == 0, tc  # whole-prompt fallback never taken


def test_published_hashes_invariant_to_batch_and_order(key):
    """The store's key set after draining a workload depends only on the
    prompts' token ids — not on batch size, submission order, or who hit
    whose blocks (the batch/order-invariance property, end to end)."""
    specs = _oracle_specs()

    def published(B, order):
        eng = _tiny_engine(key, B=B, prefill_chunk=4, prefix_cache=True)
        reqs = [Request(prompt=specs[i][0], max_new_tokens=specs[i][1])
                for i in order]
        eng.serve(reqs, max_steps=400)
        return set(eng.prefix_store._blocks)

    base = published(1, [0, 1, 2, 3, 4])
    assert published(2, [4, 3, 2, 1, 0]) == base
    assert published(3, [2, 0, 4, 1, 3]) == base


def test_windowed_reuse_depth_capped_at_ring(key):
    """Windowed configs: blocks past the first CL positions are overwritten
    in the ring before the prompt's tail attends them — they must be neither
    published nor consulted.  window=8, chunk=4 → at most 2 blocks per
    prompt, whatever the prompt length."""
    eng = _tiny_engine(key, B=1, window=8, prefill_chunk=4, max_len=48,
                       prefix_cache=True)
    assert eng._CL == 8
    prompt = list(range(2, 18))  # 16 tokens = 4 full blocks uncapped
    eng.serve([Request(prompt=prompt, max_new_tokens=2)], max_steps=200)
    assert len(eng.prefix_store) <= 2
    again = Request(prompt=prompt, max_new_tokens=2)
    assert eng.prefix_match_len(again) == 8  # 2 blocks, not 12 tokens
    # and the capped reuse still replays byte-identically
    base = _tiny_engine(key, B=1, window=8, prefill_chunk=4, max_len=48)
    want = Request(prompt=prompt, max_new_tokens=2)
    base.serve([want], max_steps=200)
    eng.serve([again], max_steps=200)
    assert again.out == want.out


def test_engine_rejects_mismatched_store(key):
    eng = _tiny_engine(key, B=1, prefill_chunk=4, prefix_cache=True)
    cfg, sp = eng.cfg, eng.params
    with pytest.raises(ValueError, match="block size"):
        DecodeEngine(sp, cfg, batch_size=1, max_len=48,
                     matmul_policy="fixed:ref", prefill_chunk=4,
                     prefix_cache=PrefixBlockStore(8))
    with pytest.raises(ValueError, match="namespace"):
        DecodeEngine(sp, cfg, batch_size=1, max_len=48,
                     matmul_policy="fixed:ref", prefill_chunk=4,
                     prefix_cache=PrefixBlockStore(4, namespace=b"other"))
    # a store handed from one engine to a geometry-identical sibling is fine
    # (the cross-engine sharing the namespace exists to permit) — and an
    # EMPTY store is falsy (len 0), so this also pins the identity check
    sib = DecodeEngine(sp, cfg, batch_size=1, max_len=48,
                       matmul_policy="fixed:ref", prefill_chunk=4,
                       prefix_cache=eng.prefix_store)
    assert sib.prefix_store is eng.prefix_store
    assert len(eng.prefix_store) == 0  # falsy, yet wired — identity check


# ---------------------------------------------------------------------------
# cache-affinity admission: scheduler-side, scripted fake backend
# ---------------------------------------------------------------------------


class AffinityFake:
    """Atomic-admission ScheduleBackend with a scripted prefix_match_len
    (``req._match``) — isolates the scheduler's affinity/fairness logic
    from any model or store."""

    def __init__(self, batch_size=1):
        self.batch_size = batch_size
        self.admitted: list[Request] = []

    def sched_start(self):
        return [None] * self.batch_size

    def prefix_match_len(self, request):
        return getattr(request, "_match", 0)

    def sched_admit(self, state, slot, request):
        self.admitted.append(request)
        state = list(state)
        state[slot] = [request, 0]
        return state

    def sched_step(self, state):
        B = self.batch_size
        tokens = np.full(B, -1, np.int64)
        alive = np.zeros(B, bool)
        state = list(state)
        for b, s in enumerate(state):
            if s is None:
                continue
            req, t = s
            tokens[b] = t
            s[1] = t + 1
            if s[1] >= req.max_new_tokens:
                state[b] = None
            else:
                alive[b] = True
        return state, tokens, alive


def _req(match=0, new=1):
    r = Request(prompt=[1], max_new_tokens=new)
    r._match = match
    return r


def test_affinity_admits_deepest_match_first():
    backend = AffinityFake()
    reqs = [_req(0), _req(8), _req(16)]
    sched = ContinuousScheduler(backend)
    for r in reqs:
        sched.submit(r)
    sched.run(max_steps=100)
    assert backend.admitted == [reqs[2], reqs[1], reqs[0]]
    assert sched.stats.affinity_reorders == 2
    assert len(sched.stats.queue_wait_s) == 3
    assert all(w >= 0 for w in sched.stats.queue_wait_s)


def test_affinity_ties_degrade_to_fifo():
    backend = AffinityFake()
    reqs = [_req(4) for _ in range(4)]  # equal depth everywhere
    sched = ContinuousScheduler(backend)
    for r in reqs:
        sched.submit(r)
    sched.run(max_steps=100)
    assert backend.admitted == reqs
    assert sched.stats.affinity_reorders == 0


def test_affinity_starvation_bound_forces_jumped_head():
    """A cold head can be jumped at most ``max_affinity_skips`` times; then
    it is admitted unconditionally even with hotter requests queued."""
    backend = AffinityFake()
    cold = _req(0)
    hot = [_req(8) for _ in range(5)]
    sched = ContinuousScheduler(backend, max_affinity_skips=2)
    sched.submit(cold)
    for r in hot:
        sched.submit(r)
    sched.run(max_steps=100)
    assert backend.admitted[:3] == [hot[0], hot[1], cold]
    assert {id(r) for r in backend.admitted} == {id(r) for r in (cold, *hot)}


def test_affinity_window_bounds_lookahead():
    """Only the first ``affinity_window`` queued requests are scored — a
    deep match beyond the window cannot jump."""
    backend = AffinityFake()
    reqs = [_req(0), _req(0), _req(16)]
    sched = ContinuousScheduler(backend, affinity_window=2)
    for r in reqs:
        sched.submit(r)
    sched.run(max_steps=100)
    assert backend.admitted[0] is reqs[0]  # window [r0, r1]: tie → oldest


def test_cache_affinity_off_is_pure_fifo():
    backend = AffinityFake()
    reqs = [_req(0), _req(16)]
    sched = ContinuousScheduler(backend, cache_affinity=False)
    for r in reqs:
        sched.submit(r)
    sched.run(max_steps=100)
    assert backend.admitted == reqs
    assert sched.stats.affinity_reorders == 0


def test_queue_wait_excludes_zero_budget_requests():
    backend = AffinityFake()
    reqs = [_req(new=1), _req(new=0), _req(new=1)]
    sched = ContinuousScheduler(backend)
    for r in reqs:
        sched.submit(r)
    sched.run(max_steps=100)
    assert len(sched.stats.queue_wait_s) == 2  # zero-budget never admitted
    s = sched.stats.queue_wait_summary()
    assert 0 <= s["mean"] <= s["max"]


# ---------------------------------------------------------------------------
# slab sharding specs
# ---------------------------------------------------------------------------


def test_block_slab_specs_match_cache_head_rule():
    from jax.sharding import PartitionSpec as P

    from repro.launch.mesh import make_serving_mesh
    from repro.parallel.sharding import block_slab_specs

    mesh = make_serving_mesh("1x1")
    slab = {"k": np.zeros((2, 4, 2, 8), np.float32),
            "v": np.zeros((2, 4, 2, 8), np.float32)}
    specs = block_slab_specs(slab, mesh, kv_heads=2)
    assert specs["k"] == P(None, None, "model", None)  # kv-heads on model
    assert specs["v"] == P(None, None, "model", None)
    legacy = block_slab_specs(slab, mesh)
    assert legacy["k"] == P(None, None, None, "model")  # head_dim fallback
