"""BitNet b1.58 quantization invariants (hypothesis property tests)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import quantization as q


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(2, 64), st.integers(2, 64))
def test_ternarize_invariants(seed, a, b):
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.normal(size=(a, b)) * rng.uniform(0.01, 10), jnp.float32)
    w_t, scale = q.ternarize(w)
    assert set(np.unique(np.asarray(w_t))) <= {-1, 0, 1}
    assert float(scale) > 0
    # absmean reconstruction error bounded by scale/2 + tail clipping
    err = np.abs(np.asarray(w) - np.asarray(w_t, np.float32) * float(scale))
    inside = np.abs(np.asarray(w)) <= 1.5 * float(scale)
    assert (err[inside] <= float(scale) / 2 + 1e-5).all()


def test_ternarize_per_channel_axis():
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.normal(size=(4, 8, 8)), jnp.float32)
    w_t, scale = q.ternarize(w, axis=(-2, -1))
    assert scale.shape == (4, 1, 1)
    # each slice matches its own per-tensor quantization
    for i in range(4):
        wt_i, s_i = q.ternarize(w[i])
        np.testing.assert_array_equal(np.asarray(w_t[i]), np.asarray(wt_i))
        assert float(scale[i, 0, 0]) == pytest.approx(float(s_i))


def test_ste_gradient_is_identity():
    w = jnp.asarray(np.random.default_rng(0).normal(size=(16, 16)), jnp.float32)
    g = jax.grad(lambda w: jnp.sum(q.ste_ternarize(w) * 3.0))(w)
    np.testing.assert_allclose(np.asarray(g), 3.0)
    g2 = jax.grad(lambda w: jnp.sum(q.fake_quant_ternary(w) * 2.0))(w)
    np.testing.assert_allclose(np.asarray(g2), 2.0)


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_act_quant_int8(seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(3, 32)) * 5, jnp.float32)
    x_q, scale = q.quantize_activations_int8(x)
    assert np.asarray(x_q).dtype == np.int8
    np.testing.assert_allclose(np.asarray(x_q, np.float32) * np.asarray(scale),
                               np.asarray(x), atol=np.max(np.abs(x)) / 127 + 1e-6)


def test_fake_quant_matmul_grads_flow():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(4, 16)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(16, 8)), jnp.float32)
    loss = lambda w, x: jnp.sum(q.fake_quant_matmul(x, w) ** 2)
    gw, gx = jax.grad(loss, argnums=(0, 1))(w, x)
    assert np.isfinite(np.asarray(gw)).all() and np.abs(np.asarray(gw)).sum() > 0
    assert np.isfinite(np.asarray(gx)).all()


def test_ternary_sparsity_nontrivial():
    """BitNet absmean quantization must leave a meaningful zero fraction."""
    w = jnp.asarray(np.random.default_rng(0).normal(size=(256, 256)), jnp.float32)
    stats = q.ternary_weight_stats(q.ternarize(w)[0])
    assert 0.15 < float(stats["zero"]) < 0.55
