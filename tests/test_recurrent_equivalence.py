"""Chunked-parallel train paths vs sequential decode recurrences.

These are the critical numerics tests for the SSM/xLSTM families: the
chunked SSD / chunked mLSTM used at training time must agree with the O(1)
single-step recurrences used at decode time.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.config import ModelConfig
from repro.models.ssm import _ssd_chunked
from repro.models.xlstm import _mlstm_chunked


def _ssd_sequential(u, B_in, C_in, log_a):
    Bb, S, H, P = u.shape
    N = B_in.shape[-1]
    h = np.zeros((Bb, H, N, P))
    ys = []
    for t in range(S):
        a = np.exp(np.asarray(log_a[:, t], np.float64))  # [B, H]
        h = h * a[:, :, None, None] + np.einsum(
            "bn,bhp->bhnp", np.asarray(B_in[:, t], np.float64),
            np.asarray(u[:, t], np.float64))
        ys.append(np.einsum("bn,bhnp->bhp", np.asarray(C_in[:, t], np.float64), h))
    return np.stack(ys, axis=1), h


@pytest.mark.parametrize("chunk", [4, 8, 64])
def test_ssd_chunked_equals_sequential(chunk):
    rng = np.random.default_rng(0)
    Bb, S, H, P, N = 2, 24, 3, 4, 5
    u = jnp.asarray(rng.normal(size=(Bb, S, H, P)), jnp.float32)
    Bi = jnp.asarray(rng.normal(size=(Bb, S, N)), jnp.float32)
    Ci = jnp.asarray(rng.normal(size=(Bb, S, N)), jnp.float32)
    log_a = jnp.asarray(-np.abs(rng.normal(size=(Bb, S, H))), jnp.float32)
    y, h = _ssd_chunked(u, Bi, Ci, log_a, chunk)
    y_ref, h_ref = _ssd_sequential(u, Bi, Ci, log_a)
    np.testing.assert_allclose(np.asarray(y), y_ref, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(h), h_ref, rtol=2e-4, atol=2e-4)


def test_ssd_respects_initial_state():
    rng = np.random.default_rng(1)
    Bb, S, H, P, N = 1, 12, 2, 3, 4
    u = jnp.asarray(rng.normal(size=(Bb, S, H, P)), jnp.float32)
    Bi = jnp.asarray(rng.normal(size=(Bb, S, N)), jnp.float32)
    Ci = jnp.asarray(rng.normal(size=(Bb, S, N)), jnp.float32)
    log_a = jnp.asarray(-np.abs(rng.normal(size=(Bb, S, H))), jnp.float32)
    # split the sequence: running two halves with carried state == full run
    y_full, h_full = _ssd_chunked(u, Bi, Ci, log_a, 4)
    y1, h1 = _ssd_chunked(u[:, :6], Bi[:, :6], Ci[:, :6], log_a[:, :6], 4)
    y2, h2 = _ssd_chunked(u[:, 6:], Bi[:, 6:], Ci[:, 6:], log_a[:, 6:], 4, h0=h1)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_full), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(h2), np.asarray(h_full), rtol=1e-4, atol=1e-4)


def _mlstm_sequential(q, k, v, log_f, log_i):
    Bb, S, H, dk = np.asarray(q).shape
    dv = np.asarray(v).shape[-1]
    C = np.zeros((Bb, H, dk, dv))
    n = np.zeros((Bb, H, dk))
    m = np.full((Bb, H), -1e30)
    ys = []
    q, k, v = (np.asarray(t, np.float64) for t in (q, k, v))
    log_f, log_i = np.asarray(log_f, np.float64), np.asarray(log_i, np.float64)
    for t in range(S):
        m_new = np.maximum(log_f[:, t] + m, log_i[:, t])
        i_s = np.exp(log_i[:, t] - m_new)
        f_s = np.exp(log_f[:, t] + m - m_new)
        C = f_s[:, :, None, None] * C + i_s[:, :, None, None] * \
            np.einsum("bhd,bhv->bhdv", k[:, t], v[:, t])
        n = f_s[:, :, None] * n + i_s[:, :, None] * k[:, t]
        num = np.einsum("bhd,bhdv->bhv", q[:, t], C)
        den = np.maximum(np.abs(np.einsum("bhd,bhd->bh", q[:, t], n)),
                         np.exp(-m_new))
        ys.append(num / den[..., None])
        m = m_new
    return np.stack(ys, axis=1), (C, n, m)


@pytest.mark.parametrize("chunk", [4, 8, 32])
def test_mlstm_chunked_equals_sequential(chunk):
    rng = np.random.default_rng(2)
    Bb, S, H, dk = 2, 16, 2, 4
    q = jnp.asarray(rng.normal(size=(Bb, S, H, dk)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(Bb, S, H, dk)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(Bb, S, H, dk)), jnp.float32)
    log_f = jnp.asarray(np.log(rng.uniform(0.7, 0.999, size=(Bb, S, H))), jnp.float32)
    log_i = jnp.asarray(rng.normal(size=(Bb, S, H)), jnp.float32)
    y, (C, n, m) = _mlstm_chunked(q, k, v, log_f, log_i, chunk)
    y_ref, (C_ref, n_ref, m_ref) = _mlstm_sequential(q, k, v, log_f, log_i)
    np.testing.assert_allclose(np.asarray(y), y_ref, rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(np.asarray(C), C_ref, rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(np.asarray(m), m_ref, rtol=1e-5, atol=1e-5)


def test_mamba2_block_decode_matches_prefill():
    """Full mixer: running S steps of decode == one chunked prefill pass."""
    from repro.models.ssm import init_mamba2, mamba2_block

    cfg = ModelConfig(name="t", family="hybrid", n_layers=1, d_model=32,
                      n_heads=4, n_kv_heads=4, d_ff=64, vocab_size=64,
                      block_pattern="zamba2", ssm_state=8, ssm_head_dim=8,
                      quant="fp", remat=False)
    p = init_mamba2(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(size=(2, 10, 32)) * 0.5, jnp.float32)
    y_full, (h_full, conv_full) = mamba2_block(p, x, cfg, chunk=4)

    # sequential decode over the same tokens
    d_in = cfg.ssm_expand * cfg.d_model
    state = jnp.zeros((2, d_in // cfg.ssm_head_dim, cfg.ssm_state, cfg.ssm_head_dim))
    conv = jnp.zeros((2, cfg.ssm_conv - 1, d_in + 2 * cfg.ssm_state), jnp.float32)
    ys = []
    for t in range(10):
        y_t, (state, conv) = mamba2_block(p, x[:, t:t + 1], cfg,
                                          state=state, conv_state=conv)
        ys.append(y_t)
    y_seq = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_seq, np.float32),
                               np.asarray(y_full, np.float32), rtol=0.15, atol=0.05)
    np.testing.assert_allclose(np.asarray(state), np.asarray(h_full),
                               rtol=0.1, atol=0.05)
