"""Continuous-batching scheduler: property-tested invariants (fake backend),
the continuous-vs-single-request differential oracle (real engine), the
generational run() overflow guard, and the tier-2 soak test (`slow` marker,
run by the scheduled CI job — tier-1 skips it via pytest.ini addopts)."""

import random

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs.registry import get_smoke_config
from repro.models.decode import decode_step, prefill, quantize_for_serving
from repro.models.model import init_params
from repro.serving.engine import DecodeEngine, Request
from repro.serving.scheduler import ContinuousScheduler


# ---------------------------------------------------------------------------
# fake backend: scheduler invariants without a model
# ---------------------------------------------------------------------------


class FakeBackend:
    """Deterministic ScheduleBackend: each request carries a scripted token
    stream (``req._script``); slot ``b`` replays its request's script one
    token per step.  Asserts the scheduler never refills a live slot."""

    def __init__(self, batch_size: int):
        self.batch_size = batch_size
        self.admitted: list[Request] = []

    def sched_start(self):
        return [None] * self.batch_size  # slot → {"req", "emitted"} | None

    def sched_admit(self, state, slot, request):
        assert state[slot] is None, f"refill clobbered live slot {slot}"
        self.admitted.append(request)
        state = list(state)
        state[slot] = {"req": request, "emitted": 0}
        return state

    def sched_step(self, state):
        B = self.batch_size
        tokens = np.full(B, -1, np.int64)
        alive = np.zeros(B, bool)
        state = list(state)
        for b, s in enumerate(state):
            if s is None:
                continue
            req, t = s["req"], s["emitted"]
            tok = req._script[t]
            s["emitted"] = t + 1
            tokens[b] = tok
            stopped = req.stop_token is not None and tok == req.stop_token
            if stopped or s["emitted"] >= req.max_new_tokens:
                state[b] = None  # backend-side: slot is dead now
            else:
                alive[b] = True
        return state, tokens, alive


def _make_workload(rng: random.Random, n_reqs: int):
    """Requests with unique scripted streams; some stop early, some have a
    zero budget (must complete without ever occupying a slot)."""
    reqs, want = [], []
    for rid in range(n_reqs):
        budget = rng.randint(0, 9) if rng.random() < 0.15 else rng.randint(1, 9)
        script = [rid * 1000 + t for t in range(max(budget, 1))]
        stop = None
        expected = script[:budget]
        if budget and rng.random() < 0.4:  # stop token somewhere mid-stream
            k = rng.randint(0, budget - 1)
            stop = script[k]
            expected = script[:k + 1]
        r = Request(prompt=[1], max_new_tokens=budget, stop_token=stop)
        r._script = script
        reqs.append(r)
        want.append(expected)
    return reqs, want


@settings(max_examples=25, deadline=None)
@given(st.integers(1, 4), st.integers(0, 14), st.integers(0, 10_000))
def test_scheduler_invariants(batch, n_reqs, seed):
    """No token loss or duplication, FIFO admission, every request completes,
    live slots are never clobbered (asserted inside FakeBackend)."""
    rng = random.Random(seed)
    backend = FakeBackend(batch)
    reqs, want = _make_workload(rng, n_reqs)
    streamed = {id(r): [] for r in reqs}
    sched = ContinuousScheduler(
        backend, on_token=lambda r, t: streamed[id(r)].append(t))
    for r in reqs:
        sched.submit(r)
    done = sched.run(max_steps=10_000)

    assert len(done) == len(reqs)
    for r, w in zip(reqs, want):
        assert r.done
        assert r.out == w, "token stream lost/duplicated/reordered"
        assert streamed[id(r)] == w  # streaming callback saw the same tokens
    # FIFO: admission order == submission order, minus zero-budget requests
    # (they complete immediately without taking a slot)
    assert backend.admitted == [r for r in reqs if r.max_new_tokens > 0]
    assert sched.stats.emitted_tokens == sum(len(w) for w in want)
    assert sched.stats.completed == len(reqs)


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 3), st.integers(1, 10), st.integers(0, 10_000))
def test_scheduler_mid_run_submission(batch, n_extra, seed):
    """submit() between steps (random arrivals) preserves FIFO and loses
    nothing — the admission-queue half of continuous batching."""
    rng = random.Random(seed)
    backend = FakeBackend(batch)
    initial, want_i = _make_workload(rng, 3)
    extra, want_e = _make_workload(rng, n_extra)
    sched = ContinuousScheduler(backend)
    for r in initial:
        sched.submit(r)
    arrivals = list(extra)
    steps = 0
    while sched.pending or arrivals:
        if arrivals and rng.random() < 0.5:
            sched.submit(arrivals.pop(0))
        sched.step()
        steps += 1
        assert steps < 10_000
    for r, w in zip(initial + extra, want_i + want_e):
        assert r.done and r.out == w
    admitted_nonzero = [r for r in initial + extra if r.max_new_tokens > 0]
    # extras arrive one at a time in order, so FIFO still == submission order
    assert backend.admitted == admitted_nonzero


def test_submit_completed_request_rejected():
    sched = ContinuousScheduler(FakeBackend(1))
    r = Request(prompt=[1], max_new_tokens=1)
    r.done = True
    with pytest.raises(ValueError):
        sched.submit(r)


# ---------------------------------------------------------------------------
# real engine: overflow guard, queued serving, differential oracle
# ---------------------------------------------------------------------------


def _tiny_engine(key, B=2, max_len=48):
    cfg = get_smoke_config("bitnet-b1.58-2b").with_(
        n_layers=2, d_model=64, n_heads=2, n_kv_heads=2, head_dim=32,
        d_ff=128, vocab_size=256, loss_chunk=32)
    sp = quantize_for_serving(init_params(cfg, key), cfg)
    return DecodeEngine(sp, cfg, batch_size=B, max_len=max_len,
                        matmul_policy="fixed:ref")


def test_run_overflow_raises_value_error(key):
    """run() must raise a real ValueError (not a bare assert, which vanishes
    under python -O) when handed more requests than slots."""
    eng = _tiny_engine(key, B=2)
    reqs = [Request(prompt=[3], max_new_tokens=1) for _ in range(3)]
    with pytest.raises(ValueError, match="batch_size"):
        eng.run(reqs)


def test_admit_rejects_overlong_request(key):
    eng = _tiny_engine(key, B=2, max_len=8)
    with pytest.raises(ValueError, match="max_len"):
        eng.serve([Request(prompt=[3, 4, 5, 6], max_new_tokens=8)])
    # generational run() enforces the same bound (out-of-range positions
    # would silently scatter-drop their KV writes otherwise)
    with pytest.raises(ValueError, match="max_len"):
        eng.run([Request(prompt=[3] * 6, max_new_tokens=8)])


def _single_request_oracle(eng, prompt, max_new, stop=None,
                           return_logits=False):
    """The seed generational semantics: one request alone through prefill +
    scalar-index decode_step, greedy."""
    sp, cfg = eng.params, eng.cfg
    toks = jnp.asarray(np.asarray(prompt, np.int32)[None])
    cache, logits = prefill(sp, cfg, {"tokens": toks}, s_max=eng.max_len)
    out, logs, pos = [], [], len(prompt) - 1
    for _ in range(max_new):
        logs.append(np.asarray(logits[0], np.float32))
        tok = int(jnp.argmax(logits[0]))
        out.append(tok)
        if stop is not None and tok == stop:
            break
        pos += 1
        logits, cache = decode_step(sp, cfg, cache,
                                    jnp.asarray([tok], jnp.int32),
                                    jnp.asarray(pos, jnp.int32))
    return (out, logs) if return_logits else out


def _assert_matches_oracle_up_to_ties(eng, req):
    """Long-horizon check: the scheduler's stream must equal the
    single-request oracle, except that it may diverge where greedy argmax is
    numerically TIED (bf16 tiny-model logit collisions — batched vs B=1
    accumulation order then legitimately picks a different winner; any
    divergence with a real logit gap is a scheduler bug)."""
    out, logs = _single_request_oracle(eng, req.prompt, req.max_new_tokens,
                                       return_logits=True)
    assert len(out) == len(req.out)
    for j, (a, b) in enumerate(zip(out, req.out)):
        if a == b:
            continue
        lg = logs[j]
        assert abs(lg[a] - lg[b]) <= 1e-3, (
            f"token {j}: oracle {a} (logit {lg[a]}) vs scheduler {b} "
            f"(logit {lg[b]}) — divergence without an argmax tie")
        return  # tie hit: later tokens legitimately differ

def test_continuous_matches_single_request_oracle(key):
    """Differential oracle: greedy outputs from the continuous scheduler are
    IDENTICAL per request to running each request alone (mixed prompt
    lengths and budgets, more requests than slots → mid-flight refills)."""
    eng = _tiny_engine(key, B=2)
    specs = [([3, 4, 5], 6), ([7], 4), ([9, 2, 11, 4], 5), ([6, 6], 7),
             ([12, 13, 14], 3)]
    want = [_single_request_oracle(eng, p, n) for p, n in specs]
    reqs = [Request(prompt=p, max_new_tokens=n) for p, n in specs]
    eng.serve(reqs, max_steps=200)
    for r, w in zip(reqs, want):
        assert r.done and r.out == w, (r.out, w)


def test_continuous_stop_token_matches_oracle(key):
    """A stop token must free the slot at the same step the oracle stops."""
    eng = _tiny_engine(key, B=2)
    base = _single_request_oracle(eng, [3, 4, 5], 6)
    stop = base[1]  # greedy 2nd token — learned, so the test is model-free
    want = _single_request_oracle(eng, [3, 4, 5], 6, stop=stop)
    assert want == base[:2]
    r = Request(prompt=[3, 4, 5], max_new_tokens=6, stop_token=stop)
    other = Request(prompt=[7], max_new_tokens=4)
    eng.serve([r, other], max_steps=200)
    assert r.out == want
    assert other.out == _single_request_oracle(eng, [7], 4)


def test_scheduler_refills_freed_slots(key):
    """More requests than slots must still all complete, with admissions
    strictly FIFO and ≤ B slots ever active."""
    eng = _tiny_engine(key, B=2)
    reqs = [Request(prompt=[2 + i], max_new_tokens=2 + (i % 3))
            for i in range(5)]
    sched = ContinuousScheduler(eng)
    for r in reqs:
        sched.submit(r)
    max_active = 0
    steps = 0
    while sched.pending:
        sched.step()
        max_active = max(max_active, sched.num_active)
        steps += 1
        assert steps < 200
    assert all(r.done and len(r.out) == r.max_new_tokens for r in reqs)
    assert sched.admission_order == reqs  # FIFO
    assert max_active <= 2
    # continuous batching used fewer steps than summed sequential decode
    assert sched.stats.steps < sum(r.max_new_tokens for r in reqs)


# ---------------------------------------------------------------------------
# tier-2 soak (slow marker — scheduled CI job, excluded from tier-1)
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_soak_skewed_lengths_randomized_arrivals(key):
    """Many short + few long requests with randomized mid-run arrivals; every
    request completes with exactly its budgeted tokens and matches the
    single-request oracle on a sampled subset."""
    eng = _tiny_engine(key, B=3, max_len=96)
    rng = random.Random(0)
    reqs = []
    for i in range(24):
        long = i % 8 == 7  # few long, many short
        prompt = [2 + (i % 19), 3 + (i % 11)][: 1 + i % 2]
        reqs.append(Request(prompt=prompt,
                            max_new_tokens=rng.randint(24, 32) if long
                            else rng.randint(1, 4)))
    sched = ContinuousScheduler(eng)
    pending = list(reqs)
    for _ in range(3):  # a few requests are present at t=0
        sched.submit(pending.pop(0))
    steps = 0
    while sched.pending or pending:
        if pending and rng.random() < 0.4:
            sched.submit(pending.pop(0))
        sched.step()
        steps += 1
        assert steps < 2000, "soak did not drain"
    assert all(r.done and len(r.out) == r.max_new_tokens for r in reqs)
    assert sched.stats.emitted_tokens == sum(r.max_new_tokens for r in reqs)
    assert sched.admission_order == reqs  # arrivals were in submission order
    for r in rng.sample(reqs, 4):  # spot-check decode correctness
        _assert_matches_oracle_up_to_ties(eng, r)
