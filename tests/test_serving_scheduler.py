"""Continuous-batching scheduler: property-tested invariants (fake backend),
the continuous-vs-single-request differential oracle (real engine), the
generational run() overflow guard, and the tier-2 soak test (`slow` marker,
run by the scheduled CI job — tier-1 skips it via pytest.ini addopts)."""

import random

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs.registry import get_smoke_config
from repro.models.decode import decode_step, prefill, quantize_for_serving
from repro.models.model import init_params
from repro.serving.engine import DecodeEngine, Request
from repro.serving.scheduler import ContinuousScheduler


# ---------------------------------------------------------------------------
# fake backend: scheduler invariants without a model
# ---------------------------------------------------------------------------


class FakeBackend:
    """Deterministic ScheduleBackend: each request carries a scripted token
    stream (``req._script``); slot ``b`` replays its request's script one
    token per step.  Asserts the scheduler never refills a live slot."""

    def __init__(self, batch_size: int):
        self.batch_size = batch_size
        self.admitted: list[Request] = []

    def sched_start(self):
        return [None] * self.batch_size  # slot → {"req", "emitted"} | None

    def sched_admit(self, state, slot, request):
        assert state[slot] is None, f"refill clobbered live slot {slot}"
        self.admitted.append(request)
        state = list(state)
        state[slot] = {"req": request, "emitted": 0}
        return state

    def sched_step(self, state):
        B = self.batch_size
        tokens = np.full(B, -1, np.int64)
        alive = np.zeros(B, bool)
        state = list(state)
        for b, s in enumerate(state):
            if s is None:
                continue
            req, t = s["req"], s["emitted"]
            tok = req._script[t]
            s["emitted"] = t + 1
            tokens[b] = tok
            stopped = req.stop_token is not None and tok == req.stop_token
            if stopped or s["emitted"] >= req.max_new_tokens:
                state[b] = None  # backend-side: slot is dead now
            else:
                alive[b] = True
        return state, tokens, alive


class FakeSpecBackend:
    """Deterministic ScheduleBackend speaking the speculative
    accept/rollback protocol: per round, slot ``b`` accepts a ragged
    1..spec_k-token window of its request's script (``accept(round, slot)``
    decides how many), then applies the engine's stop/budget masking.  The
    candidate rows are padded past the script with ``-7`` poison — a
    scheduler that reads past ``n_emit`` emits poison and fails the stream
    equality checks."""

    #: sched_spec_step accepts the optional per-slot window argument
    spec_window_aware = True

    def __init__(self, batch_size: int, spec_k: int = 3, accept=None):
        self.batch_size = batch_size
        self.spec_k = spec_k
        self.accept = accept or (lambda rnd, b: 1 + (rnd + b) % spec_k)
        self.admitted: list[Request] = []
        self.rounds = 0
        self.drafted = 0
        self.accepted = 0
        #: rid → list of draft windows the scheduler asked for (dynamic
        #: spec_k assertions)
        self.windows_seen: dict[int, list[int]] = {}

    def sched_start(self):
        return [None] * self.batch_size

    def sched_admit(self, state, slot, request):
        assert state[slot] is None, f"refill clobbered live slot {slot}"
        self.admitted.append(request)
        state = list(state)
        state[slot] = {"req": request, "emitted": 0}
        return state

    def sched_step(self, state):
        raise AssertionError("speculative backend: the scheduler must route "
                             "through sched_spec_step, not sched_step")

    def sched_spec_step(self, state, window=None):
        B, K = self.batch_size, self.spec_k
        win = [K] * B if window is None else [int(w) for w in window]
        assert all(2 <= w <= K for w in win), win
        tokens = np.full((B, K), -7, np.int64)  # poison past the window
        n_acc = np.zeros(B, np.int64)
        n_emit = np.zeros(B, np.int64)
        alive = np.zeros(B, bool)
        state = list(state)
        for b, s in enumerate(state):
            if s is None:
                continue
            req, t = s["req"], s["emitted"]
            self.windows_seen.setdefault(req.rid, []).append(win[b])
            remaining = req.max_new_tokens - t
            window_toks = req._script[t:t + K]
            tokens[b, :len(window_toks)] = window_toks
            # the draft window caps the accepted prefix (the engine rejects
            # everything past it)
            acc = min(self.accept(self.rounds, b), win[b])
            assert 1 <= acc <= K
            self.drafted += win[b] - 1
            self.accepted += acc - 1
            # the engine's on-device masking: emit through the first stop in
            # the accepted window, never past the budget
            stop_at = K
            for j in range(min(acc, len(window_toks))):
                if req.stop_token is not None and \
                        window_toks[j] == req.stop_token:
                    stop_at = j
                    break
            emit = min(acc, stop_at + 1, remaining)
            n_acc[b], n_emit[b] = acc, emit
            s["emitted"] = t + emit
            stopped = stop_at < emit
            if stopped or req.max_new_tokens - s["emitted"] <= 0:
                state[b] = None
            else:
                alive[b] = True
        self.rounds += 1
        return state, tokens, n_acc, n_emit, alive


def _make_workload(rng: random.Random, n_reqs: int):
    """Requests with unique scripted streams; some stop early, some have a
    zero budget (must complete without ever occupying a slot)."""
    reqs, want = [], []
    for rid in range(n_reqs):
        budget = rng.randint(0, 9) if rng.random() < 0.15 else rng.randint(1, 9)
        script = [rid * 1000 + t for t in range(max(budget, 1))]
        stop = None
        expected = script[:budget]
        if budget and rng.random() < 0.4:  # stop token somewhere mid-stream
            k = rng.randint(0, budget - 1)
            stop = script[k]
            expected = script[:k + 1]
        r = Request(prompt=[1], max_new_tokens=budget, stop_token=stop)
        r._script = script
        reqs.append(r)
        want.append(expected)
    return reqs, want


@settings(max_examples=25, deadline=None)
@given(st.integers(1, 4), st.integers(0, 14), st.integers(0, 10_000))
def test_scheduler_invariants(batch, n_reqs, seed):
    """No token loss or duplication, FIFO admission, every request completes,
    live slots are never clobbered (asserted inside FakeBackend)."""
    rng = random.Random(seed)
    backend = FakeBackend(batch)
    reqs, want = _make_workload(rng, n_reqs)
    streamed = {id(r): [] for r in reqs}
    sched = ContinuousScheduler(
        backend, on_token=lambda r, t: streamed[id(r)].append(t))
    for r in reqs:
        sched.submit(r)
    done = sched.run(max_steps=10_000)

    assert len(done) == len(reqs)
    for r, w in zip(reqs, want):
        assert r.done
        assert r.out == w, "token stream lost/duplicated/reordered"
        assert streamed[id(r)] == w  # streaming callback saw the same tokens
    # FIFO: admission order == submission order, minus zero-budget requests
    # (they complete immediately without taking a slot)
    assert backend.admitted == [r for r in reqs if r.max_new_tokens > 0]
    assert sched.stats.emitted_tokens == sum(len(w) for w in want)
    assert sched.stats.completed == len(reqs)


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 3), st.integers(1, 10), st.integers(0, 10_000))
def test_scheduler_mid_run_submission(batch, n_extra, seed):
    """submit() between steps (random arrivals) preserves FIFO and loses
    nothing — the admission-queue half of continuous batching."""
    rng = random.Random(seed)
    backend = FakeBackend(batch)
    initial, want_i = _make_workload(rng, 3)
    extra, want_e = _make_workload(rng, n_extra)
    sched = ContinuousScheduler(backend)
    for r in initial:
        sched.submit(r)
    arrivals = list(extra)
    steps = 0
    while sched.pending or arrivals:
        if arrivals and rng.random() < 0.5:
            sched.submit(arrivals.pop(0))
        sched.step()
        steps += 1
        assert steps < 10_000
    for r, w in zip(initial + extra, want_i + want_e):
        assert r.done and r.out == w
    admitted_nonzero = [r for r in initial + extra if r.max_new_tokens > 0]
    # extras arrive one at a time in order, so FIFO still == submission order
    assert backend.admitted == admitted_nonzero


@settings(max_examples=25, deadline=None)
@given(st.integers(1, 4), st.integers(0, 12), st.integers(2, 4),
       st.integers(0, 10_000))
def test_spec_scheduler_invariants(batch, n_reqs, spec_k, seed):
    """The speculative protocol under the same invariants as the scalar
    one: ragged 1..spec_k windows reassemble into exactly the scripted
    streams (no loss, duplication, reordering, or poison past n_emit), FIFO
    admission holds, and the acceptance tallies match the backend's own
    ground truth."""
    rng = random.Random(seed)
    backend = FakeSpecBackend(batch, spec_k=spec_k)
    reqs, want = _make_workload(rng, n_reqs)
    streamed = {id(r): [] for r in reqs}
    sched = ContinuousScheduler(
        backend, on_token=lambda r, t: streamed[id(r)].append(t))
    for r in reqs:
        sched.submit(r)
    sched.run(max_steps=10_000)
    for r, w in zip(reqs, want):
        assert r.done
        assert r.out == w, "ragged emission lost/duplicated/reordered tokens"
        assert streamed[id(r)] == w
    assert backend.admitted == [r for r in reqs if r.max_new_tokens > 0]
    assert sched.stats.emitted_tokens == sum(len(w) for w in want)
    assert sched.stats.spec_rounds == sched.stats.decode_steps
    assert sched.stats.drafted_tokens == backend.drafted
    assert sched.stats.accepted_drafted_tokens == backend.accepted
    assert sum(sched.stats.accepted_by_rid.values()) == backend.accepted
    assert set(sched.stats.accepted_by_rid) <= {r.rid for r in reqs}


def test_spec_budget_exhausted_mid_window():
    """A round that accepts MORE tokens than the request's remaining budget
    must emit exactly the remainder, mark the request done, and free the
    slot for the next queued request — the clip happens at n_emit while
    n_acc (and the acceptance stats) keep the full accepted count."""
    backend = FakeSpecBackend(1, spec_k=4, accept=lambda rnd, b: 4)
    first = Request(prompt=[1], max_new_tokens=6)   # 6 = 4 + (2: mid-window)
    first._script = list(range(100, 110))
    second = Request(prompt=[1], max_new_tokens=3)
    second._script = list(range(200, 210))
    sched = ContinuousScheduler(backend)
    sched.submit(first)
    sched.submit(second)
    sched.run(max_steps=100)
    assert first.done and first.out == list(range(100, 106))
    assert second.done and second.out == list(range(200, 203))
    # round 2 accepted 4 but emitted 2 — stats keep the accepted count
    assert sched.stats.accepted_drafted_tokens == backend.accepted
    assert sched.stats.emitted_tokens == 9
    # budget clipping stranded slots mid-round, yet no round was wasted:
    # first took ceil rounds, second refilled the freed slot afterwards
    assert sched.stats.spec_rounds == 2 + 1


def test_spec_stop_token_mid_window():
    """A stop token in the middle of an accepted window: emit through the
    stop (inclusive), never past it, and free the slot that same round."""
    backend = FakeSpecBackend(1, spec_k=4, accept=lambda rnd, b: 4)
    req = Request(prompt=[1], max_new_tokens=8, stop_token=102)
    req._script = list(range(100, 110))  # stop sits at window position 2
    sched = ContinuousScheduler(backend)
    sched.submit(req)
    sched.run(max_steps=100)
    assert req.done
    assert req.out == [100, 101, 102], "must stop AT the stop token"
    assert sched.stats.spec_rounds == 1
    assert sched.stats.emitted_tokens == 3


def test_submit_completed_request_rejected():
    sched = ContinuousScheduler(FakeBackend(1))
    r = Request(prompt=[1], max_new_tokens=1)
    r.done = True
    with pytest.raises(ValueError):
        sched.submit(r)


def test_admission_only_steps_are_counted():
    """A step that only advances admission (no live slot yet) must still bump
    ``stats.steps`` — the regression was an early return that skipped the
    tally, so benchmark tok/step silently inflated.  It lands in
    ``admission_steps`` so ``decode_steps`` stays honest."""
    backend = FakeBackend(1)
    sched = ContinuousScheduler(backend)
    sched.step()  # nothing queued, nothing active: pure-admission step
    assert sched.stats.steps == 1
    assert sched.stats.admission_steps == 1
    assert sched.stats.decode_steps == 0


def test_queue_wait_recorded_per_rid_under_pure_fifo():
    """Every admitted request gets a queue-wait entry keyed on its rid,
    including under pure FIFO admission on an atomic backend (the per-tenant
    analysis joins on this map — a gap here silently reports zero waits).
    An injected virtual clock makes the waits exact."""
    now = [0.0]
    backend = FakeBackend(1)  # atomic admission, no prefix_match_len
    sched = ContinuousScheduler(backend, cache_affinity=False,
                                clock=lambda: now[0])
    reqs = []
    for i in range(3):
        r = Request(prompt=[1], max_new_tokens=2)
        r._script = [i * 10, i * 10 + 1]
        reqs.append(r)
        sched.submit(r)
    # B=1: request i waits while 0..i-1 run (2 steps each); tick the clock
    # one unit per scheduler step
    while sched.pending:
        sched.step()
        now[0] += 1.0
    waits = sched.stats.queue_wait_by_rid
    assert set(waits) == {r.rid for r in reqs}, "a FIFO admission went "\
        "unrecorded"
    assert len(sched.stats.queue_wait_s) == len(reqs)
    # admission happens at the START of the step that seats the request:
    # req0 at t=0, req1 after 2 decode steps (t=2), req2 at t=4
    assert [waits[r.rid] for r in reqs] == [0.0, 2.0, 4.0]


def test_dynamic_spec_k_shrinks_low_acceptance_window():
    """Dynamic spec_k (ROADMAP speculative follow-on (c)): a request whose
    drafts keep getting rejected must shrink its window to the floor (2)
    while a fully-accepted co-batched request keeps the full spec_k.  The
    accept function keys on slot: slot 0 always accepts only the free
    token, slot 1 accepts everything the window allows."""
    K = 5
    backend = FakeSpecBackend(2, spec_k=K,
                              accept=lambda rnd, b: 1 if b == 0 else K)
    low = Request(prompt=[1], max_new_tokens=12)
    low._script = list(range(100, 120))
    high = Request(prompt=[1], max_new_tokens=12)
    high._script = list(range(200, 220))
    sched = ContinuousScheduler(backend, dynamic_spec_k=True)
    sched.submit(low)
    sched.submit(high)
    sched.run(max_steps=100)
    assert low.out == list(range(100, 112))
    assert high.out == list(range(200, 212))
    lw, hw = backend.windows_seen[low.rid], backend.windows_seen[high.rid]
    # both start optimistic at the full window...
    assert lw[0] == K and hw[0] == K
    # ...the rejected request decays to the floor and stays there...
    assert lw[-1] == 2 and min(lw) == 2
    assert all(a >= b for a, b in zip(lw, lw[1:])), \
        f"low-acceptance window must shrink monotonically, got {lw}"
    # ...while the fully-accepted one never leaves the full window
    assert all(w == K for w in hw), hw
    # drafted-token accounting charges the shrunken window, not spec_k
    assert sched.stats.drafted_tokens == backend.drafted
    assert sched.stats.drafted_tokens < sched.stats.spec_rounds * 2 * (K - 1)
    assert sched.stats.spec_window_by_rid[low.rid] == 2
    assert sched.stats.spec_window_by_rid[high.rid] == K


def test_dynamic_spec_k_rejects_window_unaware_backend():
    """Enabling dynamic_spec_k on a speculative backend that cannot take
    per-slot windows must fail loudly at construction, not silently run
    fixed-K."""

    class NoWindow(FakeSpecBackend):
        spec_window_aware = False

    with pytest.raises(ValueError, match="spec_window_aware"):
        ContinuousScheduler(NoWindow(2, spec_k=3), dynamic_spec_k=True)
    # non-speculative backends ignore the flag entirely
    ContinuousScheduler(FakeBackend(2), dynamic_spec_k=True)


# ---------------------------------------------------------------------------
# real engine: overflow guard, queued serving, differential oracle
# ---------------------------------------------------------------------------


def _tiny_engine(key, B=2, max_len=48, window=0, prefill_chunk=32):
    cfg = get_smoke_config("bitnet-b1.58-2b").with_(
        n_layers=2, d_model=64, n_heads=2, n_kv_heads=2, head_dim=32,
        d_ff=128, vocab_size=256, loss_chunk=32, window=window)
    sp = quantize_for_serving(init_params(cfg, key), cfg)
    return DecodeEngine(sp, cfg, batch_size=B, max_len=max_len,
                        matmul_policy="fixed:ref",
                        prefill_chunk=prefill_chunk)


def test_run_overflow_raises_value_error(key):
    """run() must raise a real ValueError (not a bare assert, which vanishes
    under python -O) when handed more requests than slots."""
    eng = _tiny_engine(key, B=2)
    reqs = [Request(prompt=[3], max_new_tokens=1) for _ in range(3)]
    with pytest.raises(ValueError, match="batch_size"):
        eng.run(reqs)


def test_admit_rejects_overlong_request(key):
    eng = _tiny_engine(key, B=2, max_len=8)
    with pytest.raises(ValueError, match="max_len"):
        eng.serve([Request(prompt=[3, 4, 5, 6], max_new_tokens=8)])
    # generational run() enforces the same bound (out-of-range positions
    # would silently scatter-drop their KV writes otherwise)
    with pytest.raises(ValueError, match="max_len"):
        eng.run([Request(prompt=[3] * 6, max_new_tokens=8)])


def _single_request_oracle(eng, prompt, max_new, stop=None,
                           return_logits=False):
    """The seed generational semantics: one request alone through prefill +
    scalar-index decode_step, greedy."""
    sp, cfg = eng.params, eng.cfg
    toks = jnp.asarray(np.asarray(prompt, np.int32)[None])
    cache, logits = prefill(sp, cfg, {"tokens": toks}, s_max=eng.max_len)
    out, logs, pos = [], [], len(prompt) - 1
    for _ in range(max_new):
        logs.append(np.asarray(logits[0], np.float32))
        tok = int(jnp.argmax(logits[0]))
        out.append(tok)
        if stop is not None and tok == stop:
            break
        pos += 1
        logits, cache = decode_step(sp, cfg, cache,
                                    jnp.asarray([tok], jnp.int32),
                                    jnp.asarray(pos, jnp.int32))
    return (out, logs) if return_logits else out


def _assert_matches_oracle_up_to_ties(eng, req):
    """Long-horizon check: the scheduler's stream must equal the
    single-request oracle, except that it may diverge where greedy argmax is
    numerically TIED (bf16 tiny-model logit collisions — batched vs B=1
    accumulation order then legitimately picks a different winner; any
    divergence with a real logit gap is a scheduler bug)."""
    out, logs = _single_request_oracle(eng, req.prompt, req.max_new_tokens,
                                       return_logits=True)
    assert len(out) == len(req.out)
    for j, (a, b) in enumerate(zip(out, req.out)):
        if a == b:
            continue
        lg = logs[j]
        assert abs(lg[a] - lg[b]) <= 1e-3, (
            f"token {j}: oracle {a} (logit {lg[a]}) vs scheduler {b} "
            f"(logit {lg[b]}) — divergence without an argmax tie")
        return  # tie hit: later tokens legitimately differ

def test_continuous_matches_single_request_oracle(key):
    """Differential oracle: greedy outputs from the continuous scheduler are
    IDENTICAL per request to running each request alone (mixed prompt
    lengths and budgets, more requests than slots → mid-flight refills)."""
    eng = _tiny_engine(key, B=2)
    specs = [([3, 4, 5], 6), ([7], 4), ([9, 2, 11, 4], 5), ([6, 6], 7),
             ([12, 13, 14], 3)]
    want = [_single_request_oracle(eng, p, n) for p, n in specs]
    reqs = [Request(prompt=p, max_new_tokens=n) for p, n in specs]
    eng.serve(reqs, max_steps=200)
    for r, w in zip(reqs, want):
        assert r.done and r.out == w, (r.out, w)


def test_continuous_stop_token_matches_oracle(key):
    """A stop token must free the slot at the same step the oracle stops."""
    eng = _tiny_engine(key, B=2)
    base = _single_request_oracle(eng, [3, 4, 5], 6)
    stop = base[1]  # greedy 2nd token — learned, so the test is model-free
    want = _single_request_oracle(eng, [3, 4, 5], 6, stop=stop)
    assert want == base[:2]
    r = Request(prompt=[3, 4, 5], max_new_tokens=6, stop_token=stop)
    other = Request(prompt=[7], max_new_tokens=4)
    eng.serve([r, other], max_steps=200)
    assert r.out == want
    assert other.out == _single_request_oracle(eng, [7], 4)


def test_continuous_matches_oracle_windowed(key):
    """Differential oracle, windowed config with prompts LONGER than the
    window — prefill wraps the ring, exactly where the slot-invariant bug
    hid: decode after a misaligned prefill silently dropped one attended
    in-window key per step.  Greedy streams must match the single-request
    oracle (up to bf16 argmax ties: multi-chunk admission merges attention
    chunks in a different order than whole-prompt prefill)."""
    eng = _tiny_engine(key, B=2, window=8, prefill_chunk=8)
    specs = [([3, 4, 5, 6, 7, 8, 9, 10, 11, 12], 6),  # S=10 >= CL=8: wraps
             ([7, 2], 4),
             ([9, 2, 11, 4, 13, 6, 15, 8, 17], 5),    # S=9: wraps mid-chunk
             ([6, 6, 6], 7)]
    reqs = [Request(prompt=p, max_new_tokens=n) for p, n in specs]
    eng.serve(reqs, max_steps=200)
    for r in reqs:
        assert r.done and len(r.out) == r.max_new_tokens
        _assert_matches_oracle_up_to_ties(eng, r)


def test_admission_compiles_one_trace_per_bucket(key):
    """Bucketed admission: a mixed-length request stream must compile the
    chunked prefill exactly once (one chunk shape = one bucket) and the
    commit exactly once — not one trace per prompt length, which is what the
    whole-prompt fallback path costs."""
    eng = _tiny_engine(key, B=2, prefill_chunk=4)
    reqs = [Request(prompt=[2 + j for j in range(1 + i)], max_new_tokens=2)
            for i in range(7)]  # prompt lengths 1..7: 1- and 2-chunk buckets
    eng.serve(reqs, max_steps=400)
    assert all(r.done and len(r.out) == 2 for r in reqs)
    assert eng.chunked_admission
    assert eng.trace_counts["prefill_chunk"] == 1, eng.trace_counts
    assert eng.trace_counts["admit_commit"] == 1, eng.trace_counts
    # the retracing whole-prompt fallback was never taken
    assert eng.trace_counts["prefill"] == 0, eng.trace_counts
    assert eng.trace_counts["sched_step"] == 1, eng.trace_counts


def test_admission_budget_interleaves_decode_with_long_prefill(key):
    """With an admission budget, a long arriving prompt is prefilled a chunk
    at a time while the co-batched live request keeps emitting tokens — its
    time-to-next-token stays bounded — and every stream still matches the
    single-request oracle."""
    eng = _tiny_engine(key, B=2, prefill_chunk=2, max_len=64)
    short = Request(prompt=[3], max_new_tokens=10)
    long = Request(prompt=[5 + i for i in range(12)], max_new_tokens=4)

    sched = ContinuousScheduler(eng, admission_budget=1)
    order = []
    short.on_token = lambda r, t: order.append("s")
    long.on_token = lambda r, t: order.append("l")
    sched.submit(short)
    sched.submit(long)
    steps = 0
    while sched.pending:
        sched.step()
        steps += 1
        assert steps < 200
    assert short.done and long.done
    # the long prompt needed 6 chunks at budget 1; the short request decoded
    # throughout, so its first several tokens precede long's first token
    first_l = order.index("l")
    assert first_l >= 5, order
    assert sched.stats.prefill_chunks >= 6 + 1  # long (6) + short (1)
    _assert_matches_oracle_up_to_ties(eng, short)
    _assert_matches_oracle_up_to_ties(eng, long)


def test_run_marks_budget_exhausted_requests_done(key):
    """run() regression: a request that spends its whole budget WITHOUT a
    stop-token hit must come back ``done`` — it used to stay not-done, so
    resubmitting it to a scheduler double-served it (duplicate tokens
    appended after the completed stream)."""
    eng = _tiny_engine(key, B=2)
    reqs = [Request(prompt=[3, 4], max_new_tokens=3),
            Request(prompt=[7], max_new_tokens=2)]
    eng.run(reqs)
    for r in reqs:
        assert len(r.out) == r.max_new_tokens
        assert r.done, "budget-exhausted request left not-done by run()"
    # ...which is exactly what the scheduler's resubmission guard keys on
    sched = ContinuousScheduler(eng)
    with pytest.raises(ValueError, match="completed"):
        sched.submit(reqs[0])


def test_chunked_admission_steps_counted_separately(key):
    """Steps that only advance a long prompt's prefill chunks (budget 1, no
    live slot) count in ``stats.steps`` AND ``stats.admission_steps``;
    ``decode_steps`` equals the steps that actually emitted tokens."""
    eng = _tiny_engine(key, B=1, prefill_chunk=2)
    long = Request(prompt=[5 + i for i in range(8)], max_new_tokens=4)
    sched = ContinuousScheduler(eng, admission_budget=1)
    sched.submit(long)
    sched.run(max_steps=100)
    assert long.done and len(long.out) == 4
    # 8-token prompt at chunk 2 / budget 1 → ≥ 3 steps with no decode yet
    assert sched.stats.admission_steps >= 3, sched.stats
    assert sched.stats.decode_steps == \
        sched.stats.steps - sched.stats.admission_steps
    # B=1, single request: every decode step emitted exactly one token
    assert sched.stats.decode_steps == sched.stats.emitted_tokens, sched.stats


def test_prefill_into_slot_splices_one_row(key):
    """The standalone atomic refill API: prefill one request and splice it
    into a single batch row — the other rows stay bit-identical and the
    spliced row equals a fresh single-request prefill."""
    from repro.models.decode import prefill, prefill_into_slot

    eng = _tiny_engine(key, B=2)
    sp, cfg = eng.params, eng.cfg
    toks = jnp.asarray([[3, 4, 5], [7, 8, 9]], jnp.int32)
    cache, _ = prefill(sp, cfg, {"tokens": toks}, s_max=eng.max_len)
    new_prompt = jnp.asarray([[11, 12]], jnp.int32)
    spliced, logits1 = prefill_into_slot(sp, cfg, cache,
                                         {"tokens": new_prompt},
                                         jnp.asarray(1, jnp.int32),
                                         s_max=eng.max_len)
    alone, logits_alone = prefill(sp, cfg, {"tokens": new_prompt},
                                  s_max=eng.max_len)
    for leaf in ("k", "v", "pos"):
        np.testing.assert_array_equal(  # untouched row is bit-identical
            np.asarray(spliced[leaf][:, 0], np.float32),
            np.asarray(cache[leaf][:, 0], np.float32))
        np.testing.assert_array_equal(  # spliced row == solo prefill row
            np.asarray(spliced[leaf][:, 1], np.float32),
            np.asarray(alone[leaf][:, 0], np.float32))
    np.testing.assert_array_equal(np.asarray(logits1),
                                  np.asarray(logits_alone[0]))


def test_fallback_arch_whole_prompt_admission(key):
    """Architectures without chunked-prefill support (recurrent state:
    zamba2) admit through the whole-prompt fallback — same commit splice,
    same oracle guarantees, `pending` returned as None from
    sched_admit_start."""
    cfg = get_smoke_config("zamba2-2.7b").with_(window=8, remat=False)
    sp = quantize_for_serving(init_params(cfg, key), cfg)
    eng = DecodeEngine(sp, cfg, batch_size=2, max_len=48,
                       matmul_policy="fixed:ref")
    assert not eng.chunked_admission
    # zamba2 prompts must be >= ssm_conv - 1 (conv state needs that many
    # tokens; a pre-existing prefill limitation, not an admission one)
    reqs = [Request(prompt=[3, 4, 5], max_new_tokens=3),
            Request(prompt=[7, 8, 9, 10], max_new_tokens=2),
            Request(prompt=[9, 2, 4, 6, 8], max_new_tokens=2)]
    eng.serve(reqs, max_steps=100)
    for r in reqs:
        assert r.done and len(r.out) == r.max_new_tokens
        _assert_matches_oracle_up_to_ties(eng, r)
    assert eng.trace_counts["prefill_chunk"] == 0
    # whole-prompt fallback retraces per distinct prompt length (3 here:
    # plens 3, 4, 5) — the cost the chunked path avoids
    assert eng.trace_counts["prefill"] == 3, eng.trace_counts


def test_scheduler_refills_freed_slots(key):
    """More requests than slots must still all complete, with admissions
    strictly FIFO and ≤ B slots ever active."""
    eng = _tiny_engine(key, B=2)
    reqs = [Request(prompt=[2 + i], max_new_tokens=2 + (i % 3))
            for i in range(5)]
    sched = ContinuousScheduler(eng)
    for r in reqs:
        sched.submit(r)
    max_active = 0
    steps = 0
    while sched.pending:
        sched.step()
        max_active = max(max_active, sched.num_active)
        steps += 1
        assert steps < 200
    assert all(r.done and len(r.out) == r.max_new_tokens for r in reqs)
    assert sched.admission_order == reqs  # FIFO
    assert max_active <= 2
    # continuous batching used fewer steps than summed sequential decode
    assert sched.stats.steps < sum(r.max_new_tokens for r in reqs)


# ---------------------------------------------------------------------------
# tier-2 soak (slow marker — scheduled CI job, excluded from tier-1)
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_soak_skewed_lengths_randomized_arrivals(key):
    """Many short + few long requests with randomized mid-run arrivals; every
    request completes with exactly its budgeted tokens and matches the
    single-request oracle on a sampled subset."""
    eng = _tiny_engine(key, B=3, max_len=96)
    rng = random.Random(0)
    reqs = []
    for i in range(24):
        long = i % 8 == 7  # few long, many short
        prompt = [2 + (i % 19), 3 + (i % 11)][: 1 + i % 2]
        reqs.append(Request(prompt=prompt,
                            max_new_tokens=rng.randint(24, 32) if long
                            else rng.randint(1, 4)))
    sched = ContinuousScheduler(eng)
    pending = list(reqs)
    for _ in range(3):  # a few requests are present at t=0
        sched.submit(pending.pop(0))
    steps = 0
    while sched.pending or pending:
        if pending and rng.random() < 0.4:
            sched.submit(pending.pop(0))
        sched.step()
        steps += 1
        assert steps < 2000, "soak did not drain"
    assert all(r.done and len(r.out) == r.max_new_tokens for r in reqs)
    assert sched.stats.emitted_tokens == sum(r.max_new_tokens for r in reqs)
    assert sched.admission_order == reqs  # arrivals were in submission order
    for r in rng.sample(reqs, 4):  # spot-check decode correctness
        _assert_matches_oracle_up_to_ties(eng, r)
