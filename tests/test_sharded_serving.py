"""Mesh-sharded continuous serving: per-shard dispatch localization, the
sharded-vs-single-device differential oracle, and the autotune-key
round-trip.

The oracle tests force 8 host devices via XLA_FLAGS, which must be set
before jax initializes — the parent test process already runs on one device,
so those comparisons run in a subprocess (both engines inside it, so the
token streams come from the same process/XLA build).
"""

import json
import os
import subprocess
import sys
import textwrap

import pytest

from repro.kernels.dispatch import (AutotuneCache, ShardInfo, select_kernel,
                                    shard_scope)

# ---------------------------------------------------------------------------
# ShardInfo localization (pure host logic — no mesh needed)
# ---------------------------------------------------------------------------


def test_local_dense_tp_roles():
    info = ShardInfo(model=4, data=2, batch=2)
    # out-projection: N sharded; batch divides M
    assert info.local_dense("wi", 8, 128, 256) == (4, 128, 64)
    # in-projection: N sharded (column-parallel packed layout — the byte
    # dim of a packed in-projection must stay whole, see sharding._IN_MODEL)
    assert info.local_dense("wo", 8, 256, 128) == (4, 256, 32)
    # unknown role: replicated weight, only M shards
    assert info.local_dense(None, 8, 128, 256) == (4, 128, 256)


def test_local_dense_non_divisible_stays_global():
    info = ShardInfo(model=4, data=2, batch=2)
    # N=102 % 4 != 0 → the _validate fallback replicates, so N stays global
    assert info.local_dense("wi", 8, 128, 102) == (4, 128, 102)
    # M=3 % 2 != 0 → batch replicated
    assert info.local_dense("wi", 3, 128, 256) == (3, 128, 64)


def test_local_dense_head_gating():
    """qkv projections shard out dims at whole-head granularity only — a
    head count that doesn't divide the model axis replicates the weight
    (matching ``sharding.param_specs(heads=...)``), so N stays global."""
    info = ShardInfo(model=4, data=1, batch=1, n_heads=4, n_kv_heads=1)
    assert info.local_dense("wq", 2, 128, 128) == (2, 128, 32)   # 4 % 4 == 0
    assert info.local_dense("wk", 2, 128, 32) == (2, 128, 32)    # MQA: repl
    assert info.local_dense("wv", 2, 128, 32) == (2, 128, 32)
    # zero head counts = gate off (legacy flat-dim sharding)
    legacy = ShardInfo(model=4, data=1, batch=1)
    assert legacy.local_dense("wk", 2, 128, 32) == (2, 128, 8)


def test_local_dense_no_tp_partial_replication():
    """mamba2's wz gate projection only TPs on a pure-model mesh — under
    partial replication (batch axes coexisting with model) it replicates
    (sharding._NO_TP_ROLES), so N stays global."""
    pure = ShardInfo(model=4, data=1, batch=1)
    assert pure.local_dense("wz", 2, 128, 256) == (2, 128, 64)
    mixed = ShardInfo(model=4, data=2, batch=1)
    assert mixed.local_dense("wz", 2, 128, 256) == (2, 128, 256)


def test_local_grouped_ep_tp():
    info = ShardInfo(model=2, data=2, batch=2)
    # wi: E on data, N on model; capacity stays global
    assert info.local_grouped("wi", 8, 4, 128, 256) == (4, 4, 128, 128)
    # wo: E on data, K on model
    assert info.local_grouped("wo", 8, 4, 256, 128) == (4, 4, 128, 128)
    # odd expert count: EP falls back to replicated
    assert info.local_grouped("wi", 7, 4, 128, 256) == (7, 4, 128, 128)


def test_shard_scope_restores_on_exit():
    from repro.kernels.dispatch import current_shard_info

    assert current_shard_info() is None
    with shard_scope(ShardInfo(model=2)):
        assert current_shard_info() == ShardInfo(model=2)
        with shard_scope(None):
            assert current_shard_info() is None
        assert current_shard_info() == ShardInfo(model=2)
    assert current_shard_info() is None


# ---------------------------------------------------------------------------
# autotune keys round-trip at the per-shard local problem (schema v2)
# ---------------------------------------------------------------------------


def test_autotune_key_uses_local_problem(tmp_autotune_cache):
    """A timing recorded at the LOCAL dims steers auto selection when the
    same GLOBAL problem is dispatched under the matching shard scope."""
    cache = AutotuneCache(path=str(tmp_autotune_cache))
    # global problem: wi with M=8,K=128,N=256 on model=4/batch=2 → local
    # (4, 128, 64); make the (slow-by-prior) dequant kernel the measured best
    cache.record(4, 128, 64, "float32", "cpu", "dequant_packed", 1.0)
    cache.record(4, 128, 64, "float32", "cpu", "ref", 9.0)
    with shard_scope(ShardInfo(model=4, data=2, batch=2)):
        spec = select_kernel(8, 128, 256, "float32", policy="auto",
                             backend="cpu", cache=cache, role="wi")
    assert spec.name == "dequant_packed"
    # same problem, no scope: global key has no entry → prior (ref on cpu)
    spec = select_kernel(8, 128, 256, "float32", policy="auto",
                         backend="cpu", cache=cache, role="wi")
    assert spec.name == "ref"
    # the cache file round-trips the local key in schema-v2 format
    cache.save()
    doc = json.loads(tmp_autotune_cache.read_text())
    assert doc["schema_version"] == 2
    assert "M4:K128:N64:mu3:float32:cpu" in doc["entries"]


def test_grouped_autotune_key_uses_local_problem(tmp_autotune_cache):
    cache = AutotuneCache(path=str(tmp_autotune_cache))
    # global E=8,C=4,K=256,N=128 wo under data=2/model=2 → E4:M4:K128:N128
    cache.record(4, 128, 128, "float32", "cpu", "grouped_dequant", 1.0,
                 e=4)
    cache.record(4, 128, 128, "float32", "cpu", "grouped_ref", 9.0, e=4)
    with shard_scope(ShardInfo(model=2, data=2, batch=2)):
        spec = select_kernel(4, 256, 128, "float32", policy="auto",
                             backend="cpu", cache=cache, e=8, role="wo")
    assert spec.name == "grouped_dequant"
    assert "E4:M4:K128:N128:mu3:float32:cpu" in cache.entries


# ---------------------------------------------------------------------------
# in-process 1x1 mesh: the sharded engine code path on a single device
# ---------------------------------------------------------------------------


def _tiny_dense_cfg():
    from repro.configs.registry import get_smoke_config

    return get_smoke_config("bitnet-b1.58-2b").with_(
        n_layers=2, d_model=64, n_heads=2, n_kv_heads=2, head_dim=32,
        d_ff=128, vocab_size=256, loss_chunk=32)


def _serve_tokens(engine, n_reqs=3, new=4):
    from repro.serving.engine import Request
    from repro.serving.scheduler import ContinuousScheduler

    reqs = [Request(prompt=[3 + i, 11, 2 + i], max_new_tokens=new)
            for i in range(n_reqs)]
    sched = ContinuousScheduler(engine, admission_budget=1)
    for r in reqs:
        sched.submit(r)
    sched.run(max_steps=1000)
    return [r.out for r in reqs]


def test_mesh_1x1_matches_unsharded(key):
    """The mesh-mode engine (explicit in/out shardings, shard_scope'd
    traces, device_put params) on a trivial 1x1 mesh serves the exact same
    streams as the plain engine — the sharded code path itself is a no-op
    at one device.  The mesh is built from the first local device directly
    (not ``make_serving_mesh("1x1")``, which correctly refuses when CI
    forces 8 host devices)."""
    import jax
    import numpy as np
    from jax.sharding import Mesh

    from repro.models.decode import quantize_for_serving
    from repro.models.model import init_params
    from repro.serving.engine import DecodeEngine

    cfg = _tiny_dense_cfg()
    served = quantize_for_serving(init_params(cfg, key), cfg)
    mesh = Mesh(np.asarray(jax.devices()[:1]).reshape(1, 1),
                ("data", "model"))
    sharded = DecodeEngine(served, cfg, batch_size=2, max_len=48,
                           matmul_policy="fixed:ref", prefill_chunk=8,
                           mesh=mesh)
    plain = DecodeEngine(served, cfg, batch_size=2, max_len=48,
                         matmul_policy="fixed:ref", prefill_chunk=8)
    assert _serve_tokens(sharded) == _serve_tokens(plain)
    # bucketed admission survives mesh mode: one prefill-chunk trace
    assert sharded.trace_counts["prefill_chunk"] == 1


def test_make_serving_mesh_validates():
    import jax

    from repro.launch.mesh import make_serving_mesh

    n = len(jax.devices())
    with pytest.raises(ValueError, match="devices"):
        make_serving_mesh(f"{n + 1}x{n + 1}")
    with pytest.raises(ValueError, match="mesh"):
        make_serving_mesh("2by2")


# ---------------------------------------------------------------------------
# subprocess differential oracle: 8 forced host devices, sharded == oracle
# ---------------------------------------------------------------------------

_ORACLE_SCRIPT = textwrap.dedent("""
    import json, sys
    import jax
    assert len(jax.devices()) == 8, jax.devices()
    from repro.configs.registry import get_smoke_config
    from repro.launch.mesh import make_serving_mesh
    from repro.models.decode import quantize_for_serving
    from repro.models.model import init_params
    from repro.serving.engine import DecodeEngine, Request
    from repro.serving.scheduler import ContinuousScheduler

    arch, mesh_spec, overrides = sys.argv[1], sys.argv[2], json.loads(sys.argv[3])
    cfg = get_smoke_config(arch).with_(**overrides)
    served = quantize_for_serving(init_params(cfg, jax.random.PRNGKey(1)), cfg)

    def serve(mesh):
        eng = DecodeEngine(served, cfg, batch_size=2, max_len=64,
                           matmul_policy="fixed:ref", prefill_chunk=8,
                           mesh=mesh)
        reqs = [Request(prompt=[3 + i, 11, 2 + i], max_new_tokens=6)
                for i in range(3)]
        sched = ContinuousScheduler(eng, admission_budget=1)
        for r in reqs:
            sched.submit(r)
        sched.run(max_steps=1000)
        return [r.out for r in reqs]

    base = serve(None)
    got = serve(make_serving_mesh(mesh_spec))
    print(json.dumps({"base": base, "sharded": got}))
""")


def _run_oracle(arch: str, mesh: str, overrides: dict,
                script: str = _ORACLE_SCRIPT) -> dict:
    env = dict(os.environ,
               JAX_PLATFORMS="cpu",
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    proc = subprocess.run(
        [sys.executable, "-c", script, arch, mesh,
         json.dumps(overrides)],
        capture_output=True, text=True, timeout=900, env=env)
    assert proc.returncode == 0, proc.stderr[-4000:]
    return json.loads(proc.stdout.strip().splitlines()[-1])


def test_sharded_serve_matches_oracle_dense():
    """Dense TP×batch mesh (2x4): greedy streams are exactly the
    single-device streams — whole-head TP plus replicated-when-non-divisible
    keeps every cross-device op either exact (all-gather, masked EP sum) or
    order-stable for this config."""
    out = _run_oracle("bitnet-b1.58-2b", "2x4",
                      {"n_layers": 2, "d_model": 128, "n_heads": 4,
                       "n_kv_heads": 2, "head_dim": 32, "d_ff": 256,
                       "vocab_size": 512})
    assert out["sharded"] == out["base"], out
    assert all(len(s) == 6 for s in out["base"])


def test_sharded_serve_matches_oracle_dense_model8():
    """Pure-TP mesh (1x8): exact greedy-stream match at model=8.  This was
    the long-open token-flip config — root cause was the packed
    in-projection rule sharding the packed *byte* dim, which breaks the
    base-3 unpack's logical-K slice at some shard widths (≈0.5 absolute
    prefill-logit error).  The column-parallel packed layout (dout sharded)
    is exact: no partial sums, so no reduce-order drift either."""
    out = _run_oracle("bitnet-b1.58-2b", "1x8",
                      {"n_layers": 2, "d_model": 128, "n_heads": 4,
                       "n_kv_heads": 2, "head_dim": 32, "d_ff": 256,
                       "vocab_size": 512})
    assert out["sharded"] == out["base"], out
    assert all(len(s) == 6 for s in out["base"])


_SPEC_ORACLE_SCRIPT = textwrap.dedent("""
    import json, sys
    import jax
    assert len(jax.devices()) == 8, jax.devices()
    from repro.configs.registry import get_smoke_config
    from repro.launch.mesh import make_serving_mesh
    from repro.models.decode import quantize_for_serving
    from repro.models.model import init_params
    from repro.serving.engine import DecodeEngine, Request, SamplerConfig
    from repro.serving.scheduler import ContinuousScheduler

    arch, mesh_spec, overrides = sys.argv[1], sys.argv[2], json.loads(sys.argv[3])
    cfg = get_smoke_config(arch).with_(**overrides)
    served = quantize_for_serving(init_params(cfg, jax.random.PRNGKey(1)), cfg)
    dcfg = cfg.with_(n_layers=1, name="qwen3-0.6b")
    dparams = quantize_for_serving(init_params(dcfg, jax.random.PRNGKey(7)),
                                   dcfg)

    def serve(draft):
        eng = DecodeEngine(served, cfg, batch_size=2, max_len=64,
                           matmul_policy="fixed:ref", prefill_chunk=8,
                           mesh=make_serving_mesh(mesh_spec),
                           sampler=SamplerConfig(canonical_greedy=True),
                           draft=draft, spec_k=4 if draft else 2)
        reqs = [Request(prompt=[3 + i, 11, 2 + i], max_new_tokens=6)
                for i in range(3)]
        sched = ContinuousScheduler(eng, admission_budget=1)
        for r in reqs:
            sched.submit(r)
        sched.run(max_steps=1000)
        return [r.out for r in reqs], sched.stats

    base, _ = serve(None)
    spec, st = serve((dparams, dcfg))
    print(json.dumps({"base": base, "spec": spec,
                      "rounds": st.spec_rounds,
                      "drafted": st.drafted_tokens,
                      "accepted": st.accepted_drafted_tokens}))
""")


def test_sharded_spec_serve_matches_nonspec_1x8():
    """Speculative serving on a pure-TP 1x8 mesh: the sharded verify (target
    TP geometry) plus the replicated draft must stream byte-identical greedy
    output to the sharded NON-speculative engine — both under the canonical
    bf16-argmax greedy the speculative round is defined over.  A mismatched
    1-layer random draft keeps acceptance partial, so rollback runs on the
    sharded KV cache too."""
    out = _run_oracle("bitnet-b1.58-2b", "1x8",
                      {"n_layers": 2, "d_model": 128, "n_heads": 4,
                       "n_kv_heads": 2, "head_dim": 32, "d_ff": 256,
                       "vocab_size": 512},
                      script=_SPEC_ORACLE_SCRIPT)
    assert out["spec"] == out["base"], out
    assert all(len(s) == 6 for s in out["base"])
    assert out["rounds"] > 0 and out["drafted"] > 0, out
    assert 0 <= out["accepted"] <= out["drafted"], out


_PREFIX_ORACLE_SCRIPT = textwrap.dedent("""
    import json, sys
    import jax
    assert len(jax.devices()) == 8, jax.devices()
    from repro.configs.registry import get_smoke_config
    from repro.launch.mesh import make_serving_mesh
    from repro.models.decode import quantize_for_serving
    from repro.models.model import init_params
    from repro.serving.engine import DecodeEngine, Request
    from repro.serving.scheduler import ContinuousScheduler

    arch, mesh_spec, overrides = sys.argv[1], sys.argv[2], json.loads(sys.argv[3])
    cfg = get_smoke_config(arch).with_(**overrides)
    served = quantize_for_serving(init_params(cfg, jax.random.PRNGKey(1)), cfg)
    shared = [3, 4, 5, 6, 7, 8, 9, 10, 11, 12]
    specs = [(shared + [20 + i], 5) for i in range(3)] + [([9, 2, 4], 5)]

    def serve_pass(eng):
        reqs = [Request(prompt=p, max_new_tokens=n) for p, n in specs]
        sched = ContinuousScheduler(eng, admission_budget=1)
        for r in reqs:
            sched.submit(r)
        sched.run(max_steps=1000)
        return [r.out for r in reqs]

    def engine(mesh, prefix_cache):
        return DecodeEngine(served, cfg, batch_size=2, max_len=64,
                            matmul_policy="fixed:ref", prefill_chunk=4,
                            mesh=mesh, prefix_cache=prefix_cache)

    base = serve_pass(engine(make_serving_mesh(mesh_spec), False))
    cached = engine(make_serving_mesh(mesh_spec), True)
    cold = serve_pass(cached)    # publishes + intra-pass hits
    warm = serve_pass(cached)    # hits everything publishable
    st = cached.prefix_store.stats
    print(json.dumps({"base": base, "cold": cold, "warm": warm,
                      "hit_blocks": st.hit_blocks,
                      "reused_tokens": st.reused_tokens,
                      "traces": dict(cached.trace_counts)}))
""")


def test_sharded_prefix_cache_matches_oracle_1x8():
    """Prefix-cache acceptance on a mesh: warm-store reuse on a 1x8 TP mesh
    serves greedy streams byte-identical to the no-cache sharded engine —
    slabs are extracted, stored, and spliced in the kv-head-sharded layout
    (``block_slab_specs``), so reuse moves no bytes and changes no math —
    and cache hits mint no extra jit traces."""
    env = dict(os.environ,
               JAX_PLATFORMS="cpu",
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    overrides = {"n_layers": 2, "d_model": 128, "n_heads": 4,
                 "n_kv_heads": 2, "head_dim": 32, "d_ff": 256,
                 "vocab_size": 512}
    proc = subprocess.run(
        [sys.executable, "-c", _PREFIX_ORACLE_SCRIPT, "bitnet-b1.58-2b",
         "1x8", json.dumps(overrides)],
        capture_output=True, text=True, timeout=900, env=env)
    assert proc.returncode == 0, proc.stderr[-4000:]
    out = json.loads(proc.stdout.strip().splitlines()[-1])
    assert out["cold"] == out["base"], out
    assert out["warm"] == out["base"], out
    assert out["hit_blocks"] > 0 and out["reused_tokens"] > 0, out
    assert out["traces"]["prefill_chunk"] == 1, out["traces"]
    assert out["traces"]["splice_block"] == 1, out["traces"]


def test_sharded_serve_matches_oracle_moe():
    """MoE EP×TP mesh (2x4): expert stacks sharded E/2 on data with TP
    inside each expert, MQA kv replicated by the head gate — streams match
    the single-device oracle exactly."""
    out = _run_oracle("phi3.5-moe-42b-a6.6b", "2x4", {"n_layers": 2})
    assert out["sharded"] == out["base"], out
    assert all(len(s) == 6 for s in out["base"])


def test_sharded_serve_matches_oracle_xlstm():
    """xlstm TP mesh (2x4): the slstm ``ffn_up`` two-way GLU split and the
    mlstm ``up`` split are segment-gated — their out dims replicate when the
    split segments don't land whole on shards — so the downstream
    ``jnp.split`` never slices through a sharded dim and the streams match
    the single-device oracle exactly."""
    out = _run_oracle("xlstm-125m", "2x4", {"n_layers": 2})
    assert out["sharded"] == out["base"], out
    assert all(len(s) == 6 for s in out["base"])


def test_sharded_serve_matches_oracle_ssm():
    """mamba2 (zamba2 backbone) TP mesh (2x4): three gates make the block
    exact — ``wx`` (feeds the causal-conv concat, sliced back after) is
    segment-gated, ``wz`` (elementwise gate projection) is replicated under
    partial replication (``_NO_TP_ROLES``), and the SSM state cache stays
    replicated — so streams match the single-device oracle exactly."""
    out = _run_oracle("zamba2-2.7b", "2x4", {"n_layers": 2, "attn_every": 1})
    assert out["sharded"] == out["base"], out
    assert all(len(s) == 6 for s in out["base"])
