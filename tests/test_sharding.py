"""Sharding-rule invariants for every arch on both production mesh shapes —
checked structurally (no 512-device compile; that's the dry-run's job)."""

import functools

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import AbstractMesh, PartitionSpec as P

from repro.configs.registry import ARCHS, input_specs
from repro.configs.shapes import cells_for
from repro.models.decode import quantize_for_serving
from repro.models.model import init_params
from repro.optim.optimizers import make_optimizer
from repro.parallel import sharding as sh

def _abstract_mesh(sizes, names):
    """AbstractMesh across JAX versions: new signature takes (name, size)
    pairs; pre-0.4.36 took (shape_tuple, axis_names)."""
    try:
        return AbstractMesh(tuple(zip(names, sizes)))
    except TypeError:
        return AbstractMesh(sizes, names)


MESHES = [_abstract_mesh((16, 16), ("data", "model")),
          _abstract_mesh((2, 16, 16), ("pod", "data", "model"))]


def _check_divisible(tree_sds, tree_specs, mesh):
    leaves = jax.tree.leaves(tree_sds)
    specs = jax.tree.leaves(tree_specs, is_leaf=lambda s: isinstance(s, P))
    assert len(leaves) == len(specs)
    sharded = 0
    for sds, spec in zip(leaves, specs):
        dims = list(spec) + [None] * (sds.ndim - len(spec))
        for size, axes in zip(sds.shape, dims):
            if axes is None:
                continue
            shards = 1
            for a in (axes if isinstance(axes, tuple) else (axes,)):
                shards *= mesh.shape[a]
            assert size % shards == 0, (sds.shape, spec)
            sharded += 1
    return sharded


@pytest.mark.parametrize("mesh", MESHES, ids=["16x16", "2x16x16"])
@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_param_specs_divisible(arch, mesh, key):
    cfg = ARCHS[arch]
    sds = jax.eval_shape(functools.partial(init_params, cfg), key)
    specs = sh.param_specs(sds, mesh)
    n = _check_divisible(sds, specs, mesh)
    assert n > 0, "no parameter ended up sharded"


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_packed_specs_divisible(arch, key):
    mesh = MESHES[0]
    cfg = ARCHS[arch]
    sds = jax.eval_shape(functools.partial(init_params, cfg), key)
    packed = jax.eval_shape(functools.partial(quantize_for_serving, cfg=cfg), sds)
    specs = sh.param_specs(packed, mesh)
    assert _check_divisible(packed, specs, mesh) > 0


@pytest.mark.parametrize("mesh", MESHES, ids=["16x16", "2x16x16"])
def test_cell_input_specs_divisible(mesh):
    for arch in sorted(ARCHS):
        if arch == "bitnet-b1.58-2b":
            continue
        for shape_name in cells_for(arch):
            cfg, shape, specs = input_specs(arch, shape_name)
            if shape.kind == "decode":
                _check_divisible(specs["cache"], sh.cache_specs(specs["cache"], mesh), mesh)
            else:
                _check_divisible(specs, sh.batch_specs(specs, mesh), mesh)


def test_opt_state_specs_divisible(key):
    mesh = MESHES[0]
    cfg = ARCHS["qwen2.5-14b"]
    sds = jax.eval_shape(functools.partial(init_params, cfg), key)
    pspecs = sh.param_specs(sds, mesh)
    for name in ("adamw", "adafactor"):
        opt = make_optimizer(name)
        state_sds = jax.eval_shape(opt.init, sds)
        sspecs = opt.state_specs(pspecs, sds)
        _check_divisible(state_sds, sspecs, mesh)


def test_batch_size_one_replicated():
    """long_500k (global_batch=1) must fall back to replication, not crash."""
    mesh = MESHES[0]
    specs = sh.batch_specs({"tokens": jax.ShapeDtypeStruct((1,), jnp.int32)}, mesh)
    assert specs["tokens"] == P(None)


def test_small_scale_jit_with_shardings(key):
    """End-to-end jit on a real 1-device mesh using the same sharding code
    path as the 512-chip dry-run."""
    from repro.configs.registry import get_smoke_config
    from repro.models.model import train_loss

    cfg = get_smoke_config("qwen3-0.6b")
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    params = init_params(cfg, key)
    psh = sh.to_shardings(sh.param_specs(params, mesh), mesh)
    batch = {"tokens": jnp.ones((2, 16), jnp.int32),
             "labels": jnp.ones((2, 16), jnp.int32),
             "loss_mask": jnp.ones((2, 16), jnp.float32)}
    bsh = sh.to_shardings(sh.batch_specs(batch, mesh), mesh)
    fn = jax.jit(lambda p, b: train_loss(p, cfg, b)[0],
                 in_shardings=(psh, bsh))
    with mesh:
        loss = fn(jax.device_put(params, psh), jax.device_put(batch, bsh))
    assert jnp.isfinite(loss)
