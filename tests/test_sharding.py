"""Sharding-rule invariants for every arch on both production mesh shapes —
checked structurally (no 512-device compile; that's the dry-run's job)."""

import functools

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import AbstractMesh, PartitionSpec as P

from repro.configs.registry import ARCHS, input_specs
from repro.configs.shapes import cells_for
from repro.models.decode import quantize_for_serving
from repro.models.model import init_params
from repro.optim.optimizers import make_optimizer
from repro.parallel import sharding as sh

def _abstract_mesh(sizes, names):
    """AbstractMesh across JAX versions: new signature takes (name, size)
    pairs; pre-0.4.36 took (shape_tuple, axis_names)."""
    try:
        return AbstractMesh(tuple(zip(names, sizes)))
    except TypeError:
        return AbstractMesh(sizes, names)


MESHES = [_abstract_mesh((16, 16), ("data", "model")),
          _abstract_mesh((2, 16, 16), ("pod", "data", "model"))]


def _check_divisible(tree_sds, tree_specs, mesh):
    leaves = jax.tree.leaves(tree_sds)
    specs = jax.tree.leaves(tree_specs, is_leaf=lambda s: isinstance(s, P))
    assert len(leaves) == len(specs)
    sharded = 0
    for sds, spec in zip(leaves, specs):
        dims = list(spec) + [None] * (sds.ndim - len(spec))
        for size, axes in zip(sds.shape, dims):
            if axes is None:
                continue
            shards = 1
            for a in (axes if isinstance(axes, tuple) else (axes,)):
                shards *= mesh.shape[a]
            assert size % shards == 0, (sds.shape, spec)
            sharded += 1
    return sharded


@pytest.mark.parametrize("mesh", MESHES, ids=["16x16", "2x16x16"])
@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_param_specs_divisible(arch, mesh, key):
    cfg = ARCHS[arch]
    sds = jax.eval_shape(functools.partial(init_params, cfg), key)
    specs = sh.param_specs(sds, mesh)
    n = _check_divisible(sds, specs, mesh)
    assert n > 0, "no parameter ended up sharded"


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_packed_specs_divisible(arch, key):
    mesh = MESHES[0]
    cfg = ARCHS[arch]
    sds = jax.eval_shape(functools.partial(init_params, cfg), key)
    packed = jax.eval_shape(functools.partial(quantize_for_serving, cfg=cfg), sds)
    specs = sh.param_specs(packed, mesh)
    assert _check_divisible(packed, specs, mesh) > 0


@pytest.mark.parametrize("mesh", MESHES, ids=["16x16", "2x16x16"])
def test_cell_input_specs_divisible(mesh):
    for arch in sorted(ARCHS):
        if arch == "bitnet-b1.58-2b":
            continue
        for shape_name in cells_for(arch):
            cfg, shape, specs = input_specs(arch, shape_name)
            if shape.kind == "decode":
                _check_divisible(specs["cache"], sh.cache_specs(specs["cache"], mesh), mesh)
            else:
                _check_divisible(specs, sh.batch_specs(specs, mesh), mesh)


def test_opt_state_specs_divisible(key):
    mesh = MESHES[0]
    cfg = ARCHS["qwen2.5-14b"]
    sds = jax.eval_shape(functools.partial(init_params, cfg), key)
    pspecs = sh.param_specs(sds, mesh)
    for name in ("adamw", "adafactor"):
        opt = make_optimizer(name)
        state_sds = jax.eval_shape(opt.init, sds)
        sspecs = opt.state_specs(pspecs, sds)
        _check_divisible(state_sds, sspecs, mesh)


def test_validate_rejects_overlong_spec():
    """A rule emitting more axes than the array has rank is a rule/shape
    mismatch — the regression was a silent truncation that sharded the wrong
    dims (or none)."""
    mesh = MESHES[0]
    with pytest.raises(ValueError, match="rank"):
        sh._validate(P(None, "model", None), (32, 64), mesh)
    # at-rank and under-rank specs still pass through (right-padded)
    assert sh._validate(P(None, "model"), (32, 64), mesh) == P(None, "model")
    assert sh._validate(P("data"), (32, 64), mesh) == P("data", None)


def test_param_specs_golden_packed_moe(key):
    """Golden specs over a packed MoE tree on a serving mesh (2 data × 4
    model) with head geometry: EP on data + TP inside each expert for the
    expert stacks, replicated router, whole-head-gated attention TP (MQA kv
    replicates: 1 head doesn't divide model=4)."""
    from repro.configs.registry import get_smoke_config

    mesh = _abstract_mesh((2, 4), ("data", "model"))
    cfg = get_smoke_config("phi3.5-moe-42b-a6.6b").with_(n_layers=2)
    assert cfg.n_heads % 4 == 0 and cfg.n_kv_heads == 1
    sds = jax.eval_shape(functools.partial(init_params, cfg), key)
    packed = jax.eval_shape(
        functools.partial(quantize_for_serving, cfg=cfg), sds)
    specs = sh.param_specs(packed, mesh,
                           heads={"wq": cfg.n_heads, "wk": cfg.n_kv_heads})
    blocks = specs["blocks"]
    # expert stacks [L, E, dout, din/5]: EP on data, wi/wg shard the out
    # (dout) dim, wo the contraction it packs (din → model)
    assert blocks["moe"]["wi"]["packed"] == P(None, "data", "model", None)
    assert blocks["moe"]["wg"]["packed"] == P(None, "data", "model", None)
    assert blocks["moe"]["wo"]["packed"] == P(None, "data", None, "model")
    # router weight [L, d_model, E] is NOT an expert stack: replicated
    # (the regression sharded its d_model dim via the expert rule)
    router = jax.tree.leaves(blocks["moe"]["router"],
                             is_leaf=lambda s: isinstance(s, P))
    assert all(all(a is None for a in s) for s in router), \
        blocks["moe"]["router"]
    # attention: wq shards whole heads (4 % 4 == 0); MQA k/v replicate
    assert blocks["attn"]["wq"]["packed"] == P(None, "model", None)
    assert all(a is None for a in blocks["attn"]["wk"]["packed"])
    assert all(a is None for a in blocks["attn"]["wv"]["packed"])
    # packed wo [L, dout, din/5] is column-parallel (dout): sharding the
    # packed byte dim breaks the base-3 unpack's logical-K slice
    assert blocks["attn"]["wo"]["packed"] == P(None, "model", None)


def test_cache_specs_kv_head_gated():
    """Serving KV cache with ``kv_heads``: shard the head dim (whole heads),
    falling back to replication when the head count doesn't divide model —
    never the intra-head hd dim."""
    mesh = _abstract_mesh((2, 4), ("data", "model"))
    kv = {"k": jax.ShapeDtypeStruct((2, 4, 64, 8, 32), jnp.bfloat16),
          "v": jax.ShapeDtypeStruct((2, 4, 64, 8, 32), jnp.bfloat16),
          "pos": jax.ShapeDtypeStruct((4,), jnp.int32)}
    ba = ("data",)
    specs = sh.cache_specs(kv, mesh, kv_heads=8)
    assert specs["k"] == P(None, ba, None, "model", None)
    assert specs["v"] == P(None, ba, None, "model", None)
    # MQA: 1 head can't split 4 ways — replicated, NOT silently hd-sharded
    mqa = {"k": jax.ShapeDtypeStruct((2, 4, 64, 1, 32), jnp.bfloat16)}
    assert sh.cache_specs(mqa, mesh, kv_heads=1)["k"] == \
        P(None, ba, None, None, None)
    # legacy (no kv_heads): hd-dim sharding as before
    assert sh.cache_specs(kv, mesh)["k"] == P(None, ba, None, None, "model")


def test_wz_partial_replication_gate():
    """wz (mamba2's elementwise gate projection) is TP'd only on a
    pure-model mesh; with a real batch axis alongside model it replicates
    (sharding._NO_TP_ROLES — CPU SPMD partial-replication miscompile)."""
    mixed = _abstract_mesh((2, 4), ("data", "model"))
    pure = _abstract_mesh((1, 8), ("data", "model"))
    path = ("blocks", "ssm", "wz", "w")
    assert sh._param_spec(path, 2, mixed) == P()
    assert sh._param_spec(path, 2, pure) == P(None, "model")


def test_batch_size_one_replicated():
    """long_500k (global_batch=1) must fall back to replication, not crash."""
    mesh = MESHES[0]
    specs = sh.batch_specs({"tokens": jax.ShapeDtypeStruct((1,), jnp.int32)}, mesh)
    assert specs["tokens"] == P(None)


def test_small_scale_jit_with_shardings(key):
    """End-to-end jit on a real 1-device mesh using the same sharding code
    path as the 512-chip dry-run."""
    from repro.configs.registry import get_smoke_config
    from repro.models.model import train_loss

    cfg = get_smoke_config("qwen3-0.6b")
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    params = init_params(cfg, key)
    psh = sh.to_shardings(sh.param_specs(params, mesh), mesh)
    batch = {"tokens": jnp.ones((2, 16), jnp.int32),
             "labels": jnp.ones((2, 16), jnp.int32),
             "loss_mask": jnp.ones((2, 16), jnp.float32)}
    bsh = sh.to_shardings(sh.batch_specs(batch, mesh), mesh)
    fn = jax.jit(lambda p, b: train_loss(p, cfg, b)[0],
                 in_shardings=(psh, bsh))
    with mesh:
        loss = fn(jax.device_put(params, psh), jax.device_put(batch, bsh))
    assert jnp.isfinite(loss)
