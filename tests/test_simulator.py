"""Functional datapath simulation: bit-exact vs matmul across design points."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.generator import LUTCoreConfig, generate
from repro.core.simulator import simulate_gemv, simulate_vs_reference


@settings(max_examples=12, deadline=None)
@given(st.integers(1, 4), st.integers(1, 5), st.integers(1, 5),
       st.integers(1, 30), st.integers(1, 40), st.integers(0, 2**31 - 1))
def test_simulator_bit_exact(mu, L, K, M, N, seed):
    rng = np.random.default_rng(seed)
    w = rng.integers(-1, 2, size=(M, N)).astype(np.int8)
    x = rng.integers(-100, 100, size=N).astype(np.int64)
    y, y_ref, stats = simulate_vs_reference(
        LUTCoreConfig(mu=mu, L=L, K=K, act_dtype="int8"), w, x)
    np.testing.assert_array_equal(y, y_ref)
    assert stats.muls_per_cycle <= mu * L * K + 1e-9


def test_simulator_float():
    rng = np.random.default_rng(0)
    w = rng.integers(-1, 2, size=(9, 21)).astype(np.int8)
    x = rng.normal(size=21).astype(np.float64)
    d = generate(LUTCoreConfig(mu=3, L=2, K=4, act_dtype="fp16"))
    y, stats = simulate_gemv(d, w, x)
    np.testing.assert_allclose(y, w.astype(np.float64) @ x, rtol=1e-9)


def test_throughput_schedule():
    """Eq. 1: steady-state throughput approaches n·m mul/cycle for large
    matrices (pipeline fill amortized)."""
    d = generate(LUTCoreConfig(mu=2, L=4, K=4, act_dtype="int8"))
    w = np.random.default_rng(0).integers(-1, 2, size=(64, 64)).astype(np.int8)
    x = np.arange(64).astype(np.int64)
    _, stats = simulate_gemv(d, w, x)
    frac = stats.muls_per_cycle / d.config.throughput_mul_per_cycle
    assert frac > 0.9
