"""Speculative decoding: draft-and-verify on the continuous scheduler.

Three layers of guarantee, each tested here:

1. **Bitwise verify (dense).**  On dense (window=0) caches ``verify_step``
   runs the scatter-first exact forward: its logits AND written KV are
   bitwise what K sequential ``decode_step`` calls produce.  No tolerance —
   ``==`` on every element, under both the reference and autotuned matmul
   policies.
2. **Exact rollback.**  ``snapshot_kv_window`` / ``rollback_kv_window``
   restore the rejected suffix of a speculative write exactly, so the cache
   after a partial acceptance equals the cache after the accepted tokens
   alone (the ring-wrap half of this property lives in
   ``test_window_decode.py``).
3. **Byte-identical streams.**  The speculative continuous engine emits the
   same greedy token streams as the non-speculative scheduler — for ANY
   draft sharing the target's vocab, accepted or not — when the baseline
   opts into the canonical bf16-argmax greedy selection
   (``SamplerConfig(canonical_greedy=True)``) the speculative round is
   defined over.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_smoke_config
from repro.models.decode import (decode_step, init_cache, prefill_into_slot,
                                 quantize_for_serving, rollback_kv_window,
                                 snapshot_kv_window, verify_step)
from repro.models.model import init_params
from repro.serving.engine import DecodeEngine, Request, SamplerConfig
from repro.serving.scheduler import ContinuousScheduler


def _dense_cfg(policy="fixed:ref", **over):
    return get_smoke_config("bitnet-b1.58-2b").with_(
        n_layers=3, d_model=64, n_heads=2, n_kv_heads=2, head_dim=32,
        d_ff=128, vocab_size=256, loss_chunk=32, matmul_policy=policy, **over)


def _ragged_prefill(p, cfg, B, CL, plens, rng):
    """A batch cache with per-row prompts of different lengths (the state a
    continuous scheduler actually verifies against)."""
    cache = init_cache(cfg, B, CL)
    for b in range(B):
        toks = jnp.asarray(rng.integers(2, cfg.vocab_size - 2,
                                        (1, plens[b])), jnp.int32)
        cache, _ = prefill_into_slot(p, cfg, cache, {"tokens": toks},
                                     b, int(plens[b]))
    return cache


# ---------------------------------------------------------------------------
# 1. dense verify is bitwise equal to sequential decode
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("policy", ["fixed:ref", "auto"])
def test_verify_step_bitwise_matches_sequential_dense(policy,
                                                      tmp_autotune_cache):
    """The load-bearing exactness claim: one batched K-candidate verify
    forward produces BITWISE the logits and cache (k, v, pos) of K
    sequential decode_step calls — per query the attended set, the
    online-softmax partition boundaries, and the reduction order are
    identical by construction, so there is nothing to be approximately
    equal about."""
    cfg = _dense_cfg(policy)
    assert not cfg.window
    p = quantize_for_serving(init_params(cfg, jax.random.PRNGKey(0)), cfg)
    B, CL, K = 2, 32, 4
    rng = np.random.default_rng(0)
    for trial in range(3):
        cache = _ragged_prefill(p, cfg, B, CL, [5, 9], rng)
        cands = jnp.asarray(rng.integers(2, cfg.vocab_size - 2, (B, K)),
                            jnp.int32)
        start = jnp.asarray([5, 9], jnp.int32)

        seq_cache, seq_logits = cache, []
        for j in range(K):
            logits, seq_cache = decode_step(p, cfg, seq_cache, cands[:, j],
                                            start + j)
            seq_logits.append(logits)
        seq_logits = jnp.stack(seq_logits, 1)

        vlogits, vcache = verify_step(p, cfg, cache, cands, start)
        np.testing.assert_array_equal(np.asarray(vlogits),
                                      np.asarray(seq_logits))
        for leaf in ("k", "v", "pos"):
            np.testing.assert_array_equal(
                np.asarray(vcache[leaf], np.float32),
                np.asarray(seq_cache[leaf], np.float32))


def test_verify_step_dead_row_writes_nothing(key):
    """A dead row (start = -1) must leave its cache row untouched — the
    whole-row guard matters because -1 + j is a REAL position for j >= 1."""
    cfg = _dense_cfg()
    p = quantize_for_serving(init_params(cfg, key), cfg)
    B, CL, K = 2, 32, 4
    rng = np.random.default_rng(1)
    cache = _ragged_prefill(p, cfg, B, CL, [5, 9], rng)
    cands = jnp.asarray(rng.integers(2, 200, (B, K)), jnp.int32)
    _, vcache = verify_step(p, cfg, cache, cands,
                            jnp.asarray([5, -1], jnp.int32))
    for leaf in ("k", "v", "pos"):
        np.testing.assert_array_equal(  # row 1 was dead: bit-identical
            np.asarray(vcache[leaf][:, 1], np.float32),
            np.asarray(cache[leaf][:, 1], np.float32))


# ---------------------------------------------------------------------------
# 2. snapshot/rollback exactness (dense; ring-wrap in test_window_decode.py)
# ---------------------------------------------------------------------------


def test_rollback_equals_sequential_prefix_dense(key):
    """After verify + rollback(keep), the cache is bitwise the cache after
    decoding only the first ``keep`` candidates — for every keep in 0..K,
    per row independently."""
    cfg = _dense_cfg()
    p = quantize_for_serving(init_params(cfg, key), cfg)
    B, CL, K = 2, 32, 4
    rng = np.random.default_rng(2)
    cache = _ragged_prefill(p, cfg, B, CL, [4, 11], rng)
    cands = jnp.asarray(rng.integers(2, 200, (B, K)), jnp.int32)
    start = jnp.asarray([4, 11], jnp.int32)
    undo = snapshot_kv_window(cfg, cache, start, K)
    _, vcache = verify_step(p, cfg, cache, cands, start)
    for keep in [(0, K), (K, 0), (1, 3), (2, 2)]:
        rolled = rollback_kv_window(cfg, vcache, undo,
                                    jnp.asarray(keep, jnp.int32))
        seq = cache
        for j in range(max(keep)):
            live = jnp.asarray([j < k for k in keep])
            tok = jnp.where(live, cands[:, j], 0)
            _, seq = decode_step(p, cfg, seq, tok,
                                 jnp.where(live, start + j, -1))
        for leaf in ("k", "v", "pos"):
            np.testing.assert_array_equal(
                np.asarray(rolled[leaf], np.float32),
                np.asarray(seq[leaf], np.float32), err_msg=f"keep={keep}")


# ---------------------------------------------------------------------------
# 3. engine: speculative streams are byte-identical to non-speculative
# ---------------------------------------------------------------------------


def _mk_draft(cfg, layers=1, key_seed=7):
    """A REAL mismatched draft: same vocab, fewer layers, different random
    params — most proposals get rejected, which is exactly the case the
    byte-identity guarantee has to survive."""
    dcfg = cfg.with_(n_layers=layers, name="qwen3-0.6b")
    dp = quantize_for_serving(
        init_params(dcfg, jax.random.PRNGKey(key_seed)), dcfg)
    return dp, dcfg


def _pinned_requests():
    rng = np.random.default_rng(3)
    specs = [(5, 12), (11, 7), (3, 20), (9, 9), (17, 5)]
    reqs = []
    for i, (plen, budget) in enumerate(specs):
        prompt = [int(t) for t in rng.integers(2, 250, plen)]
        stop = 5 if i == 2 else None  # one request stops on a token
        reqs.append(Request(prompt=prompt, max_new_tokens=budget,
                            stop_token=stop))
    return reqs


def _serve(engine, reqs):
    sched = ContinuousScheduler(engine)
    for r in reqs:
        sched.submit(r)
    sched.run(max_steps=500)
    return [list(r.out) for r in reqs], sched.stats


def _byte_identity_engines(key, window=0, spec_prefix_cache=False):
    """(baseline, speculative) engine pair; the baseline never has a prefix
    store, so the composition test pins spec+cache against the plain
    non-speculative engine directly."""
    cfg = _dense_cfg(window=window)
    p = quantize_for_serving(init_params(cfg, key), cfg)
    draft = _mk_draft(cfg)
    mk = lambda d: DecodeEngine(
        p, cfg, batch_size=2, max_len=48, prefill_chunk=8,
        matmul_policy="fixed:ref",
        sampler=SamplerConfig(canonical_greedy=True),
        prefix_cache=bool(d) and spec_prefix_cache,
        draft=d, spec_k=4 if d else 2)
    return mk(None), mk(draft)


@pytest.mark.parametrize("window", [0, 8], ids=["dense", "windowed"])
def test_spec_stream_byte_identical(key, window):
    """End to end: the speculative scheduler's greedy streams equal the
    non-speculative scheduler's byte for byte, with a low-acceptance
    mismatched draft, mixed prompt lengths/budgets, a stop token, and slot
    refills (5 requests through 2 slots).  The baseline engine opts into
    canonical greedy; on the dense config the verify forward is bitwise
    exact, on the windowed one the bf16 canonical grid absorbs the chunk
    partition noise."""
    base_eng, spec_eng = _byte_identity_engines(key, window=window)
    base, _ = _serve(base_eng, _pinned_requests())
    spec, stats = _serve(spec_eng, _pinned_requests())
    assert spec == base
    assert stats.spec_rounds > 0
    # every round drafts spec_k - 1 = 3 candidates per live slot
    assert 0 < stats.drafted_tokens
    assert 0 <= stats.accepted_drafted_tokens <= stats.drafted_tokens
    assert stats.emitted_tokens == sum(len(o) for o in spec)


def test_spec_twin_draft_accepts_everything(key):
    """A draft that IS the target (same params/config) must reach 100%
    acceptance — every round emits the full spec_k window (modulo stop and
    budget clipping) and decode_steps collapse accordingly."""
    cfg = _dense_cfg()
    p = quantize_for_serving(init_params(cfg, key), cfg)
    reqs = [Request(prompt=[7 + i, 13 + i, 5], max_new_tokens=12)
            for i in range(2)]
    eng = DecodeEngine(p, cfg, batch_size=2, max_len=48, prefill_chunk=8,
                       matmul_policy="fixed:ref", draft=(p, cfg), spec_k=4)
    out, stats = _serve(eng, reqs)
    assert stats.acceptance_rate == 1.0
    assert all(len(o) == 12 for o in out)
    # 24 tokens in ceil(12/4) = 3 rounds (both slots live throughout)
    assert stats.spec_rounds == 3
    base_eng = DecodeEngine(p, cfg, batch_size=2, max_len=48,
                            prefill_chunk=8, matmul_policy="fixed:ref",
                            sampler=SamplerConfig(canonical_greedy=True))
    base, _ = _serve(base_eng, [Request(prompt=[7 + i, 13 + i, 5],
                                        max_new_tokens=12)
                                for i in range(2)])
    assert out == base


def test_spec_composes_with_prefix_cache(key):
    """Prefix-cache splicing on the target + full draft prefill must yield
    the same byte-identical streams: a second wave sharing a long prefix
    hits the store, and the speculative warm-store output still equals the
    NO-cache non-speculative baseline's."""
    base_eng, spec_eng = _byte_identity_engines(key, spec_prefix_cache=True)

    def waves():
        shared = [int(t) for t in np.random.default_rng(9).integers(2, 250, 17)]
        w1 = [Request(prompt=shared + [30 + i], max_new_tokens=6)
              for i in range(2)]
        w2 = [Request(prompt=shared + [40 + i], max_new_tokens=6)
              for i in range(2)]
        return w1 + w2

    base, _ = _serve(base_eng, waves())
    spec, stats = _serve(spec_eng, waves())
    assert spec == base
    assert stats.spec_rounds > 0
    assert spec_eng.prefix_store.stats.hit_blocks > 0  # reuse actually fired


def test_dynamic_spec_k_stream_byte_identical(key):
    """Dynamic draft windows on the REAL engine: the low-acceptance
    mismatched draft forces windows to shrink, yet the emitted streams must
    still equal the non-speculative baseline byte for byte — window capping
    only rejects candidates earlier, it can never change which tokens the
    canonical greedy path emits.  Also checks the windows genuinely moved
    (spec_window_by_rid reached below the full spec_k) and that the charged
    draft count shrank accordingly."""
    base_eng, spec_eng = _byte_identity_engines(key)
    base, _ = _serve(base_eng, _pinned_requests())
    sched = ContinuousScheduler(spec_eng, dynamic_spec_k=True)
    reqs = _pinned_requests()
    for r in reqs:
        sched.submit(r)
    sched.run(max_steps=500)
    stats = sched.stats
    assert [list(r.out) for r in reqs] == base
    assert stats.spec_rounds > 0
    assert stats.spec_window_by_rid, "dynamic windows never recorded"
    assert all(2 <= w <= 4 for w in stats.spec_window_by_rid.values())
    # the mismatched draft rejects most candidates, so some request must
    # have shrunk below the full window...
    assert min(stats.spec_window_by_rid.values()) < 4
    # ...and the accounting charges the shrunken windows, not K - 1 per
    # slot per round (strictly fewer drafts than the fixed-K run would)
    assert stats.drafted_tokens < stats.spec_rounds * 2 * 3


def test_spec_window_caps_acceptance(key):
    """sched_spec_step(window=...): a window of 2 everywhere bounds n_acc
    by 2 even where the full-K round would accept more (twin draft: 100%
    acceptance), and window=spec_k reproduces the unwindowed round."""
    cfg = _dense_cfg()
    p = quantize_for_serving(init_params(cfg, key), cfg)

    def mk():
        return DecodeEngine(p, cfg, batch_size=2, max_len=48,
                            prefill_chunk=8, matmul_policy="fixed:ref",
                            draft=(p, cfg), spec_k=4)

    def admit(eng):
        state = eng.sched_start()
        for slot in range(2):
            state = eng.sched_admit(state, slot, Request(
                prompt=[7 + slot, 13 + slot, 5], max_new_tokens=12))
        return state

    eng = mk()
    _, _, full_acc, _, _ = eng.sched_spec_step(admit(eng))
    assert list(full_acc) == [4, 4]  # twin draft: full window accepted
    eng2 = mk()
    _, _, capped, _, _ = eng2.sched_spec_step(admit(eng2), window=[2, 3])
    assert list(capped) == [2, 3], "window must cap the accepted prefix"
    eng3 = mk()
    _, _, explicit, _, _ = eng3.sched_spec_step(admit(eng3), window=[4, 4])
    assert list(explicit) == list(full_acc)
    with pytest.raises(ValueError, match="window"):
        eng3.sched_spec_step(eng3.sched_start(), window=[2])


def test_spec_per_request_acceptance_accounting(key):
    """stats.accepted_by_rid: keyed on stable Request.rid, one entry per
    admitted request, values summing to the global accepted count."""
    cfg = _dense_cfg()
    p = quantize_for_serving(init_params(cfg, key), cfg)
    eng = DecodeEngine(p, cfg, batch_size=2, max_len=48, prefill_chunk=8,
                       matmul_policy="fixed:ref", draft=(p, cfg), spec_k=3)
    reqs = [Request(prompt=[3 + i, 4], max_new_tokens=6) for i in range(3)]
    _, stats = _serve(eng, reqs)
    assert set(stats.accepted_by_rid) == {r.rid for r in reqs}
    assert sum(stats.accepted_by_rid.values()) == \
        stats.accepted_drafted_tokens
    assert len({r.rid for r in reqs}) == 3  # rids are distinct and stable


def test_spec_compiles_one_trace_per_entry(key):
    """The speculative path must stay as trace-frugal as the plain one: one
    spec_step trace, one draft prefill bucket, one admit commit — across a
    mixed-length request stream."""
    cfg = _dense_cfg()
    p = quantize_for_serving(init_params(cfg, key), cfg)
    eng = DecodeEngine(p, cfg, batch_size=2, max_len=48, prefill_chunk=4,
                       matmul_policy="fixed:ref", draft=_mk_draft(cfg),
                       spec_k=3)
    reqs = [Request(prompt=[2 + j for j in range(1 + i)], max_new_tokens=3)
            for i in range(6)]  # prompt lengths 1..6: 1- and 2-chunk buckets
    _serve(eng, reqs)
    assert eng.trace_counts["spec_step"] == 1, eng.trace_counts
    assert eng.trace_counts["prefill_chunk"] == 1, eng.trace_counts
    assert eng.trace_counts["draft_prefill_chunk"] == 1, eng.trace_counts
    assert eng.trace_counts["admit_commit"] == 1, eng.trace_counts
    assert eng.trace_counts["sched_step"] == 0, eng.trace_counts


# ---------------------------------------------------------------------------
# constructor validation
# ---------------------------------------------------------------------------


def test_draft_vocab_mismatch_rejected(key):
    cfg = _dense_cfg()
    p = quantize_for_serving(init_params(cfg, key), cfg)
    dcfg = cfg.with_(n_layers=1, vocab_size=128, name="qwen3-0.6b")
    dp = quantize_for_serving(init_params(dcfg, key), dcfg)
    with pytest.raises(ValueError, match="tokenizer mismatch"):
        DecodeEngine(p, cfg, batch_size=2, max_len=48, draft=(dp, dcfg))


def test_draft_requires_greedy(key):
    cfg = _dense_cfg()
    p = quantize_for_serving(init_params(cfg, key), cfg)
    with pytest.raises(ValueError, match="temperature"):
        DecodeEngine(p, cfg, batch_size=2, max_len=48, draft=(p, cfg),
                     sampler=SamplerConfig(temperature=0.7))


def test_spec_k_bounds_enforced(key):
    cfg = _dense_cfg()
    p = quantize_for_serving(init_params(cfg, key), cfg)
    with pytest.raises(ValueError, match="spec_k"):
        DecodeEngine(p, cfg, batch_size=2, max_len=48, draft=(p, cfg),
                     spec_k=1)
    wcfg = cfg.with_(window=8)
    wp = quantize_for_serving(init_params(wcfg, key), wcfg)
    with pytest.raises(ValueError, match="ring length"):
        DecodeEngine(wp, wcfg, batch_size=2, max_len=48, draft=(wp, wcfg),
                     spec_k=9)  # > CL=8: the verify window would self-collide


def test_draft_arch_must_support_batched_verify(key):
    cfg = _dense_cfg()
    p = quantize_for_serving(init_params(cfg, key), cfg)
    zcfg = get_smoke_config("zamba2-2.7b").with_(remat=False,
                                                 vocab_size=cfg.vocab_size)
    zp = quantize_for_serving(init_params(zcfg, key), zcfg)
    with pytest.raises(ValueError, match="does not support"):
        DecodeEngine(p, cfg, batch_size=2, max_len=48, draft=(zp, zcfg))
