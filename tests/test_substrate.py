"""Substrate tests: data pipeline, optimizers, gradient compression,
checkpointing (atomicity / crc / resume), serving engine."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.checkpoint import checkpointing as ckpt
from repro.data.pipeline import DataConfig, SyntheticLMStream
from repro.optim import compression
from repro.optim.optimizers import (
    adafactor,
    adamw,
    clip_by_global_norm,
    cosine_schedule,
    make_optimizer,
)

# ---------------------------------------------------------------------------
# data
# ---------------------------------------------------------------------------


def test_data_deterministic():
    cfg = DataConfig(vocab_size=128, seq_len=32, global_batch=4)
    a = SyntheticLMStream(cfg).batch(7)
    b = SyntheticLMStream(cfg).batch(7)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    assert a["tokens"].shape == (4, 32)
    assert (a["labels"][:, :-1] == a["tokens"][:, 1:]).all()


def test_data_host_sharding_partitions_global_batch():
    cfg = DataConfig(vocab_size=128, seq_len=16, global_batch=8)
    whole = SyntheticLMStream(cfg, host_id=0, n_hosts=1).batch(3)
    parts = [SyntheticLMStream(cfg, host_id=h, n_hosts=4).batch(3)
             for h in range(4)]
    np.testing.assert_array_equal(
        np.concatenate([p["tokens"] for p in parts]), whole["tokens"])


def test_data_masks_eod():
    cfg = DataConfig(vocab_size=64, seq_len=512, global_batch=1, mean_doc_len=32)
    b = SyntheticLMStream(cfg).batch(0)
    assert (b["loss_mask"] == (b["labels"] != 0)).all()
    assert 0 < b["loss_mask"].mean() <= 1


# ---------------------------------------------------------------------------
# optimizers
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", ["adamw", "adafactor"])
def test_optimizer_decreases_quadratic(name):
    opt = make_optimizer(name, base_lr=0.1, warmup=1, total=100)
    params = {"w": jnp.asarray(np.random.default_rng(0).normal(size=(8, 4)),
                               jnp.float32)}
    state = opt.init(params)
    loss = lambda p: jnp.sum(p["w"] ** 2)
    l0 = float(loss(params))
    for step in range(30):
        grads = jax.grad(loss)(params)
        params, state = opt.update(state, grads, params, jnp.asarray(step))
    assert float(loss(params)) < 0.5 * l0


def test_adafactor_state_is_factored():
    opt = make_optimizer("adafactor")
    params = {"m": jnp.zeros((64, 32)), "v": jnp.zeros((7,))}
    state = opt.init(params)
    assert state["s"]["m"]["vr"].shape == (64,)
    assert state["s"]["m"]["vc"].shape == (32,)
    assert state["s"]["v"]["v"].shape == (7,)


def test_state_specs_match_state_structure():
    from jax.sharding import PartitionSpec as P
    for name in ("adamw", "adafactor"):
        opt = make_optimizer(name)
        params = {"a": jnp.zeros((8, 4)), "b": jnp.zeros((3,))}
        sds = jax.eval_shape(lambda: params)
        specs = opt.state_specs({"a": P(None, "model"), "b": P()}, sds)
        state = jax.eval_shape(opt.init, sds)
        jax.tree.map(lambda s, x: None, specs, state,
                     is_leaf=lambda x: isinstance(x, P))  # same treedef


def test_clip_by_global_norm():
    g = {"w": jnp.full((10,), 10.0)}
    clipped, n = clip_by_global_norm(g, 1.0)
    assert float(n) == pytest.approx(np.sqrt(1000), rel=1e-5)
    assert float(jnp.linalg.norm(clipped["w"])) == pytest.approx(1.0, rel=1e-4)


def test_cosine_schedule_shape():
    lr = cosine_schedule(1.0, warmup=10, total=100)
    assert float(lr(jnp.asarray(0))) < float(lr(jnp.asarray(9)))
    assert float(lr(jnp.asarray(10))) == pytest.approx(1.0, rel=1e-3)
    assert float(lr(jnp.asarray(99))) < 0.01


# ---------------------------------------------------------------------------
# gradient compression
# ---------------------------------------------------------------------------


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_compression_error_feedback_telescopes(seed):
    """Σ dequantized_k = Σ g_k − err_K: error feedback is unbiased over time."""
    rng = np.random.default_rng(seed)
    g_sum = np.zeros((16,), np.float32)
    d_sum = np.zeros((16,), np.float32)
    err = {"w": jnp.zeros((16,), jnp.float32)}
    for _ in range(8):
        g = rng.normal(size=(16,)).astype(np.float32)
        deq, err = compression.roundtrip({"w": jnp.asarray(g)}, err)
        g_sum += g
        d_sum += np.asarray(deq["w"])
    resid = np.abs(g_sum - d_sum)
    # residual is exactly the carried error, bounded by one quantization step
    np.testing.assert_allclose(resid, np.abs(np.asarray(err["w"])), atol=1e-5)


def test_compression_payload_is_int8():
    g = {"w": jnp.asarray(np.random.default_rng(0).normal(size=(32,)), jnp.float32)}
    (q, s), _ = compression.compress_grads(g, compression.init_error_state(g))
    assert np.asarray(q["w"]).dtype == np.int8


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {"a": jnp.asarray(rng.normal(size=(8, 8)), jnp.float32),
            "b": {"c": jnp.asarray(rng.integers(0, 5, size=(3,)), jnp.int32)}}


def test_checkpoint_roundtrip(tmp_path):
    t = _tree()
    ckpt.save(str(tmp_path), 5, t)
    assert ckpt.latest_step(str(tmp_path)) == 5
    r = ckpt.restore(str(tmp_path), 5, jax.eval_shape(lambda: t))
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(np.asarray(a), b), t, r)


def test_checkpoint_uncommitted_ignored(tmp_path):
    t = _tree()
    d = ckpt.save(str(tmp_path), 3, t)
    os.remove(os.path.join(d, "_COMMITTED"))
    assert ckpt.latest_step(str(tmp_path)) is None


def test_checkpoint_crc_validation(tmp_path):
    t = _tree()
    d = ckpt.save(str(tmp_path), 1, t)
    # corrupt a shard
    shard = os.path.join(d, "shard_00000.npz")
    data = dict(np.load(shard))
    data["leaf_000000"] = data["leaf_000000"] + 1
    np.savez(shard, **data)
    with pytest.raises(IOError):
        ckpt.restore(str(tmp_path), 1, jax.eval_shape(lambda: t))


def test_checkpoint_async_and_latest(tmp_path):
    for step in (2, 7, 4):
        ckpt.save_async(str(tmp_path), step, _tree(step))
    ckpt.wait()
    assert ckpt.latest_step(str(tmp_path)) == 7
    s, r = ckpt.restore_latest(str(tmp_path), jax.eval_shape(lambda: _tree()))
    assert s == 7
    np.testing.assert_array_equal(np.asarray(r["a"]), np.asarray(_tree(7)["a"]))


# ---------------------------------------------------------------------------
# serving engine
# ---------------------------------------------------------------------------


def test_decode_engine_generates(key):
    from repro.configs.registry import get_smoke_config
    from repro.models.decode import quantize_for_serving
    from repro.models.model import init_params
    from repro.serving.engine import DecodeEngine, Request, SamplerConfig

    cfg = get_smoke_config("qwen3-0.6b")
    sp = quantize_for_serving(init_params(cfg, key), cfg)
    eng = DecodeEngine(sp, cfg, batch_size=2, max_len=64,
                       sampler=SamplerConfig(temperature=0.0))
    reqs = [Request(prompt=[5, 6, 7], max_new_tokens=6),
            Request(prompt=[9, 2], max_new_tokens=4, stop_token=None)]
    out = eng.run(reqs)
    assert len(out[0].out) == 6 and len(out[1].out) == 4
    assert all(0 <= t < cfg.vocab_size for t in out[0].out)


def test_decode_engine_sampling_temperature(key):
    from repro.serving.engine import SamplerConfig, sample_tokens
    logits = jnp.asarray(np.random.default_rng(0).normal(size=(4, 50)), jnp.float32)
    greedy = sample_tokens(logits, SamplerConfig(temperature=0.0), key)
    np.testing.assert_array_equal(np.asarray(greedy),
                                  np.argmax(np.asarray(logits), -1))
    hot = sample_tokens(logits, SamplerConfig(temperature=2.0, top_k=5), key)
    assert hot.shape == (4,)
