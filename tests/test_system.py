"""End-to-end system behaviour: the fault-tolerant train loop (losses
decrease, checkpoint/restart resumes bit-continuously), and the full
train → quantize → serve lifecycle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_smoke_config
from repro.launch.train import train
from repro.models.decode import quantize_for_serving
from repro.serving.engine import DecodeEngine, Request


def _mesh():
    return jax.make_mesh((1, 1), ("data", "model"))


def test_train_loss_decreases():
    cfg = get_smoke_config("bitnet-b1.58-2b").with_(
        n_layers=2, d_model=64, n_heads=2, n_kv_heads=2, head_dim=32,
        d_ff=128, vocab_size=256, loss_chunk=32)
    out = train(cfg, steps=25, global_batch=4, seq_len=64, mesh=_mesh(),
                lr=3e-3, log_every=100)
    hist = out["history"]
    assert out["exit"] == "done"
    assert np.mean(hist[-5:]) < np.mean(hist[:5]) - 0.1, hist


def test_checkpoint_restart_resumes(tmp_path):
    cfg = get_smoke_config("qwen3-0.6b").with_(
        n_layers=2, d_model=64, n_heads=2, n_kv_heads=2, head_dim=32,
        d_ff=128, vocab_size=256, loss_chunk=32)
    kw = dict(global_batch=4, seq_len=32, mesh=_mesh(),
              ckpt_dir=str(tmp_path), checkpoint_every=5, log_every=100)
    # run 10 steps straight through
    full = train(cfg, steps=10, **kw)
    # fresh dir: run 5, "crash", resume to 10
    import shutil
    shutil.rmtree(tmp_path)
    train(cfg, steps=5, **kw)
    resumed = train(cfg, steps=10, **kw)
    # deterministic data + restored state ⇒ identical trailing losses
    np.testing.assert_allclose(resumed["history"][-3:], full["history"][-3:],
                               rtol=1e-4, atol=1e-4)


def test_full_lifecycle_train_quantize_serve(key):
    cfg = get_smoke_config("bitnet-b1.58-2b").with_(
        n_layers=2, d_model=64, n_heads=2, n_kv_heads=2, head_dim=32,
        d_ff=128, vocab_size=256, loss_chunk=32)
    out = train(cfg, steps=5, global_batch=4, seq_len=32, mesh=_mesh(),
                log_every=100)
    sp = quantize_for_serving(out["params"], cfg)
    eng = DecodeEngine(sp, cfg, batch_size=2, max_len=48)
    reqs = eng.run([Request(prompt=[3, 4, 5], max_new_tokens=5)])
    assert len(reqs[0].out) == 5


def test_grad_compression_path_trains():
    cfg = get_smoke_config("qwen3-0.6b").with_(
        n_layers=2, d_model=64, n_heads=2, n_kv_heads=2, head_dim=32,
        d_ff=128, vocab_size=256, loss_chunk=32)
    out = train(cfg, steps=10, global_batch=4, seq_len=32, mesh=_mesh(),
                compress_grads=True, lr=3e-3, log_every=100)
    assert out["exit"] == "done"
    assert np.isfinite(out["history"]).all()
