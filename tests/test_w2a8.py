"""W1.58A8 kernel (ternary weights × INT8 activations, int32 accumulation):
the paper's Table-I BitNet b1.58 operating point."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import encoding
from repro.core.quantization import quantize_activations_int8, ternarize
from repro.kernels.w2a8_matmul import w2a8_linear, w2a8_matmul


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 6), st.integers(1, 24), st.integers(1, 50),
       st.integers(0, 2**31 - 1))
def test_w2a8_exact_int32(B, O, N, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.integers(-127, 128, size=(B, N)), jnp.int8)
    w = jnp.asarray(rng.integers(-1, 2, size=(O, N)), jnp.int8)
    y = w2a8_matmul(x, encoding.pack_base3(w), N, block_b=2, block_o=8, block_n=20)
    ref = np.asarray(x, np.int64) @ np.asarray(w, np.int64).T
    np.testing.assert_array_equal(np.asarray(y, np.int64), ref)  # bit exact


def test_w2a8_linear_rescale():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(3, 40)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(16, 40)), jnp.float32)
    w_t, w_scale = ternarize(w)
    y = w2a8_linear(x, encoding.pack_base3(w_t), w_scale, 40)
    # reference: fake-quant both sides in fp
    x_q, x_scale = quantize_activations_int8(x)
    ref = (np.asarray(x_q, np.float32) * np.asarray(x_scale)) @ \
        (np.asarray(w_t, np.float32) * float(w_scale)).T
    np.testing.assert_allclose(np.asarray(y), ref, rtol=1e-4, atol=1e-4)


def test_w2a8_activation_bytes_halved():
    """The W2A8 path streams half the activation bytes of bf16."""
    x = jnp.zeros((8, 1024), jnp.bfloat16)
    x_q, _ = quantize_activations_int8(x.astype(jnp.float32))
    assert x_q.dtype == jnp.int8 and x_q.nbytes * 2 == x.nbytes
