"""Ring-buffer windowed decode: correctness across cache wraparound —
the long_500k execution mode (zamba2's shared attention at 4k window)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_smoke_config
from repro.models.decode import cache_len, decode_step, init_cache, prefill, quantize_for_serving
from repro.models.model import init_params


def test_window_cache_is_ring_sized():
    cfg = get_smoke_config("zamba2-2.7b").with_(window=8)
    assert cache_len(cfg, 1000) == 8
    cache = init_cache(cfg, 2, 1000)
    assert cache["k"].shape[2] == 8


def test_decode_through_wraparound():
    """Decode far past the window; positions and outputs must stay finite and
    the ring must contain exactly the last `window` absolute positions."""
    cfg = get_smoke_config("zamba2-2.7b").with_(window=8, remat=False)
    key = jax.random.PRNGKey(0)
    sp = quantize_for_serving(init_params(cfg, key), cfg)
    B, S = 2, 6
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(1, cfg.vocab_size, size=(B, S)), jnp.int32)
    cache, _ = prefill(sp, cfg, {"tokens": toks}, s_max=64)
    for t in range(S, S + 14):  # writes wrap the 8-slot ring
        logits, cache = decode_step(sp, cfg, cache,
                                    jnp.zeros((B,), jnp.int32) + (t % 17) + 1,
                                    jnp.asarray(t, jnp.int32))
        assert np.isfinite(np.asarray(logits)).all(), t
    for b in range(B):  # pos is per-row ([n, B, CL]) since per-slot decode
        pos = np.sort(np.asarray(cache["pos"][0, b]))
        want = np.arange(S + 14 - 8, S + 14)
        np.testing.assert_array_equal(pos, want)


def test_per_slot_decode_wraps_ring_independently():
    """With a per-slot position vector, each batch row wraps the ring on its
    own schedule: after enough steps every row holds exactly the last
    `window` absolute positions *of its own trajectory*."""
    cfg = get_smoke_config("zamba2-2.7b").with_(window=8, remat=False)
    sp = quantize_for_serving(init_params(cfg, jax.random.PRNGKey(2)), cfg)
    B, S = 2, 6
    rng = np.random.default_rng(2)
    toks = jnp.asarray(rng.integers(1, cfg.vocab_size, size=(B, S)), jnp.int32)
    cache, _ = prefill(sp, cfg, {"tokens": toks}, s_max=64)
    idx = jnp.asarray([S, S + 3], jnp.int32)  # row 1 decodes 3 positions ahead
    for t in range(12):  # ≥ window consecutive writes per row
        logits, cache = decode_step(sp, cfg, cache,
                                    jnp.full((B,), (t % 13) + 1, jnp.int32), idx)
        assert np.isfinite(np.asarray(logits)).all(), t
        idx = idx + 1
    for b, last in enumerate(np.asarray(idx) - 1):
        pos = np.sort(np.asarray(cache["pos"][0, b]))
        np.testing.assert_array_equal(pos, np.arange(last - 7, last + 1))


def test_windowed_decode_matches_windowed_forward():
    """Teacher-forced windowed forward vs prefill+decode at the same window."""
    cfg = get_smoke_config("zamba2-2.7b").with_(window=8, remat=False)
    key = jax.random.PRNGKey(1)
    sp = quantize_for_serving(init_params(cfg, key), cfg)
    B, S = 2, 12
    rng = np.random.default_rng(1)
    toks = jnp.asarray(rng.integers(1, cfg.vocab_size, size=(B, S + 1)), jnp.int32)
    _, logits_long = prefill(sp, cfg, {"tokens": toks}, s_max=S + 1)
    cache, _ = prefill(sp, cfg, {"tokens": toks[:, :S]}, s_max=S + 1)
    logits_step, _ = decode_step(sp, cfg, cache, toks[:, S], jnp.asarray(S, jnp.int32))
    a = np.asarray(logits_long, np.float32)
    b = np.asarray(logits_step, np.float32)
    m = np.abs(a) < 1e29
    corr = np.corrcoef(a[m].ravel(), b[m].ravel())[0, 1]
    assert corr > 0.99, corr
