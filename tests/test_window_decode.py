"""Ring-buffer windowed decode: correctness across cache wraparound —
the long_500k execution mode (zamba2's shared attention at 4k window)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_smoke_config
from repro.models.decode import (cache_len, decode_step, init_cache, prefill,
                                 quantize_for_serving, rollback_kv_window,
                                 snapshot_kv_window, verify_step)
from repro.models.model import init_params


def _assert_ring_occupancy(cache):
    """The canonical slot invariant: slot ``s`` holds position ``p`` ⇒
    ``p % CL == s`` (for every batch row; -1 = empty slot).  Fails on the
    pre-fix layout, where windowed prefill parked the last CL positions at
    slots 0..CL-1 regardless of their absolute position."""
    pos = np.asarray(cache["pos"][0])  # [B, CL]
    CL = pos.shape[-1]
    for b in range(pos.shape[0]):
        for s, p in enumerate(pos[b]):
            assert p < 0 or p % CL == s, (
                f"row {b}: slot {s} holds position {p} (canonical slot "
                f"{p % CL}) — ring misaligned")


def test_nonwindowed_prefill_overlong_raises():
    """Without a sliding window the cache must hold the whole prompt:
    prefill used to silently keep only the last ``s_max`` keys (truncation
    inside ``_pad_kv_to``), changing what decode attends to.  Now it raises
    at the source instead of relying on each caller's guard."""
    cfg = get_smoke_config("bitnet-b1.58-2b").with_(
        n_layers=2, d_model=64, n_heads=2, n_kv_heads=2, head_dim=32,
        d_ff=128, vocab_size=256, loss_chunk=32, remat=False)
    assert not cfg.window
    sp = quantize_for_serving(init_params(cfg, jax.random.PRNGKey(0)), cfg)
    toks = jnp.ones((1, 12), jnp.int32)
    with pytest.raises(ValueError, match="exceeds cache length"):
        prefill(sp, cfg, {"tokens": toks}, s_max=8)
    # windowed configs legitimately keep a ring smaller than the prompt
    cfgw = get_smoke_config("zamba2-2.7b").with_(window=8, remat=False)
    spw = quantize_for_serving(init_params(cfgw, jax.random.PRNGKey(0)), cfgw)
    cache, logits = prefill(spw, cfgw, {"tokens": toks}, s_max=8)
    assert np.isfinite(np.asarray(logits)).all()


def test_window_cache_is_ring_sized():
    cfg = get_smoke_config("zamba2-2.7b").with_(window=8)
    assert cache_len(cfg, 1000) == 8
    cache = init_cache(cfg, 2, 1000)
    assert cache["k"].shape[2] == 8


def test_decode_through_wraparound():
    """Decode far past the window; positions and outputs must stay finite and
    the ring must contain exactly the last `window` absolute positions."""
    cfg = get_smoke_config("zamba2-2.7b").with_(window=8, remat=False)
    key = jax.random.PRNGKey(0)
    sp = quantize_for_serving(init_params(cfg, key), cfg)
    B, S = 2, 6
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(1, cfg.vocab_size, size=(B, S)), jnp.int32)
    cache, _ = prefill(sp, cfg, {"tokens": toks}, s_max=64)
    for t in range(S, S + 14):  # writes wrap the 8-slot ring
        logits, cache = decode_step(sp, cfg, cache,
                                    jnp.zeros((B,), jnp.int32) + (t % 17) + 1,
                                    jnp.asarray(t, jnp.int32))
        assert np.isfinite(np.asarray(logits)).all(), t
        _assert_ring_occupancy(cache)
    for b in range(B):  # pos is per-row ([n, B, CL]) since per-slot decode
        pos = np.sort(np.asarray(cache["pos"][0, b]))
        want = np.arange(S + 14 - 8, S + 14)
        np.testing.assert_array_equal(pos, want)


def test_per_slot_decode_wraps_ring_independently():
    """With a per-slot position vector, each batch row wraps the ring on its
    own schedule: after enough steps every row holds exactly the last
    `window` absolute positions *of its own trajectory*."""
    cfg = get_smoke_config("zamba2-2.7b").with_(window=8, remat=False)
    sp = quantize_for_serving(init_params(cfg, jax.random.PRNGKey(2)), cfg)
    B, S = 2, 6
    rng = np.random.default_rng(2)
    toks = jnp.asarray(rng.integers(1, cfg.vocab_size, size=(B, S)), jnp.int32)
    cache, _ = prefill(sp, cfg, {"tokens": toks}, s_max=64)
    idx = jnp.asarray([S, S + 3], jnp.int32)  # row 1 decodes 3 positions ahead
    for t in range(12):  # ≥ window consecutive writes per row
        logits, cache = decode_step(sp, cfg, cache,
                                    jnp.full((B,), (t % 13) + 1, jnp.int32), idx)
        assert np.isfinite(np.asarray(logits)).all(), t
        idx = idx + 1
    for b, last in enumerate(np.asarray(idx) - 1):
        pos = np.sort(np.asarray(cache["pos"][0, b]))
        np.testing.assert_array_equal(pos, np.arange(last - 7, last + 1))


def test_windowed_prefill_ring_occupancy():
    """A prompt with S >= CL wraps the ring at prefill time: every kept key
    must land at its canonical slot ``p % CL``, so the first post-prefill
    decode write (at ``index % CL``) evicts exactly the oldest in-window
    position.  The pre-fix layout parked positions S-CL..S-1 at slots
    0..CL-1, so this fails before the fix."""
    cfg = get_smoke_config("zamba2-2.7b").with_(window=8, remat=False)
    sp = quantize_for_serving(init_params(cfg, jax.random.PRNGKey(3)), cfg)
    B, S = 2, 12  # S >= CL=8 → prefill wraps the ring
    rng = np.random.default_rng(3)
    toks = jnp.asarray(rng.integers(1, cfg.vocab_size, size=(B, S)), jnp.int32)
    cache, _ = prefill(sp, cfg, {"tokens": toks}, s_max=64)
    _assert_ring_occupancy(cache)
    # the ring holds exactly the last CL positions
    for b in range(B):
        np.testing.assert_array_equal(np.sort(np.asarray(cache["pos"][0, b])),
                                      np.arange(S - 8, S))
    # ...and stays canonical through the first post-prefill writes (the
    # window used to lose one attended key per step right here)
    for t in range(S, S + 3):
        _, cache = decode_step(sp, cfg, cache,
                               jnp.full((B,), 5, jnp.int32),
                               jnp.asarray(t, jnp.int32))
        _assert_ring_occupancy(cache)
        for b in range(B):
            np.testing.assert_array_equal(
                np.sort(np.asarray(cache["pos"][0, b])),
                np.arange(t - 7, t + 1))


@pytest.mark.parametrize("S", [5, 12], ids=["pre-wrap", "wrapped"])
def test_verify_rollback_restores_ring_across_wrap(S):
    """The speculative undo property ON THE RING: a K-token verify window
    that wraps the 8-slot ring evicts in-window keys; ``snapshot_kv_window``
    → ``verify_step`` → ``rollback_kv_window(keep)`` must restore every
    rejected slot's KV *and* position bit-for-bit — including the evicted
    old positions and ``-1`` empties — while keeping the accepted prefix and
    the canonical slot invariant.  ``keep=0`` is full undo: the cache must
    equal the pre-verify cache exactly."""
    cfg = get_smoke_config("bitnet-b1.58-2b").with_(
        n_layers=2, d_model=64, n_heads=2, n_kv_heads=2, head_dim=32,
        d_ff=128, vocab_size=256, loss_chunk=32, window=8, remat=False)
    sp = quantize_for_serving(init_params(cfg, jax.random.PRNGKey(4)), cfg)
    B, K = 2, 4
    rng = np.random.default_rng(4)
    toks = jnp.asarray(rng.integers(1, cfg.vocab_size, size=(B, S)), jnp.int32)
    cache, _ = prefill(sp, cfg, {"tokens": toks}, s_max=64)
    cands = jnp.asarray(rng.integers(1, cfg.vocab_size, (B, K)), jnp.int32)
    start = jnp.full((B,), S, jnp.int32)  # verify window: S..S+3 (wraps CL=8)

    undo = snapshot_kv_window(cfg, cache, start, K)
    _, vcache = verify_step(sp, cfg, cache, cands, start)
    _assert_ring_occupancy(vcache)

    for keep in range(K + 1):
        rolled = rollback_kv_window(cfg, vcache, undo,
                                    jnp.full((B,), keep, jnp.int32))
        _assert_ring_occupancy(rolled)
        pos = np.asarray(cache["pos"])  # pre-verify positions [L, B, CL]
        vpos = np.asarray(vcache["pos"])
        slots = np.asarray(undo["slot"])  # [B, K]
        for leaf in ("k", "v", "pos"):
            got = np.asarray(rolled[leaf], np.float32)
            pre = np.asarray(cache[leaf], np.float32)
            post = np.asarray(vcache[leaf], np.float32)
            for b in range(B):
                kept = set(slots[b, :keep].tolist())
                for s in range(8):
                    want = post if s in kept else pre
                    np.testing.assert_array_equal(
                        got[:, b, s], want[:, b, s],
                        err_msg=f"keep={keep} leaf={leaf} row={b} slot={s}")
        # rolled positions: accepted prefix advanced, suffix restored
        rpos = np.asarray(rolled["pos"])
        for b in range(B):
            for j in range(K):
                s = slots[b, j]
                assert rpos[0, b, s] == (vpos if j < keep else pos)[0, b, s]


def test_windowed_decode_matches_windowed_forward():
    """Teacher-forced windowed forward vs prefill+decode at the same window.

    Strict allclose (tolerance = a few bf16 ulps at the observed logit
    scale, NOT a correlation), plus the exact ring-occupancy invariant —
    with the pre-fix slot misalignment the ring assertion fails and decode
    drops an in-window key."""
    cfg = get_smoke_config("zamba2-2.7b").with_(window=8, remat=False)
    key = jax.random.PRNGKey(1)
    sp = quantize_for_serving(init_params(cfg, key), cfg)
    B, S = 2, 12
    rng = np.random.default_rng(1)
    toks = jnp.asarray(rng.integers(1, cfg.vocab_size, size=(B, S + 3)), jnp.int32)
    cache, _ = prefill(sp, cfg, {"tokens": toks[:, :S]}, s_max=S + 3)
    for t in range(S, S + 3):  # teacher-force a few steps past the prefill
        _, logits_long = prefill(sp, cfg, {"tokens": toks[:, :t + 1]},
                                 s_max=S + 3)
        logits_step, cache = decode_step(sp, cfg, cache, toks[:, t],
                                         jnp.asarray(t, jnp.int32))
        _assert_ring_occupancy(cache)
        a = np.asarray(logits_long, np.float32)
        b = np.asarray(logits_step, np.float32)
        m = np.abs(a) < 1e29  # finite logits (vocab padding is -1e30)
        np.testing.assert_allclose(b[m], a[m], rtol=2e-2, atol=8e-2)
