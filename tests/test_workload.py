"""Workload subsystem: trace determinism (property), percentile estimator
vs numpy, the virtual-clock admission invariant, load-generator replay
determinism, SLO analysis, saturation sweep, schema checks, and a small
real-engine scenario smoke."""

import json
import os
import sys

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.serving.engine import Request
from repro.serving.loadgen import (LoadGenerator, generate_trace,
                                   latency_summary, percentile)
from repro.serving.workload import (SCENARIOS, ArrivalProcess, Dist,
                                    Scenario, TenantSpec, get_scenario)

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "benchmarks"))
import analysis  # noqa: E402

from test_serving_scheduler import FakeBackend  # noqa: E402


def _fake_backend(batch=4):
    """FakeBackend replays ``req._script``; workload requests carry no
    script, so wrap admission to synthesize one of the right length."""
    backend = FakeBackend(batch)
    orig = backend.sched_admit

    def admit(state, slot, req):
        if not hasattr(req, "_script"):
            req._script = [17] * req.max_new_tokens
        return orig(state, slot, req)

    backend.sched_admit = admit
    return backend


# ---------------------------------------------------------------------------
# trace generation
# ---------------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(st.sampled_from(sorted(SCENARIOS)), st.integers(0, 10_000),
       st.booleans())
def test_trace_byte_identical_for_same_seed(name, seed, smoke):
    """Same (scenario, vocab, seed) ⇒ byte-identical arrival trace; a
    different seed moves it.  Serialized through repr so float timestamps
    compare exactly, not approximately."""
    sc = get_scenario(name)
    if smoke:
        sc = sc.smoke()
    a = generate_trace(sc, 512, seed)
    b = generate_trace(sc, 512, seed)
    assert repr(a) == repr(b)
    c = generate_trace(sc, 512, seed + 1)
    assert repr(a) != repr(c)


def test_trace_shape_and_ordering():
    sc = get_scenario("chat").smoke()
    tr = generate_trace(sc, 512, seed=3)
    assert 0 < len(tr) <= sc.max_requests
    assert all(tr[i].t <= tr[i + 1].t for i in range(len(tr) - 1))
    names = {t.name for t in sc.tenants}
    assert {e.tenant for e in tr} <= names
    for e in tr:
        assert e.t > 0 and e.new_tokens >= 1 and len(e.prompt) >= 1
        assert all(2 <= t < 512 for t in e.prompt)
        ten = {t.name: t for t in sc.tenants}[e.tenant]
        assert len(e.prompt) <= ten.max_prompt_len()


def test_trace_tenant_streams_independent():
    """Dropping one tenant must not perturb the other tenant's draws (the
    SeedSequence-per-tenant contract)."""
    sc = get_scenario("chat").smoke()
    solo = Scenario(name=sc.name, description="", tenants=(sc.tenants[0],),
                    duration_s=sc.duration_s, max_requests=sc.max_requests)
    both = [e for e in generate_trace(sc, 512, 0) if e.tenant ==
            sc.tenants[0].name]
    alone = generate_trace(solo, 512, 0)
    # the solo run keeps every event (no cross-tenant truncation), so
    # compare the common prefix
    n = min(len(both), len(alone))
    assert n > 0
    assert repr(both[:n]) == repr(alone[:n])


def test_shared_prefix_structure():
    """Tenants with shared_prefix_len draw from exactly prefix_groups
    distinct prefixes; prefixes are stable across seeds' token draws only
    via the trace seed."""
    sc = get_scenario("rag").smoke()
    ten = sc.tenants[0]  # the RAG tenant has prefix_groups=8
    assert ten.shared_prefix_len > 0
    tr = [e for e in generate_trace(sc, 512, 5) if e.tenant == ten.name]
    heads = {e.prompt[:ten.shared_prefix_len] for e in tr}
    assert 1 <= len(heads) <= ten.prefix_groups


def test_dist_bounds_and_smoke_shrink():
    rng = np.random.default_rng(0)
    for d in (Dist("fixed", 7), Dist("uniform", 3, 9),
              Dist("lognormal", 20, 64, sigma=0.8),
              Dist("choice", choices=(4, 8, 12))):
        for _ in range(200):
            v = d.sample(rng)
            assert 1 <= v <= d.upper()
        s = d.shrunk(8, lo=2)
        assert s.upper() <= max(d.upper() // 8, 2)
    with pytest.raises(ValueError):
        Dist("uniform", 9, 3)
    with pytest.raises(ValueError):
        Dist("nope")


def test_arrival_process_rates():
    """Mean inter-arrival gaps track 1/rate for every process kind."""
    rng = np.random.default_rng(0)
    for ap in (ArrivalProcess("poisson", 4.0),
               ArrivalProcess("gamma_burst", 4.0, cv=3.0),
               ArrivalProcess("fixed", 4.0)):
        gaps = [ap.next_gap(rng) for _ in range(4000)]
        assert np.mean(gaps) == pytest.approx(0.25, rel=0.1)
    assert ArrivalProcess("poisson", 2.0).scaled(3.0).rate == 6.0
    with pytest.raises(ValueError):
        ArrivalProcess("poisson", 0.0)


def test_scenario_scaled_and_smoke():
    sc = get_scenario("agentic")
    assert sc.scaled(2.0).offered_qps() == pytest.approx(
        2.0 * sc.offered_qps())
    sm = sc.smoke()
    assert sm.max_prompt_len() < sc.max_prompt_len()
    assert sm.duration_s < sc.duration_s
    # SLOs survive the shrink untouched
    assert [t.slo_ttft_s for t in sm.tenants] == \
        [t.slo_ttft_s for t in sc.tenants]
    with pytest.raises(KeyError):
        get_scenario("nope")


# ---------------------------------------------------------------------------
# percentile estimator
# ---------------------------------------------------------------------------


@settings(max_examples=30, deadline=None)
@given(st.integers(1, 200), st.integers(0, 10_000))
def test_percentile_matches_numpy(n, seed):
    """The hand-written linear-interpolation estimator must agree with
    numpy.percentile (its default method) to float precision."""
    rng = np.random.default_rng(seed)
    vals = rng.normal(0.0, 10.0, size=n).tolist()
    for p in (0, 1, 25, 50, 75, 95, 99, 99.9, 100):
        assert percentile(vals, p) == pytest.approx(
            float(np.percentile(vals, p)), abs=1e-9)


def test_percentile_edges():
    assert percentile([], 99) == 0.0
    assert percentile([3.0], 50) == 3.0
    with pytest.raises(ValueError):
        percentile([1.0], 101)
    s = latency_summary([])
    assert s == {"mean": 0.0, "p50": 0.0, "p95": 0.0, "p99": 0.0, "max": 0.0}
    s = latency_summary([2.0, 4.0])
    assert s["mean"] == 3.0 and s["max"] == 4.0 and s["p50"] == 3.0


# ---------------------------------------------------------------------------
# load generator (fake backend)
# ---------------------------------------------------------------------------


def _run_chat(seed=0, **kw):
    sc = get_scenario("chat").smoke()
    tr = generate_trace(sc, 512, seed)
    gen = LoadGenerator(_fake_backend(), tr, clock="virtual",
                        cache_affinity=False, **kw)
    return sc, gen.run()


def test_virtual_clock_admission_invariant():
    """No request may be admitted (or even submitted) before its arrival
    time — the whole point of the admission shim."""
    _, res = _run_chat()
    assert res.records
    for r in res.records:
        assert r.t_submit >= r.t_arrival - 1e-12
        assert r.t_admit is not None and r.t_admit >= r.t_arrival - 1e-12
        assert r.t_first_token is not None and r.t_first_token > r.t_admit
        assert r.t_done is not None and r.t_done >= r.t_first_token
        assert r.ttft_s > 0
        assert r.queue_wait_s >= 0


def test_loadgen_replay_deterministic():
    """Two replays of the same trace produce identical reports, serialized
    bytes and all — the CI diffability contract end to end."""
    sc, res1 = _run_chat()
    _, res2 = _run_chat()
    r1 = analysis.scenario_report(sc, res1, 0)
    r2 = analysis.scenario_report(sc, res2, 0)
    assert json.dumps(r1, sort_keys=True) == json.dumps(r2, sort_keys=True)
    # and a different seed moves the numbers
    sc3, res3 = _run_chat(seed=1)
    r3 = analysis.scenario_report(sc3, res3, 1)
    assert json.dumps(r1, sort_keys=True) != json.dumps(r3, sort_keys=True)


def test_loadgen_records_complete_and_tenant_tagged():
    sc, res = _run_chat()
    by = res.by_tenant()
    assert set(by) == {t.name for t in sc.tenants}
    assert sum(len(v) for v in by.values()) == len(res.records)
    assert all(r.n_out == r.new_tokens_requested for r in res.records)
    assert res.emitted_tokens == sum(r.n_out for r in res.records)
    assert res.achieved_qps > 0 and res.offered_qps > 0


def test_higher_step_cost_degrades_ttft():
    """The cost model must actually flow into the metrics: a 10x slower
    decode step must produce strictly worse tail TTFT."""
    sc, fast = _run_chat(decode_step_cost_s=0.005)
    _, slow = _run_chat(decode_step_cost_s=0.05)
    p99f = percentile([r.ttft_s for r in fast.records], 99)
    p99s = percentile([r.ttft_s for r in slow.records], 99)
    assert p99s > p99f


def test_loadgen_rejects_bad_args():
    tr = generate_trace(get_scenario("chat").smoke(), 512, 0)
    with pytest.raises(ValueError):
        LoadGenerator(_fake_backend(), tr, clock="nope")
    with pytest.raises(ValueError):
        LoadGenerator(_fake_backend(), tr, decode_step_cost_s=0.0)
    with pytest.raises(ValueError):
        LoadGenerator(_fake_backend(), []).run()


# ---------------------------------------------------------------------------
# analysis: SLO report, saturation sweep, schema checks
# ---------------------------------------------------------------------------


def test_scenario_report_slo_fields():
    sc, res = _run_chat()
    rep = analysis.scenario_report(sc, res, 0)
    assert rep["scenario"] == sc.name and rep["clock"] == "virtual"
    assert set(rep["tenants"]) == {t.name for t in sc.tenants}
    for t in rep["tenants"].values():
        assert 0.0 <= t["slo_attainment"] <= 1.0
        assert t["goodput_qps"] >= 0.0
        for sec in ("ttft_s", "tpot_s", "queue_wait_s"):
            assert set(t[sec]) == {"mean", "p50", "p95", "p99", "max"}
        assert 0 < t["ttft_s"]["p50"] <= t["ttft_s"]["p99"] \
            <= t["ttft_s"]["max"]
    assert 0.0 <= rep["slo_attainment"] <= 1.0
    assert rep["ttft_trajectory"], "trajectory must not be empty"
    assert sum(w["requests"] for w in rep["ttft_trajectory"]) == \
        len([r for r in res.records if r.ttft_s is not None])


def test_slo_attainment_reacts_to_thresholds():
    """Impossible SLOs ⇒ attainment 0; infinite SLOs ⇒ attainment 1."""
    sc, res = _run_chat()

    def with_slo(ttft, tpot):
        from dataclasses import replace
        return replace(sc, tenants=tuple(
            replace(t, slo_ttft_s=ttft, slo_tpot_s=tpot)
            for t in sc.tenants))

    loose = analysis.scenario_report(with_slo(1e9, 1e9), res, 0)
    tight = analysis.scenario_report(with_slo(1e-12, 1e-12), res, 0)
    assert loose["slo_attainment"] == 1.0
    assert tight["slo_attainment"] == 0.0
    assert tight["goodput_qps"] == 0.0


def test_saturation_sweep_brackets_knee():
    """Synthetic server with a hard knee: sweep must bracket it and report
    max sustainable QPS inside the passing region."""
    knee = 2.5
    sweep = analysis.saturation_sweep(
        lambda s: 0.05 if s <= knee else 5.0, base_qps=10.0, slo_ttft_s=1.0,
        max_doublings=3, bisect_iters=5, log=None)
    assert sweep["saturated"]
    assert 2.0 <= sweep["max_sustainable_scale"] <= knee + 1e-9
    assert sweep["max_sustainable_qps"] == pytest.approx(
        10.0 * sweep["max_sustainable_scale"])
    assert any(not p["ok"] for p in sweep["probes"])


def test_saturation_sweep_never_failing_is_lower_bound():
    sweep = analysis.saturation_sweep(lambda s: 0.0, base_qps=4.0,
                                      slo_ttft_s=1.0, max_doublings=2,
                                      bisect_iters=3, log=None)
    assert not sweep["saturated"]
    assert sweep["max_sustainable_scale"] == 4.0  # 1 → 2 → 4, all pass


def test_saturation_sweep_fails_at_base_rate():
    sweep = analysis.saturation_sweep(lambda s: 9.0, base_qps=4.0,
                                      slo_ttft_s=1.0, log=None)
    assert sweep["saturated"] and sweep["max_sustainable_qps"] == 0.0


def _minimal_v5_scenario_results():
    sc, res = _run_chat()
    path = {"tokens": 1, "seconds": 1.0, "tok_s": 1.0,
            "ttft_s": latency_summary([0.1]), "tpot_s": latency_summary([0.1])}
    return {"schema_version": 5, "arch": "x", "batch": 4, "mode": "scenario",
            "seed": 0, "request_mix": {},
            "generational": dict(path),
            "continuous": dict(path, queue_wait_s={}),
            "speedup": 1.0, "prefix": {"enabled": False},
            "speculative": {"enabled": False},
            "workload": analysis.scenario_report(sc, res, 0),
            "saturation": None}


def test_check_schema_v5_scenario_roundtrip():
    r = _minimal_v5_scenario_results()
    assert analysis.check_schema(r) == 5
    # the checker localizes what went missing
    del r["workload"]["tenants"]["interactive"]["ttft_s"]
    with pytest.raises(AssertionError, match="interactive"):
        analysis.check_schema(r)
    r2 = _minimal_v5_scenario_results()
    r2["workload"]["slo_attainment"] = 1.5
    with pytest.raises(AssertionError, match="slo_attainment"):
        analysis.check_schema(r2)
    r3 = _minimal_v5_scenario_results()
    r3["mode"] = "nope"
    with pytest.raises(AssertionError, match="mode"):
        analysis.check_schema(r3)


def test_check_schema_accepts_committed_bench_file():
    """The repo's committed BENCH_serving.json must always satisfy its own
    declared schema — this is the one-place back-compat check CI also runs."""
    bench = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "BENCH_serving.json")
    with open(bench) as f:
        results = json.load(f)
    assert analysis.check_schema(results) >= 2


def test_check_schema_v2_minimal():
    base = {"tokens": 1, "seconds": 1.0, "tok_s": 1.0,
            "ttft_s": {"mean": 0.1, "p50": 0.1, "max": 0.1}}
    r = {"schema_version": 2, "arch": "x", "batch": 4,
         "generational": base, "continuous": base, "speedup": 1.0}
    assert analysis.check_schema(r) == 2
    with pytest.raises(AssertionError, match="schema_version"):
        analysis.check_schema({})
    with pytest.raises(AssertionError):
        analysis.check_schema(dict(r, schema_version=9))


def test_diff_benches_reports_deltas():
    old = _minimal_v5_scenario_results()
    new = json.loads(json.dumps(old))
    new["continuous"]["tok_s"] = 2.0
    lines = analysis.diff_benches(old, new, log=lambda s: None)
    assert any("continuous.tok_s" in ln for ln in lines)
    same = analysis.diff_benches(old, old, log=lambda s: None)
    assert same == ["  no tracked metric changed"]


# ---------------------------------------------------------------------------
# real engine smoke
# ---------------------------------------------------------------------------


def test_scenario_replay_on_real_engine(key):
    """A truncated chat smoke scenario through a real DecodeEngine under the
    virtual clock: every record completes, per-tenant percentiles are
    nonzero, and two replays on the same engine serialize identically."""
    import jax

    from repro.configs.registry import get_smoke_config
    from repro.models.decode import quantize_for_serving
    from repro.models.model import init_params
    from repro.serving.engine import DecodeEngine

    cfg = get_smoke_config("bitnet-b1.58-2b").with_(
        n_layers=2, d_model=64, n_heads=2, n_kv_heads=2, head_dim=32,
        d_ff=128, vocab_size=256, loss_chunk=32)
    sc = get_scenario("chat").smoke()
    trace = generate_trace(sc, cfg.vocab_size, seed=0)[:6]
    max_len = max(len(e.prompt) + e.new_tokens for e in trace) + 1
    sp = quantize_for_serving(init_params(cfg, jax.random.PRNGKey(0)), cfg)
    engine = DecodeEngine(sp, cfg, batch_size=2, max_len=max_len,
                          prefill_chunk=16, matmul_policy="fixed:ref")

    def replay():
        gen = LoadGenerator(engine, trace, clock="virtual")
        return analysis.scenario_report(sc, gen.run(), 0)

    rep1, rep2 = replay(), replay()
    assert json.dumps(rep1, sort_keys=True) == json.dumps(rep2,
                                                          sort_keys=True)
    assert rep1["completed"] == len(trace)
    for t in rep1["tenants"].values():
        if t["requests"]:
            assert t["ttft_s"]["p50"] > 0
            assert 0.0 <= t["slo_attainment"] <= 1.0